"""Validate halo-exchange local attention against the plain sliding-window
oracle on an 8-device host mesh (separate process)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn

mesh = jax.make_mesh((2, 4), ("data", "model"))
attn.set_halo_mesh(mesh)

B, S, d, H, KV, hd, W = 2, 64, 32, 4, 2, 8, 8
assert attn.halo_attn_available(S, W, 4)
p = attn.init_attn(jax.random.PRNGKey(0), d, H, KV, hd, True, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d)) * 0.5
positions = jnp.arange(S)

y_ref = attn.attn_forward(p, x, positions, num_heads=H, num_kv_heads=KV,
                          head_dim=hd, window=W, rope_theta=1e4, use_rope=True)

with (jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") else mesh):
    y_halo, k, v = jax.jit(
        lambda p_, x_: attn.attn_forward_halo(
            p_, x_, num_heads=H, num_kv_heads=KV, head_dim=hd, window=W,
            rope_theta=1e4, use_rope=True, return_kv=True))(p, x)

np.testing.assert_allclose(np.asarray(y_halo), np.asarray(y_ref),
                           rtol=2e-4, atol=2e-4)
print("halo attention == sliding-window oracle: OK")

# gradient flows through the ppermute
g = jax.grad(lambda x_: jnp.sum(attn.attn_forward_halo(
    p, x_, num_heads=H, num_kv_heads=KV, head_dim=hd, window=W,
    rope_theta=1e4, use_rope=True) ** 2))(x)
assert bool(jnp.all(jnp.isfinite(g)))
print("halo attention gradients: OK")
