"""Render the EXPERIMENTS.md roofline table from experiments/dryrun/*.json."""
import glob
import json
import os
import sys

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_s(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def main(out_dir="experiments/dryrun", mesh="16x16"):
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, f"*_{mesh}.json"))):
        rec = json.load(open(path))
        r = rec["roofline"]
        rows.append((rec["arch"], rec["shape"], r))
    rows.sort(key=lambda t: (t[0], ORDER.index(t[1])))
    print(f"| arch | shape | compute | memory | collective | dominant | "
          f"MODEL_FLOPS | useful | compile |")
    print("|---|---|---|---|---|---|---|---|---|")
    for arch, shape, r in rows:
        rec = json.load(open(os.path.join(out_dir, f"{arch}_{shape}_{mesh}.json")))
        print(f"| {arch} | {shape} | {fmt_s(r['compute_s'])} | "
              f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
              f"**{r['dominant']}** | {r['model_flops']:.2e} | "
              f"{r['useful_ratio']:.2f} | {rec['compile_s']:.0f}s |")


if __name__ == "__main__":
    main(*sys.argv[1:])
