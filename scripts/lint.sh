#!/usr/bin/env bash
# repro-lint over the default trees (same invocation the CI lint job
# runs, text output). Extra args pass through, e.g.:
#   scripts/lint.sh --explain all
#   scripts/lint.sh --format=json src
set -euo pipefail
cd "$(dirname "$0")/.."
if [ "$#" -gt 0 ]; then
    PYTHONPATH=src python -m repro.analysis "$@"
else
    PYTHONPATH=src python -m repro.analysis src benchmarks examples
fi
