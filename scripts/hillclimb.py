"""§Perf hillclimb runner: re-lowers a (arch, shape) pair with an
optimization variant and prints before/after roofline terms.

  PYTHONPATH=src python scripts/hillclimb.py qwen3-moe-30b-a3b train_4k moe_ep
  PYTHONPATH=src python scripts/hillclimb.py granite-3-8b decode_32k int8_kv
  PYTHONPATH=src python scripts/hillclimb.py gemma3-4b prefill_32k seq_parallel
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "3")

import dataclasses
import json
import sys

from repro.configs import get_config
from repro.launch import dryrun
from repro.launch.mesh import data_axes, make_production_mesh

VARIANTS = {
    "moe_ep": dict(moe_impl="expert_parallel"),
    "moe_ep+seq_parallel": dict(moe_impl="expert_parallel", seq_parallel=True),
    "int8_kv": dict(kv_cache_dtype="int8"),
    "seq_parallel": dict(seq_parallel=True),
    "int8_kv+seq_parallel": dict(kv_cache_dtype="int8", seq_parallel=True),
    "int8_kv+vocab_pad": dict(kv_cache_dtype="int8", _vocab_pad=16),
    "vocab_pad": dict(_vocab_pad=16),
    "baseline": {},
}


def main():
    arch, shape, variant = sys.argv[1], sys.argv[2], sys.argv[3]
    multi_pod = len(sys.argv) > 4 and sys.argv[4] == "--multi-pod"
    overrides = dict(VARIANTS[variant])
    vocab_pad = overrides.pop("_vocab_pad", 0)
    cfg = dataclasses.replace(get_config(arch), **overrides)
    if vocab_pad:
        v = -(-cfg.vocab_size // vocab_pad) * vocab_pad
        cfg = dataclasses.replace(cfg, vocab_size=v)

    if cfg.seq_parallel:
        from repro.models import attention, transformer
        mesh = make_production_mesh(multi_pod=multi_pod)
        transformer.set_sequence_parallel_axes(data_axes(mesh))
        attention.set_halo_mesh(mesh)

    rec = dryrun.run_one(arch, shape, multi_pod=multi_pod,
                         cfg_override=cfg, verbose=True)
    tag = f"experiments/perf/{arch}_{shape}_{variant}.json"
    os.makedirs(os.path.dirname(tag), exist_ok=True)
    with open(tag, "w") as f:
        json.dump(rec, f, indent=2, default=str)

    base_path = f"experiments/dryrun/{arch}_{shape}_{rec['mesh']}.json"
    if os.path.exists(base_path) and variant != "baseline":
        base = json.load(open(base_path))["roofline"]
        new = rec["roofline"]
        print(f"\n=== {arch} × {shape} : baseline → {variant}")
        for term in ["compute_s", "memory_s", "collective_s"]:
            b, n = base[term], new[term]
            delta = (n - b) / b * 100 if b else float("nan")
            print(f"  {term:13s} {b:.3e} → {n:.3e}  ({delta:+.1f}%)")
        print(f"  dominant      {base['dominant']} → {new['dominant']}")
        print(f"  coll_by_kind  {base['coll_by_kind']}")
        print(f"            →   {new['coll_by_kind']}")


if __name__ == "__main__":
    main()
