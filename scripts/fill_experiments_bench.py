"""Render EXPERIMENTS.md §Paper-claims tables from bench_output.txt CSV."""
import re
import sys


def parse(path="bench_output.txt"):
    rows = {}
    for line in open(path):
        line = line.strip()
        if "," not in line or line.startswith(("name,", "#", "step")):
            continue
        name, us, derived = line.split(",", 2)
        kv = dict(p.split("=", 1) for p in derived.split(";") if "=" in p)
        rows[name] = kv
    return rows


def main(path="bench_output.txt"):
    rows = parse(path)
    out = []
    out.append("### Appendix-A-style table (toy scale, N per column)\n")
    out.append("| method | N | accuracy | final-branch toks | total toks | peak KV (MB) |")
    out.append("|---|---|---|---|---|---|")
    for key, kv in rows.items():
        if not key.startswith("kappa_table/"):
            continue
        m = re.match(r"kappa_table/(\w+?)_N(\d+)", key)
        out.append(f"| {m.group(1)} | {m.group(2)} | {kv['acc']} | "
                   f"{kv['final_toks']} | {kv['total_toks']} | {kv['peak_mb']} |")

    out.append("\n### Fig. 2/3 analogues — reduction vs BoN\n")
    out.append("| N | token reduction | memory reduction |")
    out.append("|---|---|---|")
    ns = sorted({int(k.split("N")[-1]) for k in rows if k.startswith("token_ratio/")})
    for n in ns:
        t = rows.get(f"token_ratio/N{n}", {})
        m = rows.get(f"memory_ratio/N{n}", {})
        out.append(f"| {n} | {float(t.get('reduction', 0)):.1%} | "
                   f"{float(m.get('reduction', 0)):.1%} |")

    for tag, title in [("schedule_ablation", "Pruning-schedule ablation (§4.2)"),
                       ("weight_ablation", "Signal-weight ablation (§4.1)"),
                       ("horizon_ablation", "Adaptive-horizon ablation (paper §5 future work)")]:
        sub = {k: v for k, v in rows.items() if k.startswith(tag + "/")}
        if not sub:
            continue
        out.append(f"\n### {title}\n")
        out.append("| variant | accuracy | total toks |")
        out.append("|---|---|---|")
        for k, v in sub.items():
            out.append(f"| {k.split('/', 1)[1]} | {v.get('acc', '—')} | "
                       f"{v.get('total_toks', '—')} |")
    print("\n".join(out))


if __name__ == "__main__":
    main(*sys.argv[1:])
