"""Validate the expert-parallel shard_map MoE against the dropless einsum
oracle on an 8-device host mesh (separate process: forces device count)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe

mesh = jax.make_mesh((2, 4), ("data", "model"))
moe.set_mesh(mesh)

E, K, d, ff = 8, 2, 16, 32
p = moe.init_moe(jax.random.PRNGKey(0), d, ff, E, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, d))

with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") else mesh:
    y_ref, aux_ref = moe.moe_ffn(p, x, num_experts=E, experts_per_tok=K,
                                 capacity_factor=0.0)
    y_ep, aux_ep = jax.jit(
        lambda p_, x_: moe.moe_ffn_expert_parallel(
            p_, x_, num_experts=E, experts_per_tok=K, capacity_factor=16.0)
    )(p, x)

np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                           rtol=2e-4, atol=2e-4)
print("expert-parallel MoE == dropless oracle: OK")

with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") else mesh:
    y_seq, _ = jax.jit(
        lambda p_, x_: moe.moe_ffn_expert_parallel(
            p_, x_, num_experts=E, experts_per_tok=K, capacity_factor=16.0,
            seq_sharded=True)
    )(p, x)
np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_ref),
                           rtol=2e-4, atol=2e-4)
print("expert-parallel MoE (seq-sharded) == dropless oracle: OK")
print(f"aux ref={float(aux_ref):.4f} ep={float(aux_ep):.4f}")

# gradient flows
def loss_ep(p_, x_):
    y, aux = moe.moe_ffn_expert_parallel(p_, x_, num_experts=E,
                                         experts_per_tok=K,
                                         capacity_factor=16.0)
    return jnp.sum(y ** 2) + 0.01 * aux

g = jax.jit(jax.grad(loss_ep))(p, x)
assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g))
assert float(jnp.abs(g["wg"]).sum()) > 0
print("expert-parallel MoE gradients: OK")
