"""Dev smoke: every reduced arch — train forward, prefill+decode agreement."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import all_configs
from repro.models import decode_step, init_cache, init_params, prefill, train_logits
from repro.models.frontends import stub_frontend

rng = jax.random.PRNGKey(0)
failures = []
for name, full in all_configs().items():
    cfg = full.reduced()
    try:
        k1, k2 = jax.random.split(jax.random.fold_in(rng, hash(name) % 2**31))
        params = init_params(k1, cfg)
        B, S = 2, 12
        tokens = jax.random.randint(k2, (B, S), 0, cfg.vocab_size)
        fe = stub_frontend(k2, cfg, B)
        logits, aux = train_logits(params, cfg, tokens, fe)
        assert logits.shape == (B, S, cfg.vocab_size), logits.shape
        assert not bool(jnp.any(jnp.isnan(logits))), "nan in train logits"

        # prefill on first S-1 tokens, decode last token step, compare with
        # teacher-forced logits at the same position
        cache = init_cache(cfg, B, max_seq=32)
        pf_logits, cache = prefill(params, cfg, tokens[:, :S - 1], cache, fe)
        assert pf_logits.shape == (B, cfg.vocab_size)
        np.testing.assert_allclose(np.asarray(pf_logits),
                                   np.asarray(logits[:, S - 2]), rtol=2e-4, atol=2e-4)
        n_prefix = cfg.frontend_tokens if (cfg.frontend and not cfg.is_encoder_decoder) else 0
        d_logits, cache = decode_step(params, cfg, tokens[:, S - 1],
                                      jnp.int32(S - 1 + n_prefix), cache)
        np.testing.assert_allclose(np.asarray(d_logits),
                                   np.asarray(logits[:, S - 1]), rtol=2e-4, atol=2e-4)
        print(f"OK   {name}")
    except Exception as e:  # noqa: BLE001
        failures.append((name, repr(e)[:500]))
        print(f"FAIL {name}: {repr(e)[:500]}")

sys.exit(1 if failures else 0)
