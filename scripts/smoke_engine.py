"""Dev smoke: train a tiny model on the arithmetic task, run all four
generation strategies, print accuracy/token/memory comparison."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import KappaConfig
from repro.data import tasks
from repro.data import tokenizer as tok
from repro.serving import engine
from repro.training.train import init_train_state, train_step

cfg = get_config("deepseek-r1-distill-qwen-1.5b").reduced(
    num_layers=2, d_model=256, vocab_size=tok.VOCAB_SIZE)

rng = jax.random.PRNGKey(0)
state = init_train_state(rng, cfg)

t0 = time.time()
train = tasks.make_dataset(0, 16384, min_steps=2, max_steps=5, num_ops=2, max_operand=10)
B, L = 64, 32
for step in range(1200):
    batch = [train[(step * B + i) % len(train)] for i in range(B)]
    toks, mask = tasks.pack_batch(batch, L)
    state, metrics = train_step(state, cfg, jnp.asarray(toks), jnp.asarray(mask),
                                jnp.int32(step), None, total=1200)
    if step % 200 == 0 or step == 1199:
        print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
              f"lr {float(metrics['lr']):.2e}  ({time.time()-t0:.0f}s)")

params = state.params
test = tasks.make_dataset(999, 40, min_steps=2, max_steps=5, num_ops=2, max_operand=10)
kcfg = KappaConfig(num_branches=5, max_new_tokens=48, max_cutoff=6, horizon=8,
                   window=8, mom_buckets=4)

for name, fn in [("greedy", engine.generate_greedy), ("bon", engine.generate_bon),
                 ("stbon", engine.generate_stbon), ("kappa", engine.generate_kappa)]:
    acc = toks_l = toks_c = peak = 0
    t0 = time.time()
    for i, prob in enumerate(test):
        r = fn(params, cfg, kcfg, np.array(prob.prompt), jax.random.PRNGKey(i),
               eos_id=tok.EOS, bos_id=tok.BOS)
        acc += tasks.check_answer(r.tokens, prob)
        toks_l += r.logical_tokens
        toks_c += r.compute_tokens
        peak = max(peak, r.peak_cache_bytes)
    print(f"{name:7s} acc {acc/len(test):.3f}  logical_toks {toks_l/len(test):8.1f}  "
          f"compute_toks {toks_c/len(test):8.1f}  peak_cache {peak/1e6:6.2f}MB  "
          f"({time.time()-t0:.0f}s)")
