"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152, GQA + RoPE, native 4k sliding window.  [arXiv:2402.19173]

StarCoder2 uses sliding-window attention (window 4096) — we model it as
all-local, which also qualifies it for the long_500k decode shape.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b",
        family="dense",
        num_layers=30,
        d_model=3072,
        num_heads=24,
        num_kv_heads=2,
        d_ff=12_288,
        vocab_size=49_152,
        qkv_bias=True,
        layer_pattern=("local",),
        window_size=4096,
        rope_theta=100_000.0,
        tie_embeddings=True,
        source="arXiv:2402.19173",
    )
