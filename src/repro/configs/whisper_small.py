"""whisper-small [audio] — enc-dec, 12L(+12L enc) d_model=768 12H (kv=12)
d_ff=3072 vocab=51865, conv/mel frontend STUBBED (precomputed frame
embeddings).  [arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        family="audio",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        d_ff=3072,
        vocab_size=51_865,
        layer_pattern=("global",),
        use_rope=False,  # whisper uses learned/sinusoidal positions
        is_encoder_decoder=True,
        encoder_layers=12,
        encoder_seq_len=1500,
        frontend="audio",
        frontend_tokens=1500,
        tie_embeddings=True,
        source="arXiv:2212.04356",
    )
