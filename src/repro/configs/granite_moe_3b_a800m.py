"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512/expert
vocab=49155, MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base family / granite-3.0-3b-a800m]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        num_layers=32,
        d_model=1536,
        num_heads=24,
        num_kv_heads=8,
        d_ff=512,  # per-expert FFN width
        vocab_size=49_155,
        num_experts=40,
        experts_per_tok=8,
        layer_pattern=("global",),
        rope_theta=10_000.0,
        tie_embeddings=True,
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    )
