"""Configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; input
shapes as ``InputShape``; the KAPPA algorithm's hyperparameters as
``KappaConfig`` (defaults = the paper's tuned values, §4.1).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Static architecture description.

    ``layer_pattern`` is cycled over the layer stack and selects the
    block type per layer:
      "global"    — full-causal GQA attention
      "local"     — sliding-window GQA attention (window ``window_size``)
      "recurrent" — RG-LRU recurrent block (recurrentgemma)
      "rwkv6"     — RWKV-6 time-mix block (attention-free)
    """

    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    layer_pattern: Tuple[str, ...] = ("global",)
    window_size: int = 4096
    # MoE
    num_experts: int = 0
    experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25  # <=0 → dropless (exact) routing
    # "einsum": sort-based dispatch under plain pjit (XLA inserts the
    # collectives — measured pathological: full-activation all-reduce).
    # "expert_parallel": hand-written shard_map all-to-all dispatch
    # (§Perf hillclimb A); requires repro.models.moe.set_mesh(...).
    moe_impl: str = "einsum"
    # RoPE
    rope_theta: float = 10_000.0
    use_rope: bool = True
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 1500  # whisper: 30 s of audio @ 50 Hz after conv
    # modality frontend stub: None | "audio" | "vision"
    frontend: Optional[str] = None
    frontend_tokens: int = 0  # patch/frame embeddings prepended by the stub
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # unroll the layer stack instead of lax.scan — used by the dry-run so
    # cost_analysis sees every layer (XLA counts while bodies once)
    unroll: bool = False
    # int8-quantized KV cache (per token-head absmax scales): halves the
    # decode HBM traffic of the cache read (§Perf hillclimb B)
    kv_cache_dtype: str = "model"  # "model" (= cfg.dtype) | "int8"
    # Megatron-style sequence parallelism: activations shard seq-on-model
    # between blocks, turning the TP all-reduces into RS+AG (§Perf C)
    seq_parallel: bool = False
    # citation for the assigned config
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def attention_free(self) -> bool:
        return all(p in ("rwkv6", "recurrent") for p in self.layer_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if no layer keeps an unbounded full-attention KV cache."""
        return all(p in ("rwkv6", "recurrent", "local") for p in self.layer_pattern)

    def block_types(self) -> Tuple[str, ...]:
        """Per-layer block type, pattern cycled over num_layers."""
        p = self.layer_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    def reduced(self, num_layers: int = 2, d_model: int = 256,
                num_experts: int = 4, vocab_size: int = 512) -> "ModelConfig":
        """Smoke-test variant of the same family (<=2 layers, d_model<=512,
        <=4 experts) — runs a real forward/train step on CPU."""
        heads = max(2, min(4, self.num_heads))
        kv = max(1, min(heads, self.num_kv_heads if self.num_kv_heads else heads))
        # keep the GQA-ness: if original had kv < heads, keep ratio >= 2
        if self.num_kv_heads and self.num_kv_heads < self.num_heads:
            kv = max(1, heads // 2)
        enc_layers = min(self.encoder_layers, num_layers) if self.is_encoder_decoder else 0
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=num_layers,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=None,
            d_ff=d_model * 2,
            vocab_size=vocab_size,
            num_experts=min(self.num_experts, num_experts) if self.is_moe else 0,
            experts_per_tok=min(self.experts_per_tok, 2) if self.is_moe else 0,
            moe_capacity_factor=0.0,  # dropless → prefill+decode ≡ train exactly
            window_size=64,
            encoder_layers=enc_layers,
            encoder_seq_len=16,
            frontend_tokens=16 if self.frontend else 0,
            dtype="float32",
        )

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS = 6·N·D)."""
        d, hd = self.d_model, self.resolved_head_dim
        q = self.num_heads * hd
        kvd = self.num_kv_heads * hd
        attn = d * q + 2 * d * kvd + q * d  # Q,K,V,O
        if self.is_moe:
            ffn = self.num_experts * 3 * d * self.d_ff + d * self.num_experts  # experts + router
        else:
            ffn = 3 * d * self.d_ff  # SwiGLU
        per_layer = 0
        for bt in self.block_types():
            if bt in ("global", "local"):
                per_layer += attn + ffn + 2 * d
            elif bt == "recurrent":
                # RG-LRU block: in/out proj + gates (~4 d*d_rnn, d_rnn≈d) + ffn
                per_layer += 4 * d * d + ffn + 2 * d
            elif bt == "rwkv6":
                # time-mix (5 d*d + lora decays) + channel-mix (2 d*d_ff)
                per_layer += 5 * d * d + 2 * d * self.d_ff + 2 * d
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        enc = 0
        if self.is_encoder_decoder:
            # encoder layers (full attn, no GQA reduction assumed) + cross-attn in decoder
            enc = self.encoder_layers * (4 * d * d + 3 * d * self.d_ff + 2 * d)
            per_layer += self.num_layers * (2 * d * d + 2 * d * kvd)  # cross-attn
        return per_layer + emb + enc

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        ffn_all = self.num_layers * self.num_experts * 3 * d * self.d_ff
        ffn_act = self.num_layers * self.experts_per_tok * 3 * d * self.d_ff
        return full - ffn_all + ffn_act


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class KappaConfig:
    """KAPPA hyperparameters — defaults are the paper's tuned values."""

    num_branches: int = 5          # N
    draft_cutoff: int = 8          # c (paper: earliest pairwise difference; we
                                   # support both fixed and adaptive — see core.kappa)
    adaptive_cutoff: bool = True   # ST-BoN-style earliest-pairwise-difference c
    max_cutoff: int = 64           # upper bound on adaptive c
    horizon: int = 32              # τ — pruning horizon
    window: int = 16               # w — MoM window
    mom_buckets: int = 4           # m
    ema_rate: float = 0.5          # α
    w_kl: float = 0.7
    w_conf: float = 0.2
    w_ent: float = 0.1
    schedule: str = "linear"       # linear | cosine | step  (paper: linear; cosine
                                   # is the paper's own suggested extension, §4.2)
    # adaptive pruning horizon (paper §5 future work): scale τ by the mean
    # normalized branch entropy at the draft cutoff — harder problems
    # (flatter next-token distributions) get a longer gating phase
    adaptive_horizon: bool = False
    horizon_beta: float = 1.0      # sensitivity; τ_eff ∈ [τ/2, 2τ]
    zscore_clip: float = 3.0
    eps: float = 1e-9
    # sampling (paper §4.1)
    temperature: float = 0.7
    top_k: int = 20
    top_p: float = 0.95
    max_new_tokens: int = 1024
    compaction: bool = True        # bucketed branch compaction (TPU adaptation)


@dataclass(frozen=True)
class MeshConfig:
    data: int = 16
    model: int = 16
    pods: int = 1

    @property
    def num_devices(self) -> int:
        return self.data * self.model * self.pods


# TPU v5e analytical constants (roofline targets; container is CPU-only)
TPU_PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
TPU_HBM_BW = 819e9             # bytes/s per chip
TPU_ICI_BW = 50e9              # bytes/s per link
