"""internvl2-76b [vlm] — language decoder: 80L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256 (Llama-3-70B backbone). InternViT vision encoder is a
STUB frontend providing precomputed patch embeddings.  [arXiv:2404.16821]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b",
        family="vlm",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=28_672,
        vocab_size=128_256,
        layer_pattern=("global",),
        rope_theta=500_000.0,
        tie_embeddings=False,
        frontend="vision",
        frontend_tokens=256,  # patch embeddings per image from the stub projector
        source="arXiv:2404.16821",
    )
