"""deepseek-r1-distill-qwen-1.5b — the paper's small evaluation model
(Qwen2.5-1.5B backbone). 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936.  [hf:deepseek-ai/DeepSeek-R1-Distill-Qwen-1.5B]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-r1-distill-qwen-1.5b",
        family="dense",
        num_layers=28,
        d_model=1536,
        num_heads=12,
        num_kv_heads=2,
        d_ff=8960,
        vocab_size=151_936,
        qkv_bias=True,
        layer_pattern=("global",),
        rope_theta=10_000.0,
        tie_embeddings=True,
        source="hf:deepseek-ai/DeepSeek-R1-Distill-Qwen-1.5B",
    )
