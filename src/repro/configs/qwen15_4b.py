"""qwen1.5-4b [dense] — 40L d_model=2560 20H (GQA kv=20) d_ff=6912
vocab=151936, QKV bias.  [hf:Qwen/Qwen1.5-0.5B family]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b",
        family="dense",
        num_layers=40,
        d_model=2560,
        num_heads=20,
        num_kv_heads=20,
        d_ff=6912,
        vocab_size=151_936,
        qkv_bias=True,
        layer_pattern=("global",),
        rope_theta=1_000_000.0,
        tie_embeddings=False,
        source="hf:Qwen/Qwen1.5-0.5B",
    )
