"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) d_ff=768/expert
vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        d_ff=768,  # per-expert (moe_intermediate_size)
        vocab_size=151_936,
        num_experts=128,
        experts_per_tok=8,
        layer_pattern=("global",),
        rope_theta=1_000_000.0,
        tie_embeddings=False,
        source="hf:Qwen/Qwen3-30B-A3B",
    )
