"""granite-3-8b [dense] — 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155.  [hf:ibm-granite/granite-3.0-2b-base family / granite-3.0-8b]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b",
        family="dense",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=12_800,
        vocab_size=49_155,
        layer_pattern=("global",),
        rope_theta=10_000.0,
        tie_embeddings=True,
        source="hf:ibm-granite/granite-3.0-2b-base",
    )
