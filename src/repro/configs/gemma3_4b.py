"""gemma3-4b [dense] — 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144, 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt family]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b",
        family="dense",
        num_layers=34,
        d_model=2560,
        num_heads=8,
        num_kv_heads=4,
        d_ff=10_240,
        vocab_size=262_144,
        # gemma3: 5 sliding-window layers per 1 global layer
        layer_pattern=("local", "local", "local", "local", "local", "global"),
        window_size=1024,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        source="hf:google/gemma-3-1b-pt",
    )
