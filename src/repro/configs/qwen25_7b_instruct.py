"""qwen2.5-7b-instruct — the paper's large evaluation model.
28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
[hf:Qwen/Qwen2.5-7B-Instruct]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-7b-instruct",
        family="dense",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        d_ff=18_944,
        vocab_size=152_064,
        qkv_bias=True,
        layer_pattern=("global",),
        rope_theta=1_000_000.0,
        tie_embeddings=False,
        source="hf:Qwen/Qwen2.5-7B-Instruct",
    )
