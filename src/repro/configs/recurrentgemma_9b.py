"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000, Griffin: RG-LRU recurrent blocks + local attention, pattern 2
recurrent : 1 local-attention.  [arXiv:2402.19427]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        d_ff=12_288,
        vocab_size=256_000,
        layer_pattern=("recurrent", "recurrent", "local"),
        window_size=2048,
        rope_theta=10_000.0,
        tie_embeddings=True,
        source="arXiv:2402.19427",
    )
