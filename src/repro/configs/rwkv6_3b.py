"""rwkv6-3b [ssm] — "Finch": 32L d_model=2560 (attention-free) d_ff=8960
vocab=65536, data-dependent decay time-mix.  [arXiv:2404.05892]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        family="ssm",
        num_layers=32,
        d_model=2560,
        num_heads=40,       # RWKV6 head_size=64 → 2560/64 = 40 heads
        num_kv_heads=40,
        head_dim=64,
        d_ff=8960,
        vocab_size=65_536,
        layer_pattern=("rwkv6",),
        use_rope=False,
        tie_embeddings=False,
        source="arXiv:2404.05892",
    )
