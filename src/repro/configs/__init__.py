"""Architecture config registry.

``get_config(name)`` returns the full-size assigned config;
``get_config(name).reduced()`` the CPU smoke variant.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (
    INPUT_SHAPES,
    TPU_HBM_BW,
    TPU_ICI_BW,
    TPU_PEAK_FLOPS,
    InputShape,
    KappaConfig,
    MeshConfig,
    ModelConfig,
)

# arch id -> module name
_REGISTRY: Dict[str, str] = {
    # the 10 assigned architectures
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "qwen1.5-4b": "qwen15_4b",
    "gemma3-4b": "gemma3_4b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "internvl2-76b": "internvl2_76b",
    "starcoder2-3b": "starcoder2_3b",
    "whisper-small": "whisper_small",
    "granite-3-8b": "granite_3_8b",
    "rwkv6-3b": "rwkv6_3b",
    # the paper's own evaluation models
    "deepseek-r1-distill-qwen-1.5b": "deepseek_r1_distill_qwen_15b",
    "qwen2.5-7b-instruct": "qwen25_7b_instruct",
}

ASSIGNED_ARCHS: List[str] = list(_REGISTRY)[:10]
PAPER_ARCHS: List[str] = list(_REGISTRY)[10:]


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    mod = importlib.import_module(f"repro.configs.{_REGISTRY[name]}")
    return mod.config()


def all_configs() -> Dict[str, ModelConfig]:
    return {name: get_config(name) for name in _REGISTRY}


def applicable_shapes(cfg: ModelConfig) -> List[str]:
    """Which of the 4 assigned input shapes apply to this arch.

    long_500k needs sub-quadratic attention (SSM / hybrid / all-local /
    local-global mixes where the unbounded-cache layers still shard); we
    run it for archs whose layer pattern contains any bounded-memory
    block type AND skip pure-full-attention stacks (noted in DESIGN.md).
    Encoder-decoder archs keep decode_32k (decoder KV) but skip long_500k.
    """
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    pat = set(cfg.block_types())
    sub_quadratic_ok = pat <= {"rwkv6", "recurrent", "local"} or (
        "local" in pat and "global" in pat and not cfg.is_encoder_decoder
    )
    if sub_quadratic_ok and not cfg.is_encoder_decoder:
        shapes.append("long_500k")
    return shapes


__all__ = [
    "ModelConfig", "InputShape", "KappaConfig", "MeshConfig",
    "INPUT_SHAPES", "ASSIGNED_ARCHS", "PAPER_ARCHS",
    "get_config", "all_configs", "applicable_shapes",
    "TPU_PEAK_FLOPS", "TPU_HBM_BW", "TPU_ICI_BW",
]
