"""KAPPA controller — jittable per-step state update + prune decision.

This is the paper's Algorithm 2 as a fixed-shape JAX state machine over N
branches. The serving engine (repro.serving.engine) drives the model,
feeds per-branch next-token logits in, and applies the returned alive
mask (with bucketed compaction — see DESIGN.md §2).

Phases are encoded in the state rather than in Python control flow so the
whole decode step jits:
  draft   : t < cutoff         — no scoring, all branches alive
  gating  : cutoff ≤ t < cutoff+τ — score + prune on the schedule
  continue: one survivor decodes to EOS
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import KappaConfig
from repro.core import robust, schedule, scoring, signals


class KappaState(NamedTuple):
    alive: jnp.ndarray        # (N,) bool
    prev_kl: jnp.ndarray      # (N,) fp32 — D_{t-1} (D_{c-1} ≡ 0)
    di_buf: jnp.ndarray       # (N, w) fp32 ring buffer of ΔI
    di_count: jnp.ndarray     # scalar int32 — valid entries in di_buf (≤ w)
    di_ptr: jnp.ndarray       # scalar int32 — monotone ring write pointer
    ema_raw: jnp.ndarray      # (N,) fp32 uncorrected EMA
    ema_steps: jnp.ndarray    # scalar int32 — EMA updates so far
    traj_num: jnp.ndarray     # (N,) fp32
    traj_den: jnp.ndarray     # scalar fp32
    traj: jnp.ndarray         # (N,) fp32 — current trajectory score S_t
    step: jnp.ndarray         # scalar int32 — decode steps taken
    cutoff: jnp.ndarray       # scalar int32 — c (set when draft ends)
    in_gating: jnp.ndarray    # scalar bool
    diverged: jnp.ndarray     # (N, N) bool — pairwise prefix divergence
    horizon_dyn: jnp.ndarray  # scalar int32 — effective τ (adaptive-horizon)


def init_state(cfg: KappaConfig, n: Optional[int] = None) -> KappaState:
    """Fresh controller state over ``n`` branch rows (default
    ``cfg.num_branches``). Passing a smaller ``n`` gives a row-subset
    view for schedulers that admit a request with fewer rows than the
    configured fan-out; the pruning schedule still anneals from
    ``cfg.num_branches`` (see kappa_step)."""
    n = cfg.num_branches if n is None else n
    w = cfg.window
    eye = jnp.eye(n, dtype=bool)
    return KappaState(
        alive=jnp.ones((n,), bool),
        prev_kl=jnp.zeros((n,), jnp.float32),
        di_buf=jnp.zeros((n, w), jnp.float32),
        di_count=jnp.int32(0),
        di_ptr=jnp.int32(0),
        ema_raw=jnp.zeros((n,), jnp.float32),
        ema_steps=jnp.int32(0),
        traj_num=jnp.zeros((n,), jnp.float32),
        traj_den=jnp.float32(0.0),
        traj=jnp.zeros((n,), jnp.float32),
        step=jnp.int32(0),
        cutoff=jnp.int32(cfg.max_cutoff if cfg.adaptive_cutoff else cfg.draft_cutoff),
        in_gating=jnp.bool_(False),
        diverged=eye,  # diagonal "True" so all-pairwise checks read clean
        horizon_dyn=jnp.int32(cfg.horizon),
    )


def _update_divergence(state: KappaState, tokens) -> KappaState:
    """Track earliest pairwise inconsistency (ST-BoN's draft-cutoff rule).
    tokens: (N,) int32 sampled this step."""
    neq = tokens[:, None] != tokens[None, :]
    return state._replace(diverged=state.diverged | neq)


def _all_pairwise_diverged(state: KappaState) -> jnp.ndarray:
    return jnp.all(state.diverged)


def _score_update(state: KappaState, sigs, cfg: KappaConfig,
                  mask=None) -> Tuple[KappaState, jnp.ndarray]:
    """One gating-phase scoring step (Alg. 2 lines 13–21).
    Returns (state, trajectory scores). ``mask`` (default ``state.alive``)
    is the z-score population — the finite-guard narrows it so poisoned
    rows can't sit in sibling branches' normalization statistics."""
    kl, conf, ent = sigs
    if mask is None:
        mask = state.alive
    first = state.ema_steps == 0
    d_prev = jnp.where(first, jnp.zeros_like(kl), state.prev_kl)  # D_{c-1} ≡ 0
    di = kl - d_prev

    # ring write: the slot comes from the MONOTONE pointer, not from
    # di_count — di_count clamps at w (it is the valid-entry count fed to
    # median_of_means), so indexing by it would pin every post-warmup
    # write to slot 0 and leave slots 1..w-1 permanently stale
    slot = jnp.mod(state.di_ptr, cfg.window)
    di_buf = jax.lax.dynamic_update_index_in_dim(state.di_buf, di, slot, axis=1)
    di_ptr = state.di_ptr + 1
    di_count = jnp.minimum(state.di_count + 1, cfg.window)
    di_hat = robust.median_of_means(di_buf, di_count, cfg.mom_buckets)

    ema_raw = robust.ema_update(state.ema_raw, di_hat, cfg.ema_rate)
    ema_steps = state.ema_steps + 1
    ema_hat = robust.ema_debias(ema_raw, ema_steps, cfg.ema_rate)

    z_ema = scoring.masked_zscore(ema_hat, mask, cfg.zscore_clip)
    z_conf = scoring.masked_zscore(conf, mask, cfg.zscore_clip)
    z_ent = scoring.masked_zscore(ent, mask, cfg.zscore_clip)
    s = scoring.aggregate(z_ema, z_conf, z_ent, cfg.w_kl, cfg.w_conf, cfg.w_ent)

    num, den, traj = scoring.trajectory_update(
        state.traj_num, state.traj_den, s, state.step)

    return state._replace(
        prev_kl=kl, di_buf=di_buf, di_count=di_count, di_ptr=di_ptr,
        ema_raw=ema_raw, ema_steps=ema_steps,
        traj_num=num, traj_den=den, traj=traj), traj


def _prune(alive, traj, r_target):
    """Keep the r_target highest-trajectory alive branches (Alg. 2 l. 25).
    Never prunes below 1; dead branches stay dead."""
    n = alive.shape[0]
    neg = jnp.float32(-3.4e38)
    masked = jnp.where(alive, traj, neg)
    order = jnp.argsort(-masked)                       # best first
    rank = jnp.zeros((n,), jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
    keep = (rank < r_target) & alive
    # safety: if r_target exceeds the alive count nothing changes
    return keep


def kappa_step(state: KappaState, logits, tokens, log_q, cfg: KappaConfig
               ) -> KappaState:
    """Full per-decode-step controller update. Jittable; cfg static.

    logits: (N, V) next-token logits of every branch (dead branches may
    contain garbage — they are masked). tokens: (N,) the tokens just
    sampled. log_q: (V,) unconditional reference log-probs.
    """
    state = _update_divergence(state, tokens)
    sigs = signals.compute_signals(logits, log_q)

    # --- finite-guard: a branch whose logits went NaN/Inf (device fault,
    # injected or real) must not poison its siblings. Its signals are
    # zeroed BEFORE any reduction (masked_zscore sums x*mask, and
    # NaN * 0 = NaN — masking alone is not enough), it is dropped from
    # the z-score population, and it is killed below. All three moves
    # are bitwise no-ops when every branch is finite.
    finite_ok = jnp.all(jnp.isfinite(logits), axis=-1)
    kl_s, conf_s, ent_s = sigs
    sigs = (jnp.where(finite_ok, kl_s, 0.0),
            jnp.where(finite_ok, conf_s, 0.0),
            jnp.where(finite_ok, ent_s, 0.0))

    # --- draft→gating transition (adaptive cutoff à la ST-BoN)
    if cfg.adaptive_cutoff:
        hit = _all_pairwise_diverged(state) | (state.step >= cfg.max_cutoff)
    else:
        hit = state.step >= cfg.draft_cutoff
    enter = (~state.in_gating) & hit
    cutoff = jnp.where(enter, state.step, state.cutoff)
    in_gating = state.in_gating | hit

    # --- adaptive horizon (paper §5 future work): at gating entry, scale
    # τ by the alive branches' mean normalized entropy — flat next-token
    # distributions (hard problems) earn a longer gating phase
    horizon_dyn = state.horizon_dyn
    if cfg.adaptive_horizon:
        _, _, ent = sigs
        aw = (state.alive & finite_ok).astype(jnp.float32)
        h_mean = jnp.sum(ent * aw) / jnp.maximum(jnp.sum(aw), 1.0)
        h_norm = jnp.clip(h_mean / jnp.log(jnp.float32(logits.shape[-1])), 0.0, 1.0)
        tau = jnp.round(cfg.horizon * (1.0 + cfg.horizon_beta * (2.0 * h_norm - 1.0)))
        tau = jnp.clip(tau, max(2, cfg.horizon // 2), cfg.horizon * 2).astype(jnp.int32)
        horizon_dyn = jnp.where(enter, tau, state.horizon_dyn)
    state = state._replace(cutoff=cutoff, in_gating=in_gating,
                           horizon_dyn=horizon_dyn)

    # --- gating-phase scoring + pruning (masked when not in gating)
    scored, traj = _score_update(state, sigs, cfg,
                                 mask=state.alive & finite_ok)
    gate_rel = jnp.clip(state.step - cutoff, 0, horizon_dyn)
    r_target = schedule.survivors(cfg.schedule, cfg.num_branches,
                                  gate_rel, horizon_dyn)
    active_gate = in_gating & (gate_rel < horizon_dyn) & (jnp.sum(state.alive) > 1)
    new_alive = _prune(state.alive, traj, r_target)

    out = jax.tree.map(
        lambda a, b: jnp.where(in_gating, a, b), scored, state)
    alive = jnp.where(active_gate, new_alive, state.alive)
    # finite-guard kill: a poisoned branch dies in every phase (draft
    # included) — unless EVERY alive branch is poisoned, in which case
    # leaving the mask untouched keeps the state machine well-formed
    # (the serving scheduler detects that case and replays the request).
    guarded = alive & finite_ok
    alive = jnp.where(jnp.any(guarded), guarded, alive)
    return out._replace(alive=alive, step=state.step + 1,
                        cutoff=cutoff, in_gating=in_gating,
                        diverged=state.diverged, horizon_dyn=horizon_dyn)


def survivor_index(state: KappaState) -> jnp.ndarray:
    """Unique survivor (ties: larger trajectory score, then lower index)."""
    masked = jnp.where(state.alive, state.traj, -3.4e38)
    return jnp.argmax(masked)


def num_alive(state: KappaState) -> jnp.ndarray:
    return jnp.sum(state.alive.astype(jnp.int32))


# ------------------------------------------------------- pooled controller
#
# A multi-request scheduler runs MANY kappa controllers at once. Stepping
# them one jit dispatch (plus one host sync) per request per tick makes
# the controller the serving bottleneck, so the pooled form stacks every
# request's KappaState along a leading slot axis — per-request scalars
# (step, cutoff, in_gating, di_count, di_ptr, ema_steps, traj_den,
# horizon_dyn) become (S,) vectors — and one vmapped kappa_step advances
# all of them in a single dispatch (see serving.strategies
# PooledKappaController and DESIGN.md §4).
#
# Row masking instead of physical compaction: a slot always keeps
# cfg.num_branches rows. Requests admitted with fewer rows, and rows
# dropped by bucketed compaction, are represented by alive=False (their
# diverged pairs forced True at init). That is EXACTLY equivalent to the
# gathered row-subset state kappa_step otherwise runs on: dead rows
# contribute 0.0 terms to the masked z-score sums (adding 0.0 is exact
# in fp), rank below every alive row in _prune (traj masked to -3.4e38,
# stable argsort preserves alive rows' relative order), and compaction
# only ever drops dead rows after gating entry, when the divergence
# matrix no longer influences anything (in_gating is sticky). Hence the
# pooled controller is bitwise identical per request to the sequential
# one — the property the scheduler's token-for-token guarantee rests on.


def _fresh_masked_state(cfg: KappaConfig, n) -> KappaState:
    """Fresh full-fan-out state whose rows ≥ ``n`` (traced int32) are
    padding: dead from the start, pairwise-diverged so adaptive-cutoff
    checks read exactly as they would on an n-row state."""
    nb = cfg.num_branches
    valid = jnp.arange(nb) < n
    pad = ~valid
    base = init_state(cfg)
    return base._replace(
        alive=valid,
        diverged=base.diverged | pad[:, None] | pad[None, :])


def init_pool(cfg: KappaConfig, slots: int) -> KappaState:
    """Stacked controller state for ``slots`` concurrent requests: every
    leaf of init_state gains a leading (slots,) axis."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (slots,) + x.shape),
        init_state(cfg))


def init_pool_rows(cfg: KappaConfig, row_n) -> KappaState:
    """Per-slot fresh states with per-slot row counts. row_n: (S,) int32
    live-row count of each slot (≤ cfg.num_branches); the remaining rows
    are masked padding. Jittable — used to reset re-acquired slots inside
    the fused tick dispatch."""
    return jax.vmap(lambda n: _fresh_masked_state(cfg, n))(row_n)


def pooled_step(state: KappaState, logits, tokens, log_q,
                cfg: KappaConfig) -> KappaState:
    """kappa_step vmapped over the slot axis. state: init_pool-shaped;
    logits: (S, N, V); tokens: (S, N); log_q: (V,) shared (all requests
    condition on the same BOS-only reference)."""
    return jax.vmap(
        lambda s, l, t: kappa_step(s, l, t, log_q, cfg))(state, logits, tokens)


def compact_state(state: KappaState, idx) -> KappaState:
    """Gather branch rows for bucketed compaction. idx: (M,) int32 of
    surviving branch indices (M ≤ N)."""
    m = idx.shape[0]
    return KappaState(
        alive=state.alive[idx],
        prev_kl=state.prev_kl[idx],
        di_buf=state.di_buf[idx],
        di_count=state.di_count,
        di_ptr=state.di_ptr,
        ema_raw=state.ema_raw[idx],
        ema_steps=state.ema_steps,
        traj_num=state.traj_num[idx],
        traj_den=state.traj_den,
        traj=state.traj[idx],
        step=state.step,
        cutoff=state.cutoff,
        in_gating=state.in_gating,
        diverged=state.diverged[idx][:, idx],
        horizon_dyn=state.horizon_dyn,
    )
