from repro.core.kappa import (
    KappaState,
    compact_state,
    init_pool,
    init_pool_rows,
    init_state,
    kappa_step,
    num_alive,
    pooled_step,
    survivor_index,
)
from repro.core.signals import compute_signals, reference_log_q

__all__ = ["KappaState", "init_state", "kappa_step", "survivor_index",
           "num_alive", "compact_state", "init_pool", "init_pool_rows",
           "pooled_step", "compute_signals", "reference_log_q"]
