from repro.core.kappa import (
    KappaState,
    compact_state,
    init_state,
    kappa_step,
    num_alive,
    survivor_index,
)
from repro.core.signals import compute_signals, reference_log_q

__all__ = ["KappaState", "init_state", "kappa_step", "survivor_index",
           "num_alive", "compact_state", "compute_signals", "reference_log_q"]
