"""Latent informativeness signals (paper §3, Alg. 2 lines 13–18).

All three signals come from the branch's own next-token distribution:
  D_t  = D_KL(p_t ‖ q)      — divergence from the unconditional reference
  C_t  = max_v p_t(v)       — confidence
  H_t  = −Σ p log(p + ε)    — entropy

``compute_signals`` is the single fusion point: the pure-jnp path below
is the oracle; kernels/fused_score provides the Pallas TPU kernel that
computes all three in one VMEM pass over the vocabulary.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-9


def log_softmax(logits):
    return jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)


def reference_log_q(ref_logits):
    """Unconditional reference distribution q from the BOS-only forward
    pass (Alg. 2 line 9). ref_logits: (V,) or (1, V)."""
    return log_softmax(ref_logits).reshape(-1)


def kl_to_reference(log_p, log_q):
    """D_KL(p ‖ q) = Σ p (log p − log q). log_p: (..., V); log_q: (V,)."""
    p = jnp.exp(log_p)
    return jnp.sum(p * (log_p - log_q), axis=-1)


def confidence(log_p):
    return jnp.exp(jnp.max(log_p, axis=-1))


def entropy(log_p):
    p = jnp.exp(log_p)
    return -jnp.sum(p * jnp.log(p + EPS), axis=-1)


def compute_signals(logits, log_q, *, use_pallas: bool = False):
    """logits: (..., V) fp32/bf16 — typically (N, V) per-request, or the
    pooled controller's (S, N, V) request-slot stack (all reductions are
    over the last axis, so leading axes batch independently and a batched
    call is row-wise identical to per-row calls); log_q: (V,) fp32,
    broadcast against the leading axes. Returns (kl, conf, ent), each
    logits.shape[:-1] fp32. The Pallas kernel path is (N, V)-only."""
    if use_pallas:
        from repro.kernels.fused_score.ops import fused_score
        return fused_score(logits, log_q)
    log_p = log_softmax(logits)
    return (kl_to_reference(log_p, log_q),
            confidence(log_p),
            entropy(log_p))
