"""Cross-branch normalization and score aggregation (Alg. 2 lines 19–21)."""
from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-9


def masked_zscore(x, alive, clip: float = 3.0):
    """z-score x across *alive* branches only, clamp to ±clip.
    x: (..., N), alive: (..., N) bool — the branch axis is last, leading
    axes (e.g. the pooled controller's request-slot axis) batch
    independently. Dead entries are returned as 0 and contribute exact
    0.0 terms to the sums, so a masked call is bitwise identical to the
    same call on only the alive rows."""
    aw = alive.astype(jnp.float32)
    n = jnp.maximum(jnp.sum(aw, axis=-1, keepdims=True), 1.0)
    mu = jnp.sum(x * aw, axis=-1, keepdims=True) / n
    var = jnp.sum(jnp.square(x - mu) * aw, axis=-1, keepdims=True) / n
    z = (x - mu) / (jnp.sqrt(var) + EPS)
    return jnp.clip(z, -clip, clip) * aw


def aggregate(z_ema, z_conf, z_ent, w_kl: float, w_conf: float, w_ent: float):
    """Instantaneous score s_t (Alg. 2 line 20)."""
    return w_kl * z_ema + w_conf * z_conf + w_ent * z_ent


def trajectory_update(num, den, s, t_abs):
    """Running recency-weighted trajectory score S_t = Σ t′·s_{t′} / Σ t′
    (Alg. 2 line 21, ω_{t′,t} ∝ t′). Returns (num, den, S)."""
    w = jnp.maximum(t_abs.astype(jnp.float32), 1.0)
    num = num + w * s
    den = den + w
    return num, den, num / jnp.maximum(den, EPS)
