"""Pruning schedules: target survivor count R_t over the gating horizon.

Paper (Alg. 2 line 24): linear — R_t = N − ⌊(t−c+1)·N/τ⌋, clipped to ≥1,
reaching exactly 1 at the end of the horizon. The cosine schedule is the
paper's own suggested less-aggressive extension (§4.2 / §5).
"""
from __future__ import annotations

import jax.numpy as jnp


def linear_survivors(n: int, step_in_horizon, horizon: int):
    """step_in_horizon: 0-based (t − c). Returns R ∈ [1, N]."""
    u = step_in_horizon + 1
    r = n - (u * n) // horizon
    return jnp.clip(r, 1, n)


def cosine_survivors(n: int, step_in_horizon, horizon: int):
    """Cosine: slow early pruning, steep at the end; R_τ = 1."""
    u = (step_in_horizon + 1).astype(jnp.float32) / horizon
    r = jnp.ceil(1.0 + (n - 1) * jnp.cos(jnp.pi / 2.0 * jnp.clip(u, 0.0, 1.0)))
    return jnp.clip(r.astype(jnp.int32), 1, n)


def step_survivors(n: int, step_in_horizon, horizon: int, n_stages: int = 4):
    """Piecewise-constant halving schedule (beyond-paper ablation)."""
    u = (step_in_horizon + 1).astype(jnp.float32) / horizon
    stage = jnp.floor(u * n_stages)
    r = jnp.floor(n * (0.5 ** stage))
    last = (step_in_horizon + 1) >= horizon
    r = jnp.where(last, 1, jnp.clip(r.astype(jnp.int32), 1, n))
    return r


def survivors(kind: str, n: int, step_in_horizon, horizon: int):
    if kind == "linear":
        return linear_survivors(n, step_in_horizon, horizon)
    if kind == "cosine":
        return cosine_survivors(n, step_in_horizon, horizon)
    if kind == "step":
        return step_survivors(n, step_in_horizon, horizon)
    raise ValueError(f"unknown schedule {kind!r}")
