"""Robustification of the ΔI signal (paper Alg. 2 lines 15–17):
median-of-means over a ring-buffered window, then bias-corrected EMA.
"""
from __future__ import annotations

import jax.numpy as jnp


def median_of_means(window, count, m: int):
    """MoM over the last ``count`` valid entries of ``window``.

    window: (..., w) — ring-ordered values, only the first ``count``
    (chronologically) are valid; invalid entries may be anything.
    count: scalar int. m: static bucket count.

    Splits the w slots into m equal buckets; bucket means are computed
    over valid entries only (empty buckets are excluded from the median
    by replicating the global mean of valid entries).
    """
    w = window.shape[-1]
    assert w % m == 0, "window must divide evenly into MoM buckets"
    per = w // m
    idx = jnp.arange(w)
    valid = (idx < count).astype(jnp.float32)            # (w,)
    vw = window * valid
    bucket_sum = vw.reshape(*window.shape[:-1], m, per).sum(-1)
    bucket_n = valid.reshape(m, per).sum(-1)             # (m,)
    total_mean = vw.sum(-1) / jnp.maximum(valid.sum(), 1.0)
    bucket_mean = jnp.where(bucket_n > 0,
                            bucket_sum / jnp.maximum(bucket_n, 1.0),
                            total_mean[..., None])
    return jnp.median(bucket_mean, axis=-1)


def ema_update(ema_raw, x, alpha: float):
    """One uncorrected EMA step: m_t = α·x + (1−α)·m_{t−1}."""
    return alpha * x + (1.0 - alpha) * ema_raw


def ema_debias(ema_raw, step, alpha: float):
    """Bias-corrected read: m̂_t = m_t / (1 − (1−α)^t), t ≥ 1 (Adam-style;
    the paper's Alg. 2 line 17 written as a recursion on the corrected
    value is numerically equivalent at read time)."""
    corr = 1.0 - (1.0 - alpha) ** jnp.maximum(step, 1)
    return ema_raw / corr
