"""Rotary position embeddings (paired-halves layout, LLaMA/Qwen style)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., S, 1, hd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
