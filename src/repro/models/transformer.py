"""Model assembly: heterogeneous layer stacks via cycle-scan.

The layer pattern (e.g. gemma3's 5×local+1×global, recurrentgemma's
rec/rec/local) repeats K = L // len(pattern) times with R = L % len(pattern)
remainder layers. Parameters and caches are **stacked over the K cycles**
(one stacked pytree per pattern position) and the stack is applied with a
single ``lax.scan`` — compile time and HLO size stay flat in depth
(80-layer internvl2 lowers as fast as 12-layer whisper), which also keeps
the roofline HLO readable.

Public API (cfg is static / hashable):
    init_params(rng, cfg)                         -> params pytree
    train_logits(params, cfg, tokens, frontend)   -> (logits, aux_loss)
    init_cache(cfg, batch, max_seq)               -> cache pytree
    prefill(params, cfg, tokens, cache, frontend) -> (last_logits, cache)
    decode_step(params, cfg, token, pos, cache)   -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mlp as mlp_lib
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import rwkv6 as rwkv6_lib
from repro.models.layers import dense_init, embed, rms_norm, sinusoidal_positions, unembed


# ------------------------------------------------------------------ init

def _init_block(rng, cfg: ModelConfig, block_type: str, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(rng, 4)
    p = {"ln1": jnp.zeros((d,), jnp.float32), "ln2": jnp.zeros((d,), jnp.float32)}
    if block_type in ("global", "local"):
        p["attn"] = attn.init_attn(ks[0], d, cfg.num_heads, cfg.num_kv_heads,
                                   hd, cfg.qkv_bias, dtype)
    elif block_type == "recurrent":
        p["rec"] = rglru_lib.init_rglru(ks[0], d, dtype)
    elif block_type == "rwkv6":
        p["mix"] = rwkv6_lib.init_rwkv6(ks[0], d, cfg.d_ff, cfg.num_heads, hd, dtype)
        return p  # rwkv6 block carries its own channel-mix FFN
    else:
        raise ValueError(block_type)
    if cfg.is_moe:
        p["ffn"] = moe_lib.init_moe(ks[1], d, cfg.d_ff, cfg.num_experts, dtype)
    else:
        p["ffn"] = mlp_lib.init_swiglu(ks[1], d, cfg.d_ff, dtype)
    if cfg.is_encoder_decoder:
        p["lnx"] = jnp.zeros((d,), jnp.float32)
        p["xattn"] = attn.init_cross_attn(ks[2], d, cfg.num_heads,
                                          cfg.num_kv_heads, hd, dtype)
    return p


def _init_encoder_block(rng, cfg: ModelConfig, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(rng, 2)
    return {
        "ln1": jnp.zeros((d,), jnp.float32),
        "ln2": jnp.zeros((d,), jnp.float32),
        "attn": attn.init_attn(ks[0], d, cfg.num_heads, cfg.num_heads, hd, False, dtype),
        "ffn": mlp_lib.init_gelu_mlp(ks[1], d, cfg.d_ff, dtype),
    }


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(rng, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    pattern = cfg.layer_pattern
    P = len(pattern)
    K, R = cfg.num_layers // P, cfg.num_layers % P
    keys = jax.random.split(rng, cfg.num_layers + cfg.encoder_layers + 3)

    params = {
        "embed": dense_init(keys[0], (cfg.vocab_size, cfg.d_model),
                            scale=0.02, dtype=dtype),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(keys[1], (cfg.vocab_size, cfg.d_model),
                                       scale=0.02, dtype=dtype)

    blocks = [_init_block(keys[2 + i], cfg, pattern[i % P], dtype)
              for i in range(cfg.num_layers)]
    if K > 0:
        params["stack"] = tuple(_stack(blocks[j::P][:K]) for j in range(P))
    else:
        params["stack"] = ()
    params["rem"] = tuple(blocks[K * P:])

    if cfg.is_encoder_decoder:
        ekeys = keys[2 + cfg.num_layers:]
        enc_blocks = [_init_encoder_block(ekeys[i], cfg, dtype)
                      for i in range(cfg.encoder_layers)]
        params["encoder"] = _stack(enc_blocks)
        params["enc_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return params


# ------------------------------------------------------------------ blocks

# Megatron-style sequence parallelism (§Perf C): the launcher installs the
# data-parallel axis names; blocks then constrain the residual stream to
# (batch=dp, seq="model") so GSPMD lowers the TP partial-sums as
# reduce-scatter + all-gather instead of full all-reduces.
_SP_DP_AXES = None


def set_sequence_parallel_axes(dp_axes) -> None:
    global _SP_DP_AXES
    _SP_DP_AXES = tuple(dp_axes) if dp_axes else None


def _sp_constrain(x, cfg: ModelConfig):
    if not cfg.seq_parallel or _SP_DP_AXES is None or x.ndim != 3:
        return x
    from jax.sharding import PartitionSpec as P
    try:
        return jax.lax.with_sharding_constraint(
            x, P(_SP_DP_AXES, "model", None))
    except Exception:
        return x


def _sp_gather(x, cfg: ModelConfig):
    """§Perf C it.3 — REFUTED, kept for the record: forcing the classic
    Megatron AG(x)→matmul→RS dataflow regressed collectives 0.43s→1.24s on
    gemma3 prefill. With few batch rows per chip (2×32k×2560 ≈ 335 MB vs
    3 FFN weight shards ≈ 157 MB/layer), GSPMD's weight-gather choice is
    the cheaper side of the trade — the textbook SP dataflow assumes
    activations ≪ weights, which long-context prefill inverts."""
    if not cfg.seq_parallel or _SP_DP_AXES is None or x.ndim != 3:
        return x
    from jax.sharding import PartitionSpec as P
    try:
        return jax.lax.with_sharding_constraint(x, P(_SP_DP_AXES, None, None))
    except Exception:
        return x


def _use_halo(cfg: ModelConfig, seq_len: int) -> bool:
    """Halo-exchange local attention (§Perf C it.2): seq-sharded sliding
    window with a neighbour halo instead of a full-sequence all-gather."""
    if not cfg.seq_parallel or attn._HALO_MESH is None:
        return False
    m = attn._HALO_MESH.shape.get("model", 1)
    return attn.halo_attn_available(seq_len, cfg.window_size, m)


def _window_of(cfg: ModelConfig, bt: str) -> int:
    return cfg.window_size if bt == "local" else 0


def _ffn_apply(p, cfg: ModelConfig, x):
    if cfg.is_moe:
        if cfg.moe_impl == "expert_parallel":
            seq_ok = (cfg.seq_parallel and attn._HALO_MESH is not None
                      and x.shape[1] % attn._HALO_MESH.shape.get("model", 1) == 0)
            return moe_lib.moe_ffn_expert_parallel(
                p["ffn"], x, num_experts=cfg.num_experts,
                experts_per_tok=cfg.experts_per_tok,
                capacity_factor=max(cfg.moe_capacity_factor, 1.25),
                seq_sharded=seq_ok)
        return moe_lib.moe_ffn(p["ffn"], x, num_experts=cfg.num_experts,
                               experts_per_tok=cfg.experts_per_tok,
                               capacity_factor=cfg.moe_capacity_factor)
    return mlp_lib.swiglu(p["ffn"], x), jnp.float32(0.0)


def _block_forward(p, cfg: ModelConfig, bt: str, x, positions, enc_kv=None):
    """Full-sequence (train) block, no cache. Returns (x, aux)."""
    aux = jnp.float32(0.0)
    x = _sp_constrain(x, cfg)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if bt in ("global", "local"):
        if bt == "local" and _use_halo(cfg, x.shape[1]):
            y = attn.attn_forward_halo(
                p["attn"], h, num_heads=cfg.num_heads,
                num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
                window=cfg.window_size, rope_theta=cfg.rope_theta,
                use_rope=cfg.use_rope)
        else:
            y = attn.attn_forward(p["attn"], h, positions,
                                  num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                                  head_dim=cfg.resolved_head_dim,
                                  window=_window_of(cfg, bt),
                                  rope_theta=cfg.rope_theta, use_rope=cfg.use_rope)
        x = x + y
    elif bt == "recurrent":
        y, _ = rglru_lib.rglru_forward(p["rec"], h)
        x = x + y
    elif bt == "rwkv6":
        st = rwkv6_lib.init_rwkv6_state(x.shape[0], cfg.d_model, cfg.num_heads,
                                        cfg.resolved_head_dim, x.dtype)
        y, _ = rwkv6_lib.time_mix(p["mix"], h, st, num_heads=cfg.num_heads,
                                  head_dim=cfg.resolved_head_dim)
        x = x + y
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        y2, _ = rwkv6_lib.channel_mix(p["mix"], h2, st)
        return x + y2, aux
    if cfg.is_encoder_decoder and enc_kv is not None:
        hx = rms_norm(x, p["lnx"], cfg.norm_eps)
        x = x + attn.cross_attn(p["xattn"], hx, enc_kv[0], enc_kv[1],
                                num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                                head_dim=cfg.resolved_head_dim)
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    y2, aux = _ffn_apply(p, cfg, h2)
    return x + y2, aux


def _block_prefill(p, cfg: ModelConfig, bt: str, x, positions, cache, enc_kv=None):
    aux = jnp.float32(0.0)
    x = _sp_constrain(x, cfg)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if bt in ("global", "local"):
        if bt == "local" and _use_halo(cfg, x.shape[1]):
            y, k, v = attn.attn_forward_halo(
                p["attn"], h, num_heads=cfg.num_heads,
                num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
                window=cfg.window_size, rope_theta=cfg.rope_theta,
                use_rope=cfg.use_rope, return_kv=True)
            new_cache = attn.write_ring_from_kv(cache, k, v, positions)
        else:
            y, new_cache = attn.attn_prefill(
                p["attn"], h, positions, cache,
                num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, window=_window_of(cfg, bt),
                rope_theta=cfg.rope_theta, use_rope=cfg.use_rope)
        x = x + y
    elif bt == "recurrent":
        y, new_cache = rglru_lib.rglru_forward(p["rec"], h, cache)
        x = x + y
    elif bt == "rwkv6":
        y, tm = rwkv6_lib.time_mix(p["mix"], h, cache, num_heads=cfg.num_heads,
                                   head_dim=cfg.resolved_head_dim)
        x = x + y
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        y2, cm = rwkv6_lib.channel_mix(p["mix"], h2, cache)
        new_cache = {**tm, **cm}
        return x + y2, new_cache, aux
    if cfg.is_encoder_decoder and enc_kv is not None:
        hx = rms_norm(x, p["lnx"], cfg.norm_eps)
        x = x + attn.cross_attn(p["xattn"], hx, enc_kv[0], enc_kv[1],
                                num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                                head_dim=cfg.resolved_head_dim)
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    y2, aux = _ffn_apply(p, cfg, h2)
    return x + y2, new_cache, aux


def _block_decode(p, cfg: ModelConfig, bt: str, x, pos, cache, enc_kv=None,
                  block_tables=None, write_pages=None):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if bt == "global" and block_tables is not None:
        y, new_cache = attn.attn_decode_paged(
            p["attn"], h, pos, cache, block_tables, write_pages,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim,
            rope_theta=cfg.rope_theta, use_rope=cfg.use_rope)
        x = x + y
    elif bt in ("global", "local"):
        y, new_cache = attn.attn_decode(
            p["attn"], h, pos, cache,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim, window=_window_of(cfg, bt),
            rope_theta=cfg.rope_theta, use_rope=cfg.use_rope)
        x = x + y
    elif bt == "recurrent":
        y, new_cache = rglru_lib.rglru_step(p["rec"], h, cache)
        x = x + y
    elif bt == "rwkv6":
        y, tm = rwkv6_lib.time_mix_step(p["mix"], h, cache, num_heads=cfg.num_heads,
                                        head_dim=cfg.resolved_head_dim)
        x = x + y
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        y2, cm = rwkv6_lib.channel_mix_step(p["mix"], h2, cache)
        return x + y2, {**tm, **cm}
    if cfg.is_encoder_decoder and enc_kv is not None:
        hx = rms_norm(x, p["lnx"], cfg.norm_eps)
        x = x + attn.cross_attn(p["xattn"], hx, enc_kv[0], enc_kv[1],
                                num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                                head_dim=cfg.resolved_head_dim)
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    y2, _ = _ffn_apply(p, cfg, h2)
    return x + y2, new_cache



def _scan_maybe(fn, carry, xs, unroll: bool):
    """lax.scan, or an unrolled Python loop when cfg.unroll is set (the
    dry-run uses unrolled stacks so cost_analysis sees every layer)."""
    if not unroll:
        return jax.lax.scan(fn, carry, xs)
    K = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(K):
        sl = jax.tree.map(lambda a: a[i], xs)
        carry, y = fn(carry, sl)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


# ------------------------------------------------------------------ encoder

def run_encoder(params, cfg: ModelConfig, frames):
    """Whisper-style bidirectional encoder over stub frame embeddings."""
    S = frames.shape[1]
    x = frames + sinusoidal_positions(S, cfg.d_model).astype(frames.dtype)
    positions = jnp.arange(S)

    def body(x, blk):
        h = rms_norm(x, blk["ln1"], cfg.norm_eps)
        # bidirectional: reuse attn_forward with an all-true mask via window=0
        # and positions trick — simplest is direct call with no causal mask:
        y = _encoder_attn(blk["attn"], h, cfg)
        x = x + y
        h2 = rms_norm(x, blk["ln2"], cfg.norm_eps)
        x = x + mlp_lib.gelu_mlp(blk["ffn"], h2)
        return x, None

    x, _ = _scan_maybe(body, x, params["encoder"], cfg.unroll)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _encoder_attn(p, x, cfg: ModelConfig):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H = cfg.num_heads
    q = (x @ p["wq"]).reshape(B, S, H, 1, hd)
    k = (x @ p["wk"]).reshape(B, S, H, hd)
    v = (x @ p["wv"]).reshape(B, S, H, hd)
    mask = jnp.ones((1, 1, 1, 1, S), bool)
    out = attn._attend(q.reshape(B, S, H, 1, hd), k, v, mask)
    return out.reshape(B, S, H * hd) @ p["wo"]


# ------------------------------------------------------------------ public

def _apply_stack(params, cfg: ModelConfig, x, fn_cycle, fn_rem):
    """Run the cycle-scan + remainder. fn_cycle(x, stacked_slices)->(x, ys),
    fn_rem(x, rem_params, idx)->x."""
    pattern = cfg.layer_pattern
    P = len(pattern)
    K = cfg.num_layers // P
    ys = None
    if K > 0:
        x, ys = _scan_maybe(fn_cycle, x, params["stack"], cfg.unroll)
    for j, bp in enumerate(params["rem"]):
        x = fn_rem(x, bp, j)
    return x, ys


def _logits(params, cfg: ModelConfig, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return unembed(x, table)


def _embed_in(params, cfg: ModelConfig, tokens, positions=None):
    x = embed(tokens, params["embed"])
    if not cfg.use_rope and not cfg.is_encoder_decoder:
        pass  # rwkv6: no positional signal needed
    if cfg.is_encoder_decoder:
        S = tokens.shape[1]
        start = 0 if positions is None else positions
        x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)
    return x


def train_logits(params, cfg: ModelConfig, tokens, frontend=None):
    """Teacher-forced full-sequence logits. tokens: (B, S) int32.
    frontend: stub embeddings (B, F, d) for vlm/audio archs.
    Returns (logits (B, S_text, V), aux_loss)."""
    B, S = tokens.shape
    pattern = cfg.layer_pattern
    enc_kv = None
    x = embed(tokens, params["embed"])
    n_prefix = 0

    if cfg.is_encoder_decoder:
        assert frontend is not None, "enc-dec arch needs frontend frames"
        enc_out = run_encoder(params, cfg, frontend)
        x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)
    elif cfg.frontend is not None and frontend is not None:
        # VLM: prepend patch embeddings to the token stream
        x = jnp.concatenate([frontend.astype(x.dtype), x], axis=1)
        n_prefix = frontend.shape[1]

    Sx = x.shape[1]
    positions = jnp.arange(Sx)
    aux_total = jnp.float32(0.0)

    if cfg.is_encoder_decoder:
        # precompute per-layer cross K/V lazily inside each block instead:
        # simplest faithful version recomputes K,V from enc_out per layer.
        def fn_cycle(x, slices):
            aux_c = jnp.float32(0.0)
            for j, bt in enumerate(pattern):
                ekv = attn.cross_attn_kv(slices[j]["xattn"], enc_out,
                                         cfg.num_kv_heads, cfg.resolved_head_dim)
                x, aux = _block_forward(slices[j], cfg, bt, x, positions, ekv)
                aux_c += aux
            return x, aux_c

        def fn_rem(x, bp, j):
            nonlocal aux_total
            bt = pattern[(cfg.num_layers // len(pattern)) * len(pattern) + j] \
                if False else pattern[j % len(pattern)]
            ekv = attn.cross_attn_kv(bp["xattn"], enc_out,
                                     cfg.num_kv_heads, cfg.resolved_head_dim)
            x, aux = _block_forward(bp, cfg, bt, x, positions, ekv)
            aux_total += aux
            return x
    else:
        def fn_cycle(x, slices):
            aux_c = jnp.float32(0.0)
            for j, bt in enumerate(pattern):
                x, aux = _block_forward(slices[j], cfg, bt, x, positions)
                aux_c += aux
            return x, aux_c

        def fn_rem(x, bp, j):
            nonlocal aux_total
            x, aux = _block_forward(bp, cfg, pattern[j % len(pattern)], x, positions)
            aux_total += aux
            return x

    x, ys = _apply_stack(params, cfg, x, fn_cycle, fn_rem)
    if ys is not None:
        aux_total = aux_total + jnp.sum(ys)
    if n_prefix:
        x = x[:, n_prefix:]
    return _logits(params, cfg, x), aux_total


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    """Cache pytree matching the stacked-params layout."""
    dtype = jnp.dtype(cfg.dtype)
    pattern = cfg.layer_pattern
    P = len(pattern)
    K, R = cfg.num_layers // P, cfg.num_layers % P
    hd = cfg.resolved_head_dim

    quant = cfg.kv_cache_dtype == "int8"

    def one(bt):
        if bt == "global":
            return attn.init_full_cache(batch, max_seq, cfg.num_kv_heads, hd,
                                        dtype, quantized=quant)
        if bt == "local":
            W = min(cfg.window_size, max_seq)
            return attn.init_ring_cache(batch, W, cfg.num_kv_heads, hd,
                                        dtype, quantized=quant)
        if bt == "recurrent":
            return rglru_lib.init_rglru_state(batch, cfg.d_model, dtype)
        if bt == "rwkv6":
            return rwkv6_lib.init_rwkv6_state(batch, cfg.d_model, cfg.num_heads, hd, dtype)
        raise ValueError(bt)

    def stacked(bt):
        c = one(bt)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (K,) + a.shape).copy(), c) \
            if K > 0 else c

    cache = {
        "stack": tuple(stacked(pattern[j]) for j in range(P)) if K > 0 else (),
        "rem": tuple(one(pattern[j % P]) for j in range(R)),
    }
    if cfg.is_encoder_decoder:
        # cross-attn K/V per decoder layer, filled at prefill
        xshape = (batch, cfg.encoder_seq_len, cfg.num_kv_heads, hd)
        cache["xkv_stack"] = tuple(
            {"k": jnp.zeros((K,) + xshape, dtype), "v": jnp.zeros((K,) + xshape, dtype)}
            for _ in range(P)) if K > 0 else ()
        cache["xkv_rem"] = tuple({"k": jnp.zeros(xshape, dtype),
                                  "v": jnp.zeros(xshape, dtype)} for _ in range(R))
    return cache


def init_paged_cache(cfg: ModelConfig, batch: int, num_pages: int,
                     page_size: int, max_seq: int):
    """Paged variant of :func:`init_cache` (DESIGN.md §5).

    Global-attention layers share a page pool — their leaves get shape
    (num_pages + 1, page_size, KV, hd), where physical page ``num_pages``
    is the shared *trash* page that unowned block-table entries alias.
    With ``cfg.kv_cache_dtype == "int8"`` the K/V leaves are int8 and
    per-token-head fp32 scale leaves ``k_s``/``v_s`` of shape
    (num_pages + 1, page_size, KV) ride alongside — page-granular, so
    they follow the same block table through COW copies, prefix sharing,
    and the Pallas kernels' scalar-prefetched index maps (DESIGN.md
    §13). Every other leaf family (sliding-window ring caches,
    recurrent / RWKV-6 state, cross-attention K/V) keeps its per-row
    layout: those states are O(window) or O(1) in sequence, so paging
    them would buy nothing. One block table therefore addresses every
    global layer — a logical page maps to the same physical index in
    each layer's pool."""
    dtype = jnp.dtype(cfg.dtype)
    pattern = cfg.layer_pattern
    P = len(pattern)
    K, R = cfg.num_layers // P, cfg.num_layers % P
    hd = cfg.resolved_head_dim
    quant = cfg.kv_cache_dtype == "int8"

    def one(bt):
        if bt == "global":
            return attn.init_paged_kv(num_pages + 1, page_size,
                                      cfg.num_kv_heads, hd, dtype,
                                      quantized=quant)
        if bt == "local":
            W = min(cfg.window_size, max_seq)
            return attn.init_ring_cache(batch, W, cfg.num_kv_heads, hd,
                                        dtype, quantized=quant)
        if bt == "recurrent":
            return rglru_lib.init_rglru_state(batch, cfg.d_model, dtype)
        if bt == "rwkv6":
            return rwkv6_lib.init_rwkv6_state(batch, cfg.d_model,
                                              cfg.num_heads, hd, dtype)
        raise ValueError(bt)

    def stacked(bt):
        c = one(bt)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (K,) + a.shape).copy(), c) \
            if K > 0 else c

    cache = {
        "stack": tuple(stacked(pattern[j]) for j in range(P)) if K > 0 else (),
        "rem": tuple(one(pattern[j % P]) for j in range(R)),
    }
    if cfg.is_encoder_decoder:
        xshape = (batch, cfg.encoder_seq_len, cfg.num_kv_heads, hd)
        cache["xkv_stack"] = tuple(
            {"k": jnp.zeros((K,) + xshape, dtype), "v": jnp.zeros((K,) + xshape, dtype)}
            for _ in range(P)) if K > 0 else ()
        cache["xkv_rem"] = tuple({"k": jnp.zeros(xshape, dtype),
                                  "v": jnp.zeros(xshape, dtype)} for _ in range(R))
    return cache


def prefill(params, cfg: ModelConfig, tokens, cache, frontend=None):
    """Process the prompt, fill the cache. tokens: (B, S_prompt).
    Returns (logits at last position (B, V), cache)."""
    B, S = tokens.shape
    pattern = cfg.layer_pattern
    P = len(pattern)
    x = embed(tokens, params["embed"])
    n_prefix = 0

    enc_out = None
    if cfg.is_encoder_decoder:
        assert frontend is not None
        enc_out = run_encoder(params, cfg, frontend)
        x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)
    elif cfg.frontend is not None and frontend is not None:
        x = jnp.concatenate([frontend.astype(x.dtype), x], axis=1)
        n_prefix = frontend.shape[1]

    positions = jnp.arange(x.shape[1])

    def fn_cycle(x, slices):
        pslices, cslices = slices
        newc = []
        xkv = []
        for j, bt in enumerate(pattern):
            ekv = None
            if cfg.is_encoder_decoder:
                ekv = attn.cross_attn_kv(pslices[j]["xattn"], enc_out,
                                         cfg.num_kv_heads, cfg.resolved_head_dim)
                xkv.append({"k": ekv[0], "v": ekv[1]})
            x, c, _ = _block_prefill(pslices[j], cfg, bt, x, positions, cslices[j], ekv)
            newc.append(c)
        return x, (tuple(newc), tuple(xkv))

    new_rem = []
    new_xkv_rem = []

    def fn_rem(x, bp_c, j):
        bp, c = bp_c
        bt = pattern[j % P]
        ekv = None
        if cfg.is_encoder_decoder:
            ekv = attn.cross_attn_kv(bp["xattn"], enc_out,
                                     cfg.num_kv_heads, cfg.resolved_head_dim)
            new_xkv_rem.append({"k": ekv[0], "v": ekv[1]})
        x, c2, _ = _block_prefill(bp, cfg, bt, x, positions, c, ekv)
        new_rem.append(c2)
        return x

    K = cfg.num_layers // P
    ys = None
    if K > 0:
        x, ys = _scan_maybe(fn_cycle, x, (params["stack"], cache["stack"]), cfg.unroll)
    for j, bp in enumerate(params["rem"]):
        x = fn_rem(x, (bp, cache["rem"][j]), j)

    new_cache = {
        "stack": ys[0] if ys is not None else (),
        "rem": tuple(new_rem),
    }
    if cfg.is_encoder_decoder:
        new_cache["xkv_stack"] = ys[1] if ys is not None else ()
        new_cache["xkv_rem"] = tuple(new_xkv_rem)
    logits = _logits(params, cfg, x[:, -1:])[:, 0]
    return logits, new_cache


def _block_prefill_chunk(p, cfg: ModelConfig, bt: str, x, pos0, centry,
                         aentry, *, hist_len: int, block_tables,
                         chunk_pages):
    """One block over a (B, C) prompt chunk. ``centry`` is the block's
    main-cache entry (contiguous cache, or the paged pool); ``aentry``
    is the batch-1 aux entry holding the per-row families' state in
    paged mode (None in contiguous mode, where ``centry`` holds it).
    Returns (x, new_centry, new_aentry)."""
    paged = block_tables is not None
    own = aentry if (paged and bt != "global") else centry
    new_c, new_a = centry, aentry
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if bt in ("global", "local"):
        if paged and bt == "global":
            y, new_c = attn.attn_prefill_chunk_paged(
                p["attn"], h, pos0, centry, block_tables, chunk_pages,
                num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                use_rope=cfg.use_rope)
        else:
            y, s_new = attn.attn_prefill_chunk(
                p["attn"], h, pos0, own, hist_len=hist_len,
                num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, window=_window_of(cfg, bt),
                rope_theta=cfg.rope_theta, use_rope=cfg.use_rope)
            if paged:
                new_a = s_new
            else:
                new_c = s_new
        x = x + y
    elif bt == "recurrent":
        y, s_new = rglru_lib.rglru_forward(p["rec"], h, own)
        x = x + y
        if paged:
            new_a = s_new
        else:
            new_c = s_new
    elif bt == "rwkv6":
        y, tm = rwkv6_lib.time_mix(p["mix"], h, own, num_heads=cfg.num_heads,
                                   head_dim=cfg.resolved_head_dim)
        x = x + y
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        y2, cm = rwkv6_lib.channel_mix(p["mix"], h2, own)
        s_new = {**tm, **cm}
        if paged:
            new_a = s_new
        else:
            new_c = s_new
        return x + y2, new_c, new_a
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    y2, _ = _ffn_apply(p, cfg, h2)
    return x + y2, new_c, new_a


def prefill_chunk(params, cfg: ModelConfig, tokens, pos0, hist_len: int,
                  cache, block_tables=None, chunk_pages=None, aux=None):
    """Advance the caches over one (B, C) prompt chunk whose first token
    sits at per-row absolute position ``pos0`` (DESIGN.md §6).

    Contiguous mode (``block_tables`` None): ``cache`` is a batch-B
    cache already holding each row's first ``pos0`` tokens; ``hist_len``
    is the static history slice length for full-attention layers
    (callers pass the exact filled length so the key sequence stays
    zero-gap — the bitwise-equality precondition).

    Paged mode: ``cache`` is the paged pool; global layers write the
    chunk's K/V straight into allocator-owned pages (``chunk_pages``,
    (B, C)) and attend through ``block_tables`` (B, MP); the per-row
    families (ring / recurrent / rwkv6) thread their state through the
    batch-1 ``aux`` cache, installed into the row slots when prefill
    completes. Because attention validity is purely positional
    (kv_pos <= q_pos through the block table), the FIRST chunk may start
    at a nonzero ``pos0``: the radix prefix cache (DESIGN.md §7) aliases
    already-written pages into the block table and resumes prefill at
    the cached extent — the earlier pages are attended, never recomputed.
    For all-'global' patterns this is bitwise-equal to prefilling from
    token 0; per-row aux families cannot be resumed this way, which is
    why the prefix cache requires an all-global pattern. Encoder-decoder
    and frontend-prefixed models are not supported (callers fall back to
    one-shot prefill).

    Returns (last-position logits (B, V), new_cache, new_aux)."""
    if cfg.is_encoder_decoder:
        raise ValueError("chunked prefill does not support encoder-decoder "
                         "models (use one-shot prefill)")
    paged = block_tables is not None
    pattern = cfg.layer_pattern
    P = len(pattern)
    x = embed(tokens, params["embed"])
    pos0 = jnp.asarray(pos0)

    def fn_cycle(x, slices):
        if paged:
            pslices, cslices, aslices = slices
        else:
            pslices, cslices = slices
            aslices = (None,) * P
        newc, newa = [], []
        for j, bt in enumerate(pattern):
            x, c, a = _block_prefill_chunk(
                pslices[j], cfg, bt, x, pos0, cslices[j], aslices[j],
                hist_len=hist_len, block_tables=block_tables,
                chunk_pages=chunk_pages)
            newc.append(c)
            newa.append(a)
        return x, (tuple(newc), tuple(newa)) if paged else tuple(newc)

    K = cfg.num_layers // P
    ys = None
    if K > 0:
        xs = (params["stack"], cache["stack"], aux["stack"]) if paged \
            else (params["stack"], cache["stack"])
        x, ys = _scan_maybe(fn_cycle, x, xs, cfg.unroll)

    new_rem, new_arem = [], []
    for j, bp in enumerate(params["rem"]):
        bt = pattern[j % P]
        aentry = aux["rem"][j] if paged else None
        x, c, a = _block_prefill_chunk(
            bp, cfg, bt, x, pos0, cache["rem"][j], aentry,
            hist_len=hist_len, block_tables=block_tables,
            chunk_pages=chunk_pages)
        new_rem.append(c)
        new_arem.append(a)

    if paged:
        new_cache = {"stack": ys[0] if ys is not None else (),
                     "rem": tuple(new_rem)}
        new_aux = {"stack": ys[1] if ys is not None else (),
                   "rem": tuple(new_arem)}
    else:
        new_cache = {"stack": ys if ys is not None else (),
                     "rem": tuple(new_rem)}
        new_aux = None
    logits = _logits(params, cfg, x[:, -1:])[:, 0]
    return logits, new_cache, new_aux


def decode_step(params, cfg: ModelConfig, token, pos, cache, block_tables=None,
                write_pages=None):
    """One decode step. token: (B,) int32; pos: scalar int32 (absolute
    position of this token) or (B,) int32 per-row positions (continuous
    batching: pool rows belong to different requests).

    ``block_tables`` ((B, MP) int32, optional) switches global-attention
    layers to the paged cache path: ``cache`` must then come from
    :func:`init_paged_cache` and ``pos`` must be per-row (DESIGN.md §5).
    ``write_pages`` ((B,) int32, optional) pins each row's K/V write to an
    allocator-certified refcount-1 page (the COW prefix-sharing guard);
    when omitted the write page is derived from the block table.
    Returns (logits (B, V), new_cache)."""
    pattern = cfg.layer_pattern
    P = len(pattern)
    x = embed(token[:, None], params["embed"])
    if cfg.is_encoder_decoder:
        half = cfg.d_model // 2
        freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / (half - 1))
        posf = jnp.asarray(pos)
        if posf.ndim == 0:
            ang = posf * freq
            pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])
            x = x + pe.astype(x.dtype)
        else:
            ang = posf[:, None].astype(jnp.float32) * freq
            pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
            x = x + pe[:, None, :].astype(x.dtype)

    def fn_cycle(x, slices):
        if cfg.is_encoder_decoder:
            pslices, cslices, xkvs = slices
        else:
            pslices, cslices = slices
            xkvs = None
        newc = []
        for j, bt in enumerate(pattern):
            ekv = (xkvs[j]["k"], xkvs[j]["v"]) if xkvs is not None else None
            x, c = _block_decode(pslices[j], cfg, bt, x, pos, cslices[j], ekv,
                                 block_tables, write_pages)
            newc.append(c)
        return x, tuple(newc)

    K = cfg.num_layers // P
    if K > 0:
        if cfg.is_encoder_decoder:
            x, new_stack = _scan_maybe(
                fn_cycle, x, (params["stack"], cache["stack"], cache["xkv_stack"]),
                cfg.unroll)
        else:
            x, new_stack = _scan_maybe(fn_cycle, x, (params["stack"], cache["stack"]), cfg.unroll)
    else:
        new_stack = ()

    new_rem = []
    for j, bp in enumerate(params["rem"]):
        bt = pattern[j % P]
        ekv = None
        if cfg.is_encoder_decoder:
            xkv = cache["xkv_rem"][j]
            ekv = (xkv["k"], xkv["v"])
        x, c2 = _block_decode(bp, cfg, bt, x, pos, cache["rem"][j], ekv,
                              block_tables, write_pages)
        new_rem.append(c2)

    new_cache = {"stack": new_stack, "rem": tuple(new_rem)}
    if cfg.is_encoder_decoder:
        new_cache["xkv_stack"] = cache["xkv_stack"]
        new_cache["xkv_rem"] = cache["xkv_rem"]
    logits = _logits(params, cfg, x)[:, 0]
    return logits, new_cache
