"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block structure (d_rnn = d_model here):
  x ─┬─ gate branch:  y_g = gelu(x @ w_gy)
     └─ rnn branch:   u = causal depthwise conv4(x @ w_gx)
                      i_t = σ(u @ w_i + b_i)   (input gate)
                      r_t = σ(u @ w_r + b_r)   (recurrence gate)
                      a_t = exp(-c · softplus(Λ) · r_t)
                      h_t = a_t ⊙ h_{t-1} + √(1−a_t²) ⊙ (i_t ⊙ u_t)
  y = (h ⊙ y_g) @ w_out

Training/prefill uses an associative scan (log-depth on TPU); decode is a
single fused step. State = {h: (B,d), conv: (B,3,d)}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

_C = 8.0  # Griffin's fixed recurrence sharpness constant
_CONV_W = 4


def init_rglru(rng, d_model: int, dtype):
    ks = jax.random.split(rng, 6)
    d = d_model
    return {
        "w_gx": dense_init(ks[0], (d, d), dtype=dtype),
        "w_gy": dense_init(ks[1], (d, d), dtype=dtype),
        "conv": dense_init(ks[2], (_CONV_W, d), scale=0.5, dtype=dtype),
        "w_i": dense_init(ks[3], (d, d), dtype=dtype),
        "w_r": dense_init(ks[4], (d, d), dtype=dtype),
        # Λ init so that a = exp(-c·softplus(Λ)·σ(·)) spans useful decays
        "lam": jnp.linspace(-4.0, 4.0, d).astype(jnp.float32),
        "w_out": dense_init(ks[5], (d, d), dtype=dtype),
    }


def _gates(p, u):
    i = jax.nn.sigmoid(u @ p["w_i"])
    r = jax.nn.sigmoid(u @ p["w_r"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - a * a, 0.0, 1.0)) * (i * u).astype(jnp.float32)
    return a, b


def rglru_forward(p, x, state=None):
    """Full-sequence forward. x: (B,S,d) → (y, final_state)."""
    B, S, d = x.shape
    u0 = x @ p["w_gx"]
    yg = jax.nn.gelu(x @ p["w_gy"])

    conv_hist = jnp.zeros((B, _CONV_W - 1, d), x.dtype) if state is None else state["conv"]
    u_pad = jnp.concatenate([conv_hist, u0], axis=1)          # (B, S+3, d)
    # causal depthwise conv, width 4
    u = sum(u_pad[:, i:i + S] * p["conv"][_CONV_W - 1 - i] for i in range(_CONV_W))

    a, b = _gates(p, u)                                        # fp32 (B,S,d)
    h0 = jnp.zeros((B, d), jnp.float32) if state is None else state["h"]
    # fold h0 into the first step, then associative linear-recurrence scan
    b = b.at[:, 0].add(a[:, 0] * h0)

    def comb(l, r):
        return (r[0] * l[0], r[0] * l[1] + r[1])

    _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    y = (h.astype(x.dtype) * yg) @ p["w_out"]
    new_state = {"h": h[:, -1], "conv": u0[:, -(_CONV_W - 1):]}
    return y, new_state


def rglru_step(p, x, state):
    """One-token decode. x: (B,1,d)."""
    B, _, d = x.shape
    u0 = x[:, 0] @ p["w_gx"]                                   # (B,d)
    yg = jax.nn.gelu(x[:, 0] @ p["w_gy"])
    hist = jnp.concatenate([state["conv"], u0[:, None]], axis=1)  # (B,4,d) oldest→newest
    # forward path weights position (t - j) with conv[j]: newest gets conv[0]
    u = jnp.einsum("bwd,wd->bd", hist, p["conv"][::-1])
    a, b = _gates(p, u[:, None])                               # (B,1,d)
    h = a[:, 0] * state["h"] + b[:, 0]
    y = (h.astype(x.dtype) * yg) @ p["w_out"]
    return y[:, None], {"h": h, "conv": hist[:, 1:]}


def init_rglru_state(batch: int, d_model: int, dtype):
    return {"h": jnp.zeros((batch, d_model), jnp.float32),
            "conv": jnp.zeros((batch, _CONV_W - 1, d_model), dtype)}
