"""Token-choice top-k Mixture-of-Experts FFN with sort-based dispatch.

Design (TPU-native, see DESIGN.md):
  * router: softmax over E experts, top-k per token
  * dispatch: argsort tokens by expert id, pack into an (E·C, d) buffer
    (capacity C per expert, GShard-style drop on overflow)
  * expert compute: batched SwiGLU einsum over the (E, C, d) buffer —
    FLOPs ∝ active params only (not E× dense), which keeps the roofline
    MODEL_FLOPS/HLO_FLOPs ratio honest for qwen3-moe's 128 experts
  * combine: scatter-add back, weighted by router probs
  * sharding: expert axis on "model" (expert parallelism); token→expert
    routing crosses the mesh as XLA-inserted all-to-alls under pjit
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_moe(rng, d_model: int, d_ff: int, num_experts: int, dtype):
    ks = jax.random.split(rng, 4)
    e = num_experts
    return {
        "router": dense_init(ks[0], (d_model, e), dtype=jnp.float32),
        "wg": dense_init(ks[1], (e, d_model, d_ff), dtype=dtype),
        "wu": dense_init(ks[2], (e, d_model, d_ff), dtype=dtype),
        "wd": dense_init(ks[3], (e, d_ff, d_model), dtype=dtype),
    }


def moe_ffn(p, x, *, num_experts: int, experts_per_tok: int,
            capacity_factor: float = 1.25):
    """x: (B, S, d) -> (B, S, d). Returns (y, aux) where aux carries the
    router load-balance loss term (Switch-style).

    ``capacity_factor <= 0`` selects dropless mode (C = T·K): exact
    token-choice routing, used by the smoke/parity tests where
    ``prefill+decode ≡ train`` must hold bit-for-bit per token."""
    B, S, d = x.shape
    E, K = num_experts, experts_per_tok
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                   # (T, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)   # renormalize

    # ---- load-balance aux loss (Switch Transformer eq. 4)
    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], E), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux_loss = jnp.sum(density * density_proxy) * E

    # ---- sort-based dispatch
    if capacity_factor <= 0:
        C = T * K  # dropless
    else:
        C = int(max(1, (T * K / E) * capacity_factor))
    flat_e = top_e.reshape(-1)                               # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T), K)                    # source token id
    flat_w = top_p.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # position within expert = rank among same-expert entries
    ar = jnp.arange(T * K)
    seg_start = jnp.searchsorted(se, jnp.arange(E))          # first idx per expert
    pos_in_e = ar - seg_start[se]
    keep = pos_in_e < C
    slot = jnp.where(keep, se * C + pos_in_e, E * C)         # E*C = drop slot

    buf = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(xt[st])
    buf = buf[:-1].reshape(E, C, d)

    # ---- expert compute (batched SwiGLU)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["wu"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wd"])          # (E, C, d)

    # ---- combine: weighted scatter-add back to tokens
    out_flat = out_buf.reshape(E * C, d)
    gathered = jnp.where(keep[:, None], out_flat[jnp.clip(slot, 0, E * C - 1)], 0.0)
    y = jnp.zeros((T, d), x.dtype).at[st].add(gathered * sw[:, None].astype(x.dtype))
    return y.reshape(B, S, d), aux_loss


# ---------------------------------------------------------------------------
# Expert-parallel MoE (shard_map) — §Perf hillclimb A.
#
# Under plain pjit the sort-based dispatch has data-dependent scatter
# indices, so GSPMD gives up and replicates the combine: a full (T, d)
# fp32 all-reduce per layer (measured 13.3 TB/chip for qwen3-moe train).
# The hand-written version below moves tokens with two all-to-alls over
# the "model" axis (send ≈ T_loc·K·d bytes per chip) and does the
# weighted top-k combine locally — the canonical expert-parallel flow.
# ---------------------------------------------------------------------------

_MESH = None


def set_mesh(mesh) -> None:
    """Install the mesh used by expert-parallel shard_map (launcher-set)."""
    global _MESH
    _MESH = mesh


def _sorted_pack(dest, n_dest: int, cap: int, payload):
    """Pack `payload[t]` rows into a (n_dest, cap) buffer by destination.
    Returns (buffer, slot) where slot[t] is the flat position (or n_dest*cap
    for dropped entries)."""
    n = dest.shape[0]
    order = jnp.argsort(dest, stable=True)
    sd = dest[order]
    seg_start = jnp.searchsorted(sd, jnp.arange(n_dest))
    pos = jnp.arange(n) - seg_start[sd]
    keep = pos < cap
    slot_sorted = jnp.where(keep, sd * cap + pos, n_dest * cap)
    # slot per ORIGINAL index
    slot = jnp.zeros((n,), jnp.int32).at[order].set(slot_sorted.astype(jnp.int32))
    buf = jnp.zeros((n_dest * cap + 1,) + payload.shape[1:], payload.dtype)
    buf = buf.at[slot].set(payload)
    return buf[:-1].reshape((n_dest, cap) + payload.shape[1:]), slot


def moe_ffn_expert_parallel(p, x, *, num_experts: int, experts_per_tok: int,
                            capacity_factor: float = 2.0,
                            model_axis: str = "model",
                            dp_axes=("pod", "data"),
                            seq_sharded: bool = False):
    """Expert-parallel MoE: tokens sharded on dp axes, experts on
    ``model_axis``. Must be called with a mesh installed via set_mesh().

    ``seq_sharded`` (§Perf A it.3): consume the sequence-parallel stream
    directly — x enters (dp, "model", None), each chip routes its own
    seq slice, and the y all-gather disappears (the next block's SP
    constraint keeps the stream seq-sharded)."""
    assert _MESH is not None, "call repro.models.moe.set_mesh(mesh) first"
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map as _shard_map

        def shard_map(f, **kw):
            # check_vma can't statically prove the post-all_gather model-axis
            # replication of y; disable the check (correctness covered by
            # scripts/validate_moe_ep.py against the dropless oracle)
            return _shard_map(f, check_vma=False, **kw)
    except ImportError:  # older spelling
        from jax.experimental.shard_map import shard_map as _sm

        def shard_map(f, mesh=None, in_specs=None, out_specs=None):
            return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                       check_rep=False)

    E, K = num_experts, experts_per_tok
    dp = tuple(a for a in dp_axes if a in _MESH.axis_names)
    M = _MESH.shape[model_axis]
    assert E % M == 0, f"experts {E} must divide model axis {M}"
    E_loc = E // M
    cf = capacity_factor if capacity_factor > 0 else 8.0

    def inner(router, wg, wu, wd, xl):
        B, S, d = xl.shape
        if seq_sharded:
            # xl is already this chip's seq slice: tokens are local
            T_full = T_pad = None
            T = B * S
            xt = xl.reshape(T, d)
        else:
            T_full = B * S
            xt_full = xl.reshape(T_full, d)
            # --- token-parallel over the model axis: each model chip routes
            # and combines its own 1/M slice (pad when T doesn't divide —
            # decode steps can have T < M)
            T_pad = -(-T_full // M) * M
            if T_pad != T_full:
                xt_full = jnp.pad(xt_full, ((0, T_pad - T_full), (0, 0)))
            T = T_pad // M
            idx_m = jax.lax.axis_index(model_axis)
            xt = jax.lax.dynamic_slice_in_dim(xt_full, idx_m * T, T, 0)

        logits = xt.astype(jnp.float32) @ router          # router replicated
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, K)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

        density = jnp.mean(jax.nn.one_hot(top_e[:, 0], E), axis=0)
        aux = jnp.sum(density * jnp.mean(probs, axis=0)) * E
        aux = jax.lax.pmean(aux, axis_name=dp + (model_axis,)) if dp \
            else jax.lax.pmean(aux, axis_name=model_axis)

        flat_e = top_e.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(T), K)
        flat_w = top_p.reshape(-1)
        dest = flat_e // E_loc                             # target chip

        cap = max(1, int(T * K / M * cf))
        send_x, slot = _sorted_pack(dest, M, cap, xt[flat_t])
        send_e, _ = _sorted_pack(dest, M, cap,
                                 (flat_e + 1).astype(jnp.int32))  # 0 = empty

        recv_x = jax.lax.all_to_all(send_x, model_axis, 0, 0, tiled=True)
        recv_e = jax.lax.all_to_all(send_e, model_axis, 0, 0, tiled=True)

        # --- local expert compute
        my_first = jax.lax.axis_index(model_axis) * E_loc
        rex = recv_x.reshape(M * cap, d)
        re_global = recv_e.reshape(M * cap)
        valid = re_global > 0
        re_loc = jnp.clip(re_global - 1 - my_first, 0, E_loc - 1)
        re_loc = jnp.where(valid, re_loc, E_loc)           # E_loc = drop row
        c2 = max(1, int(M * cap / E_loc * 1.5))
        ebuf, eslot = _sorted_pack(re_loc, E_loc + 1, c2, rex)
        ebuf = ebuf[:E_loc]                                # (E_loc, c2, d)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ebuf, wg)) \
            * jnp.einsum("ecd,edf->ecf", ebuf, wu)
        eout = jnp.einsum("ecf,efd->ecd", h, wd)           # (E_loc, c2, d)

        eout_flat = eout.reshape(E_loc * c2, d)
        ok = valid & (eslot < E_loc * c2)
        rows = jnp.where(ok[:, None],
                         eout_flat[jnp.clip(eslot, 0, E_loc * c2 - 1)], 0.0)
        back = rows.reshape(M, cap, d)

        ret = jax.lax.all_to_all(back, model_axis, 0, 0, tiled=True)
        ret_flat = ret.reshape(M * cap, d)

        kept = slot < M * cap
        vals = jnp.where(kept[:, None],
                         ret_flat[jnp.clip(slot, 0, M * cap - 1)], 0.0)
        y_m = jnp.zeros((T, d), xl.dtype).at[flat_t].add(
            vals.astype(xl.dtype) * flat_w[:, None].astype(xl.dtype))
        if seq_sharded:
            return y_m.reshape(B, S, d), aux
        # reassemble the model-axis token slices (Megatron-style AG)
        y = jax.lax.all_gather(y_m, model_axis, axis=0, tiled=True)
        return y[:T_full].reshape(B, S, d), aux

    B, S, d = x.shape
    if seq_sharded:
        xspec = P(dp if dp else None, model_axis, None)
    else:
        xspec = P(dp if dp else None, None, None)
    f = shard_map(
        inner, mesh=_MESH,
        in_specs=(P(), P(model_axis, None, None), P(model_axis, None, None),
                  P(model_axis, None, None), xspec),
        out_specs=(xspec, P()),
    )
    y, aux = f(p["router"], p["wg"], p["wu"], p["wd"], x)
    return y, aux
