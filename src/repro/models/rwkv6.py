"""RWKV-6 "Finch" block (arXiv:2404.05892): time-mix with data-dependent
decay + channel-mix FFN. Attention-free; per-head state S ∈ R^{hd×hd}.

Time-mix recurrence (per head, key dim i, value dim j):
    S_t[i,j] = w_t[i] · S_{t-1}[i,j] + k_t[i] · v_t[j]
    o_t[j]   = Σ_i r_t[i] · (S_{t-1}[i,j] + u[i] · k_t[i] · v_t[j])
with data-dependent decay w_t = exp(-exp(w0 + lora_w(x̄_t))) ∈ (0,1).

Inputs to r/k/v/g/w projections are data-dependent token-shift lerps
(ddlerp) between x_t and x_{t-1} — the core Finch novelty.

The pure-jnp sequential scan here is the oracle; kernels/rwkv6_scan holds
the chunked Pallas TPU kernel.

State = {"S": (B,H,hd,hd) fp32, "x_tm": (B,d), "x_cm": (B,d)}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

_LORA = 32  # rank of the ddlerp / decay loras


def init_rwkv6(rng, d_model: int, d_ff: int, num_heads: int, head_dim: int, dtype):
    assert num_heads * head_dim == d_model
    ks = jax.random.split(rng, 16)
    d = d_model
    p = {
        # time-mix projections
        "w_r": dense_init(ks[0], (d, d), dtype=dtype),
        "w_k": dense_init(ks[1], (d, d), dtype=dtype),
        "w_v": dense_init(ks[2], (d, d), dtype=dtype),
        "w_g": dense_init(ks[3], (d, d), dtype=dtype),
        "w_o": dense_init(ks[4], (d, d), dtype=dtype),
        # ddlerp: mu base + low-rank data-dependent part, for r/k/v/w/g
        "mu": dense_init(ks[5], (5, d), scale=0.3, dtype=jnp.float32),
        "lora_a": dense_init(ks[6], (d, 5 * _LORA), dtype=dtype),
        "lora_b": dense_init(ks[7], (5, _LORA, d), scale=0.01, dtype=jnp.float32),
        # decay: w0 base + lora
        "w0": jnp.linspace(-7.0, 1.0, d).astype(jnp.float32),
        "wa": dense_init(ks[8], (d, _LORA), dtype=dtype),
        "wb": dense_init(ks[9], (_LORA, d), scale=0.01, dtype=jnp.float32),
        # per-key bonus
        "u": dense_init(ks[10], (num_heads, head_dim), scale=0.5, dtype=jnp.float32),
        # per-head groupnorm
        "gn_scale": jnp.ones((d,), jnp.float32),
        # channel-mix
        "cm_mu_k": jnp.full((d,), 0.5, jnp.float32),
        "cm_mu_r": jnp.full((d,), 0.5, jnp.float32),
        "cm_wk": dense_init(ks[11], (d, d_ff), dtype=dtype),
        "cm_wr": dense_init(ks[12], (d, d), dtype=dtype),
        "cm_wv": dense_init(ks[13], (d_ff, d), dtype=dtype),
    }
    return p


def _ddlerp(p, x, x_prev):
    """Data-dependent token-shift: returns (xr, xk, xv, xw, xg)."""
    dx = x_prev - x                                             # (..., d)
    lo = jnp.tanh(dx @ p["lora_a"])                             # (..., 5*LORA)
    lo = lo.reshape(*lo.shape[:-1], 5, _LORA)
    dd = jnp.einsum("...fl,fld->...fd", lo.astype(jnp.float32), p["lora_b"])
    mix = p["mu"] + dd                                          # (..., 5, d)
    out = x[..., None, :] + dx[..., None, :] * mix.astype(x.dtype)
    return tuple(out[..., f, :] for f in range(5))


def _decay(p, xw):
    lo = jnp.tanh(xw @ p["wa"]).astype(jnp.float32) @ p["wb"]
    return jnp.exp(-jnp.exp(p["w0"] + lo))                      # (..., d) in (0,1)


def _group_norm(x, scale, num_heads, eps=64e-5):
    """Per-head LayerNorm over head_dim. x: (B, H, hd)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y.reshape(*x.shape[:-2], -1) * scale


def time_mix(p, x, state, *, num_heads: int, head_dim: int):
    """Full-sequence time-mix. x: (B,S,d) → (y, new_state_partial)."""
    B, S, d = x.shape
    H, hd = num_heads, head_dim
    x_prev = jnp.concatenate([state["x_tm"][:, None], x[:, :-1]], axis=1)
    xr, xk, xv, xw, xg = _ddlerp(p, x, x_prev)
    r = (xr @ p["w_r"]).reshape(B, S, H, hd).astype(jnp.float32)
    k = (xk @ p["w_k"]).reshape(B, S, H, hd).astype(jnp.float32)
    v = (xv @ p["w_v"]).reshape(B, S, H, hd).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["w_g"])
    w = _decay(p, xw).reshape(B, S, H, hd)                      # (B,S,H,hd)

    def step(S_c, inp):
        r_t, k_t, v_t, w_t = inp                                # (B,H,hd) each
        kv = k_t[..., :, None] * v_t[..., None, :]              # (B,H,hd,hd)
        out = jnp.einsum("bhi,bhij->bhj", r_t,
                         S_c + p["u"][None, :, :, None] * kv)
        S_n = w_t[..., :, None] * S_c + kv
        return S_n, out

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    S_fin, outs = jax.lax.scan(step, state["S"], xs)
    out = jnp.moveaxis(outs, 0, 1)                              # (B,S,H,hd)
    out = _group_norm(out, p["gn_scale"], H).astype(x.dtype)
    y = (out * g) @ p["w_o"]
    return y, {"S": S_fin, "x_tm": x[:, -1]}


def time_mix_step(p, x, state, *, num_heads: int, head_dim: int):
    """One-token decode. x: (B,1,d)."""
    B, _, d = x.shape
    H, hd = num_heads, head_dim
    xt = x[:, 0]
    xr, xk, xv, xw, xg = _ddlerp(p, xt, state["x_tm"])
    r = (xr @ p["w_r"]).reshape(B, H, hd).astype(jnp.float32)
    k = (xk @ p["w_k"]).reshape(B, H, hd).astype(jnp.float32)
    v = (xv @ p["w_v"]).reshape(B, H, hd).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["w_g"])
    w = _decay(p, xw).reshape(B, H, hd)
    kv = k[..., :, None] * v[..., None, :]
    out = jnp.einsum("bhi,bhij->bhj", r, state["S"] + p["u"][None, :, :, None] * kv)
    S_n = w[..., :, None] * state["S"] + kv
    out = _group_norm(out, p["gn_scale"], H).astype(x.dtype)
    y = (out * g) @ p["w_o"]
    return y[:, None], {"S": S_n, "x_tm": xt}


def channel_mix(p, x, state):
    """Full-sequence channel-mix FFN with token shift."""
    x_prev = jnp.concatenate([state["x_cm"][:, None], x[:, :-1]], axis=1)
    xk = x + (x_prev - x) * p["cm_mu_k"].astype(x.dtype)
    xr = x + (x_prev - x) * p["cm_mu_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["cm_wk"]))
    y = jax.nn.sigmoid(xr @ p["cm_wr"]) * (kk @ p["cm_wv"])
    return y, {"x_cm": x[:, -1]}


def channel_mix_step(p, x, state):
    xt = x[:, 0]
    xk = xt + (state["x_cm"] - xt) * p["cm_mu_k"].astype(x.dtype)
    xr = xt + (state["x_cm"] - xt) * p["cm_mu_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["cm_wk"]))
    y = jax.nn.sigmoid(xr @ p["cm_wr"]) * (kk @ p["cm_wv"])
    return y[:, None], {"x_cm": xt}


def init_rwkv6_state(batch: int, d_model: int, num_heads: int,
                     head_dim: int, dtype):
    return {
        "S": jnp.zeros((batch, num_heads, head_dim, head_dim), jnp.float32),
        "x_tm": jnp.zeros((batch, d_model), dtype),
        "x_cm": jnp.zeros((batch, d_model), dtype),
    }
