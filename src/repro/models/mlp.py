"""Feed-forward blocks: SwiGLU (LLaMA family) and GeLU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_swiglu(rng, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(rng, 3)
    return {
        "wg": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "wu": dense_init(ks[1], (d_model, d_ff), dtype=dtype),
        "wd": dense_init(ks[2], (d_ff, d_model), dtype=dtype),
    }


def swiglu(p, x):
    return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]


def init_gelu_mlp(rng, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(rng, 2)
    return {
        "wi": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "wd": dense_init(ks[1], (d_ff, d_model), dtype=dtype),
    }


def gelu_mlp(p, x):
    return jax.nn.gelu(x @ p["wi"]) @ p["wd"]
