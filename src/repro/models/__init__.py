from repro.models.transformer import (
    decode_step,
    init_cache,
    init_paged_cache,
    init_params,
    prefill,
    prefill_chunk,
    train_logits,
)

__all__ = ["init_params", "train_logits", "init_cache", "init_paged_cache",
           "prefill", "prefill_chunk", "decode_step"]
