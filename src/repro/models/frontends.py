"""STUB modality frontends (the one allowed carve-out).

The assigned [audio]/[vlm] entries specify the transformer backbone only;
``input_specs()`` provides precomputed frame/patch embeddings of the
right shape. These helpers generate those embeddings (for smoke tests /
examples) and describe their ShapeDtypeStructs (for the dry-run).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def frontend_embed_shape(cfg: ModelConfig, batch: int):
    """Shape of the stub embeddings the frontend would produce."""
    if cfg.frontend is None:
        return None
    return (batch, cfg.frontend_tokens, cfg.d_model)


def stub_frontend(rng, cfg: ModelConfig, batch: int, dtype=None):
    """Random-but-deterministic stand-in for InternViT patch embeddings /
    whisper log-mel conv features."""
    shape = frontend_embed_shape(cfg, batch)
    if shape is None:
        return None
    dtype = dtype or jnp.dtype(cfg.dtype)
    return (jax.random.normal(rng, shape, jnp.float32) * 0.02).astype(dtype)
