"""Basic layers: norms, embeddings, initializers, logits head."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(rng, shape, scale: float | None = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (matches common LLM init)."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(fan_in)
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def rms_norm(x, scale, eps: float = 1e-6):
    """RMSNorm in fp32 math, cast back to input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def embed(tokens, table):
    return jnp.take(table, tokens, axis=0)


def unembed(x, table):
    """Project hidden states to vocabulary logits. table: (V, d)."""
    return jnp.einsum("...d,vd->...v", x, table).astype(jnp.float32)


def sinusoidal_positions(num_pos: int, dim: int) -> jnp.ndarray:
    """Whisper-style sinusoidal position embeddings (num_pos, dim)."""
    half = dim // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / (half - 1))
    pos = jnp.arange(num_pos)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(pos), jnp.cos(pos)], axis=-1)
