"""GQA attention: full-causal / sliding-window, prefill + decode paths.

Layouts
-------
hidden     x : (B, S, d)
query      q : (B, S, H, hd)
key/value    : (B, S, KV, hd)
full cache   : (B, S_max, KV, hd), written at absolute position
ring cache   : (B, W, KV, hd), slot = pos % W  (sliding-window layers)

All softmax math is fp32; inputs/outputs stay in the model dtype.
The decode path has a pure-jnp implementation here; the Pallas
flash-decode kernel (kernels/decode_attn) is an optional drop-in used
when ``repro.kernels.use_pallas()`` is true.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.models.rope import apply_rope

NEG_INF = -2.0 ** 30  # large-but-finite; avoids NaN from inf-inf in padding rows

# query-chunk size for the memory-bounded prefill/train path
Q_CHUNK = 1024


def init_attn(rng, d_model: int, num_heads: int, num_kv_heads: int,
              head_dim: int, qkv_bias: bool, dtype):
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], (d_model, num_heads * head_dim), dtype=dtype),
        "wk": dense_init(ks[1], (d_model, num_kv_heads * head_dim), dtype=dtype),
        "wv": dense_init(ks[2], (d_model, num_kv_heads * head_dim), dtype=dtype),
        "wo": dense_init(ks[3], (num_heads * head_dim, d_model), dtype=dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
    return p


def _project_qkv(p, x, num_heads, num_kv_heads, head_dim):
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, num_heads, head_dim)
    k = k.reshape(B, S, num_kv_heads, head_dim)
    v = v.reshape(B, S, num_kv_heads, head_dim)
    return q, k, v


def _gqa_scores(q, k):
    """q: (B,Sq,KV,G,hd)  k: (B,Sk,KV,hd) -> (B,KV,G,Sq,Sk) fp32."""
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                      preferred_element_type=jnp.float32)


def _attend(q, k, v, mask):
    """Masked softmax attention. q:(B,Sq,KV,G,hd) k,v:(B,Sk,KV,hd)
    mask broadcastable to (B,KV,G,Sq,Sk). Returns (B,Sq,KV,G,hd)."""
    hd = q.shape[-1]
    scores = _gqa_scores(q, k) * (hd ** -0.5)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v)
    return out


def attn_forward(p, x, positions, *, num_heads: int, num_kv_heads: int,
                 head_dim: int, window: int, rope_theta: float,
                 use_rope: bool) -> jnp.ndarray:
    """Full-sequence causal attention (train / prefill). window<=0 → global.

    Scans over query chunks so live score memory is O(Q_CHUNK · S), not
    O(S²) — required for the 32k prefill shape to fit HBM.
    """
    B, S, d = x.shape
    G = num_heads // num_kv_heads
    q, k, v = _project_qkv(p, x, num_heads, num_kv_heads, head_dim)
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    q = q.reshape(B, S, num_kv_heads, G, head_dim)

    kv_pos = positions  # (B, S) or (S,)
    if kv_pos.ndim == 1:
        kv_pos = jnp.broadcast_to(kv_pos, (B, S))

    def block(q_blk, qpos_blk):
        # q_blk: (B, C, KV, G, hd); qpos_blk: (B, C)
        m = qpos_blk[:, None, None, :, None] >= kv_pos[:, None, None, None, :]
        if window > 0:
            m &= (qpos_blk[:, None, None, :, None] - kv_pos[:, None, None, None, :]) < window
        return _attend(q_blk, k, v, m)

    out = _chunked_q(block, q, kv_pos, B, S, num_kv_heads, G, head_dim)
    out = out.reshape(B, S, num_heads * head_dim)
    return out @ p["wo"]


def _chunked_q(block, q, kv_pos, B, S, num_kv_heads, G, head_dim):
    """Scan ``block`` over query chunks (pads S up to a Q_CHUNK multiple;
    padded queries get position −1 → fully masked → sliced away)."""
    if S <= Q_CHUNK:
        return block(q, kv_pos)
    nc = -(-S // Q_CHUNK)
    Sp = nc * Q_CHUNK
    if Sp != S:
        q = jnp.pad(q, ((0, 0), (0, Sp - S)) + ((0, 0),) * (q.ndim - 2))
        kv_pos_q = jnp.pad(kv_pos, ((0, 0), (0, Sp - S)), constant_values=-1)
    else:
        kv_pos_q = kv_pos
    qc = q.reshape(B, nc, Q_CHUNK, num_kv_heads, G, head_dim)
    pc = kv_pos_q.reshape(B, nc, Q_CHUNK)
    out = jax.lax.scan(
        lambda _, xs: (None, block(xs[0], xs[1])),
        None, (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(pc, 1, 0)))[1]
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sp, num_kv_heads, G, head_dim)
    return out[:, :S]


# ---------------------------------------------------------------- caches
#
# KV caches come in two flavours:
#   bf16/f32:  {"k": (B,S,KV,hd), "v": ...} in the model dtype
#   int8:      {"k","v": int8, "k_s","v_s": (B,S,KV) f32 per-token-head
#               absmax scales} — halves decode HBM traffic (§Perf B)

def init_full_cache(batch: int, max_seq: int, num_kv_heads: int,
                    head_dim: int, dtype, quantized: bool = False):
    shp = (batch, max_seq, num_kv_heads, head_dim)
    if quantized:
        return {"k": jnp.zeros(shp, jnp.int8), "v": jnp.zeros(shp, jnp.int8),
                "k_s": jnp.zeros(shp[:3], jnp.float32),
                "v_s": jnp.zeros(shp[:3], jnp.float32)}
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}


def init_ring_cache(batch: int, window: int, num_kv_heads: int,
                    head_dim: int, dtype, quantized: bool = False):
    return init_full_cache(batch, window, num_kv_heads, head_dim, dtype,
                           quantized)


def init_paged_kv(num_pages: int, page_size: int, num_kv_heads: int,
                  head_dim: int, dtype, quantized: bool = False):
    """Paged pool for a global-attention layer: physical page p holds
    ``page_size`` contiguous token slots of whichever row owns it
    (DESIGN.md §5). Layout mirrors the full cache with the batch axis
    replaced by the page axis: (P, ps, KV, hd). The caller reserves one
    extra *trash* page (by convention the last physical index) that
    unowned block-table entries alias — writes to it are garbage, reads
    from it are always masked."""
    return init_full_cache(num_pages, page_size, num_kv_heads, head_dim,
                           dtype, quantized)


def _is_quantized(cache) -> bool:
    return cache["k"].dtype == jnp.int8


def _quantize_kv(x):
    """x: (..., hd) → (int8 values, (...,) f32 scales)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.round(x.astype(jnp.float32) / scale[..., None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)).astype(dtype)


def write_ring_from_kv(cache, k, v, positions):
    """Fill a ring (or short full) cache from already-computed K/V
    (used by the halo-attention prefill path). k, v: (B, S, KV, hd)."""
    S = k.shape[1]
    W = cache["k"].shape[1]
    quant = _is_quantized(cache)
    if quant:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
    else:
        kq, vq, ks, vs = k, v, None, None
    if W < S:
        slots = jnp.mod(positions[-W:], W)
        new = {
            "k": jnp.zeros_like(cache["k"]).at[:, slots].set(
                kq[:, -W:].astype(cache["k"].dtype)),
            "v": jnp.zeros_like(cache["v"]).at[:, slots].set(
                vq[:, -W:].astype(cache["v"].dtype)),
        }
        if quant:
            new["k_s"] = jnp.zeros_like(cache["k_s"]).at[:, slots].set(ks[:, -W:])
            new["v_s"] = jnp.zeros_like(cache["v_s"]).at[:, slots].set(vs[:, -W:])
    else:
        new = {
            "k": jax.lax.dynamic_update_slice(
                cache["k"], kq.astype(cache["k"].dtype), (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(
                cache["v"], vq.astype(cache["v"].dtype), (0, 0, 0, 0)),
        }
        if quant:
            new["k_s"] = jax.lax.dynamic_update_slice(cache["k_s"], ks, (0, 0, 0))
            new["v_s"] = jax.lax.dynamic_update_slice(cache["v_s"], vs, (0, 0, 0))
    return new


def ring_slot_positions(pos, window: int):
    """Absolute position held by each ring slot when the newest write is at
    ``pos``: slot s holds the largest p <= pos with p ≡ s (mod W)."""
    s = jnp.arange(window)
    return pos - jnp.mod(pos - s, window)


# ---------------------------------------------------------------- prefill

def attn_prefill(p, x, positions, cache, *, num_heads: int, num_kv_heads: int,
                 head_dim: int, window: int, rope_theta: float,
                 use_rope: bool):
    """Run full attention over the prompt AND populate the cache.

    positions: (S,) absolute, shared across batch (lockstep engine).
    Returns (y, new_cache)."""
    B, S, _ = x.shape
    G = num_heads // num_kv_heads
    q, k, v = _project_qkv(p, x, num_heads, num_kv_heads, head_dim)
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)

    # Quantize FIRST and attend over what the cache will hold: under int8
    # every attention path (one-shot, chunked, paged, decode) sees the
    # same dequantized values, so serving mode never perturbs logits.
    quant = _is_quantized(cache)
    if quant:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        ka = _dequantize_kv(kq, ks, x.dtype)
        va = _dequantize_kv(vq, vs, x.dtype)
    else:
        kq, vq, ks, vs = k, v, None, None
        ka, va = k, v

    qr = q.reshape(B, S, num_kv_heads, G, head_dim)
    kv_pos = jnp.broadcast_to(positions, (B, S))

    def block(q_blk, qpos_blk):
        m = qpos_blk[:, None, None, :, None] >= kv_pos[:, None, None, None, :]
        if window > 0:
            m &= (qpos_blk[:, None, None, :, None] - kv_pos[:, None, None, None, :]) < window
        return _attend(q_blk, ka, va, m)

    out = _chunked_q(block, qr, kv_pos, B, S, num_kv_heads, G, head_dim)
    y = out.reshape(B, S, num_heads * head_dim) @ p["wo"]

    W = cache["k"].shape[1]
    if window > 0 and W < S:
        # ring cache: keep the last W tokens, rotated so slot = pos % W
        slots = jnp.mod(positions[-W:], W)
        new = {
            "k": jnp.zeros_like(cache["k"]).at[:, slots].set(
                kq[:, -W:].astype(cache["k"].dtype)),
            "v": jnp.zeros_like(cache["v"]).at[:, slots].set(
                vq[:, -W:].astype(cache["v"].dtype)),
        }
        if quant:
            new["k_s"] = jnp.zeros_like(cache["k_s"]).at[:, slots].set(ks[:, -W:])
            new["v_s"] = jnp.zeros_like(cache["v_s"]).at[:, slots].set(vs[:, -W:])
    else:
        new = {
            "k": jax.lax.dynamic_update_slice(
                cache["k"], kq.astype(cache["k"].dtype), (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(
                cache["v"], vq.astype(cache["v"].dtype), (0, 0, 0, 0)),
        }
        if quant:
            new["k_s"] = jax.lax.dynamic_update_slice(cache["k_s"], ks, (0, 0, 0))
            new["v_s"] = jax.lax.dynamic_update_slice(cache["v_s"], vs, (0, 0, 0))
    return y, new


# ---------------------------------------------------------------- decode

def attn_decode(p, x, pos, cache, *, num_heads: int, num_kv_heads: int,
                head_dim: int, window: int, rope_theta: float,
                use_rope: bool):
    """One-token decode. x: (B, 1, d); pos: scalar absolute position, or
    (B,) int32 per-row positions (continuous batching: pool rows belong
    to different requests and advance independently).
    Returns (y (B,1,d), new_cache)."""
    B = x.shape[0]
    G = num_heads // num_kv_heads
    q, k, v = _project_qkv(p, x, num_heads, num_kv_heads, head_dim)
    pos = jnp.asarray(pos)
    per_row = pos.ndim == 1
    posa = pos[:, None] if per_row else jnp.full((1,), pos)
    if use_rope:
        q = apply_rope(q, posa, rope_theta)
        k = apply_rope(k, posa, rope_theta)

    W = cache["k"].shape[1]
    is_ring = window > 0 and W <= window
    slot = jnp.mod(pos, W) if is_ring else pos
    quant = _is_quantized(cache)
    new_cache = dict(cache)
    if quant:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
    else:
        kq, vq = k, v
    if per_row:
        rows = jnp.arange(B)
        if quant:
            new_cache["k_s"] = cache["k_s"].at[rows, slot].set(ks[:, 0])
            new_cache["v_s"] = cache["v_s"].at[rows, slot].set(vs[:, 0])
        new_cache["k"] = cache["k"].at[rows, slot].set(
            kq[:, 0].astype(cache["k"].dtype))
        new_cache["v"] = cache["v"].at[rows, slot].set(
            vq[:, 0].astype(cache["v"].dtype))
    else:
        if quant:
            new_cache["k_s"] = jax.lax.dynamic_update_slice(cache["k_s"], ks, (0, slot, 0))
            new_cache["v_s"] = jax.lax.dynamic_update_slice(cache["v_s"], vs, (0, slot, 0))
        new_cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], kq.astype(cache["k"].dtype), (0, slot, 0, 0))
        new_cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], vq.astype(cache["v"].dtype), (0, slot, 0, 0))
    new_k = new_cache["k"] if not quant else _dequantize_kv(
        new_cache["k"], new_cache["k_s"], x.dtype)
    new_v = new_cache["v"] if not quant else _dequantize_kv(
        new_cache["v"], new_cache["v_s"], x.dtype)

    posq = pos[:, None] if per_row else pos  # (B,1) or scalar
    if is_ring:
        kv_positions = ring_slot_positions(posq, W)         # (W,) or (B,W)
        valid = (kv_positions >= 0) & (kv_positions <= posq)
        if window > 0:
            valid &= (posq - kv_positions) < window
    else:
        kv_positions = jnp.arange(W)
        valid = kv_positions <= posq
        if window > 0:
            valid &= (posq - kv_positions) > -1
            valid &= (posq - kv_positions) < window

    qr = q.reshape(B, 1, num_kv_heads, G, head_dim)
    mask = valid[:, None, None, None, :] if valid.ndim == 2 \
        else valid[None, None, None, None, :]
    out = _attend(qr, new_k, new_v, mask)
    y = out.reshape(B, 1, num_heads * head_dim) @ p["wo"]
    return y, new_cache


# ------------------------------------------------------------ chunk prefill
#
# Chunked prefill (DESIGN.md §6): a (B, C) slice of the prompt is run
# against a cache that already holds each row's first pos0 tokens, so a
# long admission advances one bounded chunk per scheduler tick instead
# of stalling every decode row for the whole prompt. Keys are always
# ordered by absolute position (history first, then the chunk) — ring
# layers included, whose slot-ordered window is re-gathered ascending —
# so the causal mask only ever *trails*: masked slots contribute
# exact-0.0 terms outside the real keys, which is what keeps the final
# chunk's logits bitwise equal to the one-shot prefill on the same
# positions for every layer kind.


def _write_chunk_kv(cache, kq, vq, ks, vs, rows, slots, quant):
    """Scatter a chunk's (B, C) K/V (and int8 scales) into per-row cache
    slots. ``rows``: (B, 1); ``slots``: (B, C)."""
    new = dict(cache)
    new["k"] = cache["k"].at[rows, slots].set(kq.astype(cache["k"].dtype))
    new["v"] = cache["v"].at[rows, slots].set(vq.astype(cache["v"].dtype))
    if quant:
        new["k_s"] = cache["k_s"].at[rows, slots].set(ks)
        new["v_s"] = cache["v_s"].at[rows, slots].set(vs)
    return new


def attn_prefill_chunk(p, x, pos0, cache, *, hist_len: int, num_heads: int,
                       num_kv_heads: int, head_dim: int, window: int,
                       rope_theta: float, use_rope: bool):
    """Chunk prefill against a contiguous (full or ring) cache.

    x: (B, C, d); pos0: (B,) absolute position of each row's first chunk
    token; the cache already holds positions < pos0. ``hist_len`` is the
    static history slice bound for full caches (callers pass the exact
    filled length, so no masked slot sits between real keys); ring
    caches ignore it (their whole window is the history). Returns
    (y (B, C, d), new_cache)."""
    B, C, _ = x.shape
    G = num_heads // num_kv_heads
    q, k, v = _project_qkv(p, x, num_heads, num_kv_heads, head_dim)
    pos0 = jnp.asarray(pos0)
    qpos = pos0[:, None] + jnp.arange(C)                       # (B, C)
    if use_rope:
        q = apply_rope(q, qpos, rope_theta)
        k = apply_rope(k, qpos, rope_theta)

    W = cache["k"].shape[1]
    is_ring = window > 0 and W <= window
    quant = _is_quantized(cache)
    if quant:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        # attend-from-cache: the chunk's own keys go through the same
        # quantize→dequantize round trip the history already took, so
        # chunked int8 prefill stays bitwise equal to one-shot (which
        # rounds identically) and to the paged path (which re-reads the
        # chunk from its pages)
        kc = _dequantize_kv(kq, ks, x.dtype)
        vc = _dequantize_kv(vq, vs, x.dtype)
    else:
        kq, vq, ks, vs = k, v, None, None
        kc, vc = k, v
    rows = jnp.arange(B)[:, None]

    if is_ring:
        # history = the whole ring as it stands before this chunk,
        # gathered in ascending absolute-position order: position
        # pos0 - W + i lives at slot (pos0 + i) mod W. Slot order (a
        # rotation) holds the same keys but permutes the nonzero softmax
        # terms, which perturbs the fp summation order — ascending order
        # is what makes chunked ring prefill bitwise-equal to the
        # one-shot path across chunk arrangements (DESIGN.md §6).
        slots_asc = jnp.mod(pos0[:, None] + jnp.arange(W), W)  # (B, W)
        hist_pos = pos0[:, None] - W + jnp.arange(W)           # (B, W)
        hk = cache["k"][rows, slots_asc]
        hv = cache["v"][rows, slots_asc]
        if quant:
            hk = _dequantize_kv(hk, cache["k_s"][rows, slots_asc], x.dtype)
            hv = _dequantize_kv(hv, cache["v_s"][rows, slots_asc], x.dtype)
        kv_pos = jnp.concatenate([hist_pos, qpos], axis=1)     # (B, W + C)
        ka = jnp.concatenate([hk, kc], axis=1)
        va = jnp.concatenate([hv, vc], axis=1)
        valid = kv_pos >= 0
        # write the chunk's last min(C, W) tokens (their slots are
        # distinct mod W; older chunk tokens would be overwritten anyway)
        if C > W:
            wslots = jnp.mod(qpos[:, -W:], W)
            kw, vw = kq[:, -W:], vq[:, -W:]
            ksw = ks[:, -W:] if quant else None
            vsw = vs[:, -W:] if quant else None
        else:
            wslots, kw, vw, ksw, vsw = jnp.mod(qpos, W), kq, vq, ks, vs
        new_cache = _write_chunk_kv(cache, kw, vw, ksw, vsw, rows, wslots,
                                    quant)
    else:
        hk, hv = cache["k"][:, :hist_len], cache["v"][:, :hist_len]
        if quant:
            hk = _dequantize_kv(hk, cache["k_s"][:, :hist_len], x.dtype)
            hv = _dequantize_kv(hv, cache["v_s"][:, :hist_len], x.dtype)
        hist_pos = jnp.broadcast_to(jnp.arange(hist_len), (B, hist_len))
        kv_pos = jnp.concatenate([hist_pos, qpos], axis=1)     # (B, H + C)
        ka = jnp.concatenate([hk, kc], axis=1)
        va = jnp.concatenate([hv, vc], axis=1)
        # history slots at/after pos0 hold garbage (or other rows' data)
        valid = kv_pos < pos0[:, None]
        valid = valid.at[:, hist_len:].set(True)
        new_cache = _write_chunk_kv(cache, kq, vq, ks, vs, rows, qpos, quant)

    mask = valid[:, None, :] & (kv_pos[:, None, :] <= qpos[:, :, None])
    if window > 0:
        mask &= (qpos[:, :, None] - kv_pos[:, None, :]) < window
    mask = mask[:, None, None]                                 # (B,1,1,C,S)

    qr = q.reshape(B, C, num_kv_heads, G, head_dim)
    out = _attend(qr, ka, va, mask)
    y = out.reshape(B, C, num_heads * head_dim) @ p["wo"]
    return y, new_cache


def attn_prefill_chunk_paged(p, x, pos0, cache, block_tables, chunk_pages, *,
                             num_heads: int, num_kv_heads: int,
                             head_dim: int, rope_theta: float,
                             use_rope: bool):
    """Chunk prefill writing straight into allocator-owned pages — no
    batch-1 side cache for the global layers (DESIGN.md §6).

    x: (B, C, d); pos0: (B,); cache: page pool from :func:`init_paged_kv`;
    block_tables: (B, MP) the rows' tables (prompt pages so far, trash
    elsewhere); chunk_pages: (B, C) physical page of each chunk token
    (all refcount-1 during prefill — the allocator hands them out before
    the chunk runs). Attention gathers the row's pages exactly like the
    decode oracle; validity is purely positional. Returns
    (y (B, C, d), new_cache)."""
    B, C, _ = x.shape
    G = num_heads // num_kv_heads
    q, k, v = _project_qkv(p, x, num_heads, num_kv_heads, head_dim)
    pos0 = jnp.asarray(pos0)
    qpos = pos0[:, None] + jnp.arange(C)                       # (B, C)
    if use_rope:
        q = apply_rope(q, qpos, rope_theta)
        k = apply_rope(k, qpos, rope_theta)

    ps = cache["k"].shape[1]
    MP = block_tables.shape[1]
    off = jnp.mod(qpos, ps)
    quant = _is_quantized(cache)
    new_cache = dict(cache)
    if quant:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        new_cache["k_s"] = cache["k_s"].at[chunk_pages, off].set(ks)
        new_cache["v_s"] = cache["v_s"].at[chunk_pages, off].set(vs)
    else:
        kq, vq = k, v
    new_cache["k"] = cache["k"].at[chunk_pages, off].set(
        kq.astype(cache["k"].dtype))
    new_cache["v"] = cache["v"].at[chunk_pages, off].set(
        vq.astype(cache["v"].dtype))

    if use_paged_kernel():
        # paged chunk-prefill kernel: C chunk tokens attend causally over
        # the row's pages, streamed through the block table — the same
        # no-HBM-gather property as the decode kernel, int8 included
        from repro.kernels.decode_attn.ops import paged_prefill_attn
        _count_paged_backend("prefill_kernel")
        out = paged_prefill_attn(
            q, new_cache["k"], new_cache["v"], block_tables, pos0,
            k_scales=new_cache["k_s"] if quant else None,
            v_scales=new_cache["v_s"] if quant else None)
        y = (out.astype(x.dtype).reshape(B, C, num_heads * head_dim)
             @ p["wo"])
        return y, new_cache

    _count_paged_backend("prefill_oracle")
    ka = new_cache["k"][block_tables].reshape(B, MP * ps, num_kv_heads,
                                              head_dim)
    va = new_cache["v"][block_tables].reshape(B, MP * ps, num_kv_heads,
                                              head_dim)
    if quant:
        ksa = new_cache["k_s"][block_tables].reshape(B, MP * ps, num_kv_heads)
        vsa = new_cache["v_s"][block_tables].reshape(B, MP * ps, num_kv_heads)
        ka = _dequantize_kv(ka, ksa, x.dtype)
        va = _dequantize_kv(va, vsa, x.dtype)

    kv_pos = jnp.arange(MP * ps)
    mask = kv_pos[None, None, :] <= qpos[:, :, None]           # (B, C, S)
    mask = mask[:, None, None]

    qr = q.reshape(B, C, num_kv_heads, G, head_dim)
    out = _attend(qr, ka, va, mask)
    y = out.reshape(B, C, num_heads * head_dim) @ p["wo"]
    return y, new_cache


_PAGED_KERNEL: Optional[bool] = None

# Trace-time record of which backend the paged attention paths actually
# dispatched — the kernel/oracle choice is a *Python* branch, invisible in
# jaxprs and silent at runtime. Every trace of a paged attention function
# bumps exactly one key, so a test (or an operator reading server stats)
# can assert the Pallas kernel really traced instead of silently falling
# back to the jnp gather oracle (the int8 bypass bug this guards against).
_PAGED_BACKEND_COUNTS = {"decode_kernel": 0, "decode_oracle": 0,
                         "prefill_kernel": 0, "prefill_oracle": 0}


def paged_backend_counts() -> dict:
    """Snapshot of trace-time paged-attention backend choices."""
    return dict(_PAGED_BACKEND_COUNTS)


def reset_paged_backend_counts() -> None:
    for key in _PAGED_BACKEND_COUNTS:
        _PAGED_BACKEND_COUNTS[key] = 0


def _count_paged_backend(which: str) -> None:
    _PAGED_BACKEND_COUNTS[which] += 1


def set_paged_kernel(flag: Optional[bool]) -> None:
    """Force the paged flash-decode kernel on/off (None = auto: kernel on
    TPU, jnp gather oracle under the Pallas interpreter / CPU). Tests set
    True to run the wired kernel path through the interpreter.

    The choice is captured at jit TRACE time: already-compiled callers
    (e.g. the scheduler's cached decode step) keep whichever path they
    were traced with — toggle before the first paged decode, or call
    ``attn_decode_paged`` eagerly as the wiring test does. The auto
    resolution is backend-based and stable for a process lifetime, so
    this only matters for explicit mid-process toggles."""
    global _PAGED_KERNEL
    _PAGED_KERNEL = flag


def use_paged_kernel() -> bool:
    if _PAGED_KERNEL is not None:
        return _PAGED_KERNEL
    from repro.kernels import interpret_mode
    return not interpret_mode()


def attn_decode_paged(p, x, pos, cache, block_tables, write_pages=None, *,
                      num_heads: int, num_kv_heads: int, head_dim: int,
                      rope_theta: float, use_rope: bool):
    """One-token decode against a paged KV pool (global layers only).

    x: (B, 1, d); pos: (B,) int32 per-row positions; cache: page pool from
    :func:`init_paged_kv` with leaves (P, ps, KV, hd); block_tables:
    (B, MP) int32 mapping row-logical pages to physical pages (unowned
    entries alias the trash page — validity is purely ``kv_pos <= pos``).

    The current token's K/V is written into ``write_pages`` ((B,) int32)
    when given — the scheduler computes it from allocator truth via
    ``PageAllocator.write_page``, which asserts each write page is
    refcount-1, so with prefix sharing a decode write is provably
    confined to unshared pages — else into the page the block table
    names at ``pos`` (standalone callers own every page privately).
    Attention then runs over the row's own pages: the gather below is
    the pure-jnp CPU oracle; when :func:`use_paged_kernel` is true the
    paged flash-decode kernel (kernels/decode_attn) streams the pages
    directly through the block table instead.
    Returns (y (B,1,d), new_cache)."""
    B = x.shape[0]
    G = num_heads // num_kv_heads
    q, k, v = _project_qkv(p, x, num_heads, num_kv_heads, head_dim)
    pos = jnp.asarray(pos)
    if use_rope:
        q = apply_rope(q, pos[:, None], rope_theta)
        k = apply_rope(k, pos[:, None], rope_theta)

    ps = cache["k"].shape[1]
    MP = block_tables.shape[1]
    lpage = pos // ps
    off = pos % ps
    if write_pages is None:
        phys = jnp.take_along_axis(block_tables, lpage[:, None], axis=1)[:, 0]
    else:
        phys = jnp.asarray(write_pages)

    quant = _is_quantized(cache)
    new_cache = dict(cache)
    if quant:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        new_cache["k_s"] = cache["k_s"].at[phys, off].set(ks[:, 0])
        new_cache["v_s"] = cache["v_s"].at[phys, off].set(vs[:, 0])
    else:
        kq, vq = k, v
    new_cache["k"] = cache["k"].at[phys, off].set(kq[:, 0].astype(cache["k"].dtype))
    new_cache["v"] = cache["v"].at[phys, off].set(vq[:, 0].astype(cache["v"].dtype))

    if use_paged_kernel():
        # paged flash-decode kernel: the S-tile index map dereferences the
        # block table, so only owned (and trash-aliased) pages stream
        # through VMEM — no (B, MP*ps, ...) gather materialized in HBM.
        # Int8 pools pass their scale pages for in-kernel dequant (the
        # quantized case used to silently drop to the oracle below).
        from repro.kernels.decode_attn.ops import paged_decode_attn
        _count_paged_backend("decode_kernel")
        out = paged_decode_attn(
            q[:, 0], new_cache["k"], new_cache["v"], block_tables, pos,
            k_scales=new_cache["k_s"] if quant else None,
            v_scales=new_cache["v_s"] if quant else None)
        y = out.astype(x.dtype).reshape(B, 1, num_heads * head_dim) @ p["wo"]
        return y, new_cache

    _count_paged_backend("decode_oracle")
    # gather the row's pages into its contiguous logical sequence view
    ka = new_cache["k"][block_tables].reshape(B, MP * ps, num_kv_heads, head_dim)
    va = new_cache["v"][block_tables].reshape(B, MP * ps, num_kv_heads, head_dim)
    if quant:
        ksa = new_cache["k_s"][block_tables].reshape(B, MP * ps, num_kv_heads)
        vsa = new_cache["v_s"][block_tables].reshape(B, MP * ps, num_kv_heads)
        ka = _dequantize_kv(ka, ksa, x.dtype)
        va = _dequantize_kv(va, vsa, x.dtype)

    kv_positions = jnp.arange(MP * ps)
    valid = kv_positions[None, :] <= pos[:, None]               # (B, S)

    qr = q.reshape(B, 1, num_kv_heads, G, head_dim)
    out = _attend(qr, ka, va, valid[:, None, None, None, :])
    y = out.reshape(B, 1, num_heads * head_dim) @ p["wo"]
    return y, new_cache


# ------------------------------------------------- halo attention (SP)
#
# §Perf hillclimb C iteration 2: with sequence parallelism the residual
# stream is seq-sharded on "model". A sliding-window layer does NOT need
# the full sequence gathered — each shard attends to its own tokens plus
# a window-sized halo from its left neighbour (one collective-permute of
# W tokens instead of an all-gather of S). Requires W ≤ S/shards.

_HALO_MESH = None


def set_halo_mesh(mesh) -> None:
    global _HALO_MESH
    _HALO_MESH = mesh


def halo_attn_available(seq_len: int, window: int, model_size: int) -> bool:
    return (_HALO_MESH is not None and seq_len % model_size == 0
            and window <= seq_len // model_size)


def attn_forward_halo(p, x, *, num_heads: int, num_kv_heads: int,
                      head_dim: int, window: int, rope_theta: float,
                      use_rope: bool, dp_axes=("pod", "data"),
                      model_axis: str = "model", return_kv: bool = False):
    """Sliding-window attention over a seq-sharded residual stream.

    x: (B, S, d) logically; sharded (dp, model, None). Returns y with the
    same sharding (and optionally the full-precision k, v for cache fill).
    """
    from jax.sharding import PartitionSpec as P
    mesh = _HALO_MESH
    dp = tuple(a for a in dp_axes if a in mesh.axis_names)
    M = mesh.shape[model_axis]

    def inner(wq, wk, wv, wo, bq, bk, bv, xl):
        B, S_loc, d = xl.shape
        idx = jax.lax.axis_index(model_axis)
        base = idx * S_loc
        q = xl @ wq
        k = xl @ wk
        v = xl @ wv
        if bq is not None:
            q, k, v = q + bq, k + bk, v + bv
        q = q.reshape(B, S_loc, num_heads, head_dim)
        k = k.reshape(B, S_loc, num_kv_heads, head_dim)
        v = v.reshape(B, S_loc, num_kv_heads, head_dim)
        positions = base + jnp.arange(S_loc)
        if use_rope:
            q = apply_rope(q, positions, rope_theta)
            k = apply_rope(k, positions, rope_theta)

        W = min(window, S_loc)
        perm = [(j, j + 1) for j in range(M - 1)]  # shard j → j+1
        k_halo = jax.lax.ppermute(k[:, -W:], model_axis, perm)
        v_halo = jax.lax.ppermute(v[:, -W:], model_axis, perm)
        k_full = jnp.concatenate([k_halo, k], axis=1)   # (B, W+S_loc, KV, hd)
        v_full = jnp.concatenate([v_halo, v], axis=1)
        kv_pos = base - W + jnp.arange(W + S_loc)       # halo positions < base

        G = num_heads // num_kv_heads
        qr = q.reshape(B, S_loc, num_kv_heads, G, head_dim)
        qp = positions[None, :]
        kp = kv_pos[None, :]
        mask = (qp[:, None, None, :, None] >= kp[:, None, None, None, :]) \
            & ((qp[:, None, None, :, None] - kp[:, None, None, None, :]) < window) \
            & (kp[:, None, None, None, :] >= 0)
        out = _attend(qr, k_full, v_full, mask)
        y = out.reshape(B, S_loc, num_heads * head_dim) @ wo
        return y, k, v

    xspec = P(dp if dp else None, model_axis, None)
    try:
        from jax import shard_map as _sm
        f = _sm(inner, mesh=mesh, check_vma=False,
                in_specs=(P(), P(), P(), P(), P(), P(), P(), xspec),
                out_specs=(xspec, xspec, xspec))
    except ImportError:
        from jax.experimental.shard_map import shard_map as _sm2
        f = _sm2(inner, mesh=mesh, check_rep=False,
                 in_specs=(P(), P(), P(), P(), P(), P(), P(), xspec),
                 out_specs=(xspec, xspec, xspec))
    bq, bk, bv = p.get("bq"), p.get("bk"), p.get("bv")
    y, k, v = f(p["wq"], p["wk"], p["wv"], p["wo"], bq, bk, bv, x)
    if return_kv:
        return y, k, v
    return y


# ---------------------------------------------------------------- cross

def init_cross_attn(rng, d_model: int, num_heads: int, num_kv_heads: int,
                    head_dim: int, dtype):
    return init_attn(rng, d_model, num_heads, num_kv_heads, head_dim, False, dtype)


def cross_attn_kv(p, enc_out, num_kv_heads: int, head_dim: int):
    """Precompute K,V from encoder output: (B, S_enc, KV, hd) each."""
    B, S, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(B, S, num_kv_heads, head_dim)
    v = (enc_out @ p["wv"]).reshape(B, S, num_kv_heads, head_dim)
    return k, v


def cross_attn(p, x, enc_k, enc_v, *, num_heads: int, num_kv_heads: int,
               head_dim: int):
    """Decoder→encoder cross attention (no causal mask, no rope)."""
    B, S, _ = x.shape
    G = num_heads // num_kv_heads
    q = (x @ p["wq"]).reshape(B, S, num_kv_heads, G, head_dim)
    mask = jnp.ones((1, 1, 1, 1, enc_k.shape[1]), bool)
    out = _attend(q, enc_k, enc_v, mask)
    return out.reshape(B, S, num_heads * head_dim) @ p["wo"]
