"""Chunked RWKV6 (WKV6) recurrence kernel.

Why a kernel: the sequential scan is the prefill/training bottleneck of
the rwkv6-3b arch — T sequential steps of tiny (hd×hd) updates leave the
MXU idle. The chunked formulation turns T steps into T/C chunk steps of
dense (C×hd)·(hd×hd) matmuls (MXU work) plus an O(C²) intra-chunk matmul,
the standard GLA/RWKV chunk-parallel trick adapted to Pallas/TPU:

For a chunk [1..C] with incoming state S₀, per key-channel i with decays
w and log-cumprod Lc_t = Σ_{j≤t} log w_j:
  inter:  y_t  += (r_t ∘ e^{Lc_{t−1}}) · S₀
  intra:  y_t  += Σ_{j<t} (Σ_i r_{t,i} e^{Lc_{t−1,i}−Lc_{j,i}} k_{j,i}) v_j
  diag :  y_t  += (r_t · (u ∘ k_t)) v_t
  state:  S_C   = diag(e^{Lc_C}) S₀ + Σ_j (e^{Lc_C−Lc_j} ∘ k_j) ⊗ v_j
All exponents are ≤ 0 (decays ∈ (0,1)), so everything is overflow-safe
without renormalization.

Grid: (B, H, T/C) — chunk axis innermost/sequential; the (hd×hd) state
lives in VMEM scratch across chunk iterations. The intra-chunk pairwise
factor A[t,j,i] = e^{Lc_{t−1,i}−Lc_{j,i}} is materialized per (t) row
block as (C, C) after contracting the key dim with r/k — VMEM cost
C·hd + C² fp32 (C=32, hd=64 → ~20 KiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import interpret_mode


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, sfin_ref, s_s,
            *, n_chunks: int, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_s[:] = jnp.zeros_like(s_s)

    r = r_ref[0, :, 0].astype(jnp.float32)     # (C, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)
    v = v_ref[0, :, 0].astype(jnp.float32)
    w = w_ref[0, :, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)           # (hd,)

    lw = jnp.log(jnp.maximum(w, 1e-38))        # (C, hd) ≤ 0
    lc = jnp.cumsum(lw, axis=0)                # Lc_t (1-based: row t = Σ_{j≤t})
    lc_prev = lc - lw                          # Lc_{t−1}

    s0 = s_s[:]                                # (hd, hd)

    # inter-chunk: (C, hd) @ (hd, hd)
    r_dec = r * jnp.exp(lc_prev)
    y = jnp.dot(r_dec, s0, preferred_element_type=jnp.float32)

    # intra-chunk: scores[t, j] = Σ_i r[t,i] e^{lc_prev[t,i] − lc[j,i]} k[j,i]
    k_dec = k * jnp.exp(-lc)                   # e^{-lc} ≥ 1 but bounded by
    # pairing: only used for j ≤ t−1 where lc_prev[t] − lc[j] ≤ 0; compute
    # scores in a numerically safe masked form via explicit broadcast:
    # A[t,j,i] = exp(lc_prev[t,i] − lc[j,i]) — strictly ≤ 1 for j < t.
    a = jnp.exp(jnp.clip(lc_prev[:, None, :] - lc[None, :, :], -80.0, 0.0))
    scores = jnp.einsum("ti,tji,ji->tj", r, a, k)          # (C, C)
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(mask, scores, 0.0)
    y += jnp.dot(scores, v, preferred_element_type=jnp.float32)

    # diagonal (current-token bonus)
    y += jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True) * v

    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    # state update: S_C = diag(e^{lc_C}) S0 + Σ_j (e^{lc_C − lc_j} k_j) ⊗ v_j
    decay_all = jnp.exp(lc[-1])                # (hd,)
    carry_k = k * jnp.exp(jnp.clip(lc[-1][None, :] - lc, -80.0, 0.0))
    s_s[:] = decay_all[:, None] * s0 + jnp.dot(
        carry_k.T, v, preferred_element_type=jnp.float32)

    @pl.when(ci == n_chunks - 1)
    def _fin():
        sfin_ref[0, 0] = s_s[:]


def rwkv6_scan_pallas(r, k, v, w, u, s0, *, chunk: int = 32,
                      interpret=None):
    """Chunk-parallel WKV6. Shapes as ref.py. T must divide by ``chunk``
    (callers pad). s0 must be zeros (scratch-initialized state; nonzero
    initial state is folded in by the ops.py wrapper).

    ``interpret=None`` resolves via :func:`repro.kernels.interpret_mode`
    so direct callers never run the Pallas interpreter on a real TPU."""
    if interpret is None:
        interpret = interpret_mode()
    return _rwkv6_scan_jit(r, k, v, w, u, s0, chunk=chunk,
                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _rwkv6_scan_jit(r, k, v, w, u, s0, *, chunk: int, interpret: bool):
    B, T, H, hd = r.shape
    assert T % chunk == 0, f"T={T} % chunk={chunk}"
    n_chunks = T // chunk

    kern = functools.partial(_kernel, n_chunks=n_chunks, chunk=chunk)
    y, s_fin = pl.pallas_call(
        kern,
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, hd), lambda b, h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return y, s_fin
