"""Public wrapper for the chunked RWKV6 scan kernel.

Handles T-padding to the chunk size and nonzero initial state: the kernel
runs with S₀ = 0 and the (linear) S₀ contribution is added outside —
  y_t += r_t · diag(e^{Lc_{t−1}}) S₀     (Lc from sequence start)
  S_T += diag(e^{Lc_T}) S₀
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import interpret_mode
from repro.kernels.rwkv6_scan.kernel import rwkv6_scan_pallas


def rwkv6_scan(r, k, v, w, u, s0=None, *, chunk: int = 32):
    B, T, H, hd = r.shape
    Tp = -(-T // chunk) * chunk
    if Tp != T:
        pad = ((0, 0), (0, Tp - T), (0, 0), (0, 0))
        r = jnp.pad(r, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        w = jnp.pad(w, pad, constant_values=1.0)  # decay 1 → state untouched
    y, s_fin = rwkv6_scan_pallas(r, k, v, w, u, None, chunk=chunk,
                                 interpret=interpret_mode())
    y = y[:, :T]

    if s0 is not None:
        lw = jnp.log(jnp.maximum(w[:, :T].astype(jnp.float32), 1e-38))
        lc = jnp.cumsum(lw, axis=1)                    # (B,T,H,hd)
        r_dec = r[:, :T].astype(jnp.float32) * jnp.exp(lc - lw)
        y = y + jnp.einsum("bthi,bhij->bthj", r_dec, s0)
        s_fin = s_fin + jnp.exp(lc[:, -1])[..., None] * s0
    return y, s_fin
