"""Pure-jnp sequential oracle for the chunked RWKV6 recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_scan_ref(r, k, v, w, u, s0):
    """Sequential WKV6 recurrence (the time-mix core).

    r, k, v, w: (B, T, H, hd) fp32 — receptance, key, value, decay (w∈(0,1))
    u: (H, hd) fp32 — per-key bonus for the current token
    s0: (B, H, hd, hd) fp32 — initial state (key-dim × value-dim)

    Returns (y (B,T,H,hd), s_final (B,H,hd,hd)):
      y_t = r_t · (S_{t−1} + u∘k_t ⊗ v_t)
      S_t = diag(w_t) S_{t−1} + k_t ⊗ v_t
    """
    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhi,bhij->bhj", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), s_fin
