"""Public wrappers for the flash-decode attention kernels (contiguous
and paged). Both entry points resolve interpret mode themselves, so the
explicit pass-through here is belt-and-braces for readability."""
from __future__ import annotations

from repro.kernels import interpret_mode
from repro.kernels.decode_attn.kernel import (
    decode_attn_pallas,
    paged_decode_attn_pallas,
    paged_prefill_attn_pallas,
)


def decode_attn(q, k, v, pos, *, window: int = 0, ring: bool = False,
                tile_s: int = 512):
    """Flash GQA decode: q (B,H,hd) vs cache (B,S,KV,hd). See kernel.py."""
    return decode_attn_pallas(q, k, v, pos, window=window, ring=ring,
                              tile_s=tile_s, interpret=interpret_mode())


def paged_decode_attn(q, k_pages, v_pages, block_tables, pos, *,
                      k_scales=None, v_scales=None):
    """Paged flash GQA decode: q (B,H,hd) vs page pool (P,ps,KV,hd)
    addressed through (B,MP) block tables at per-row positions (B,).
    Optional (P,ps,KV) fp32 scales switch the pool to int8 with in-kernel
    dequant. See kernel.py / ref.py for the page semantics."""
    return paged_decode_attn_pallas(q, k_pages, v_pages, block_tables, pos,
                                    k_scales=k_scales, v_scales=v_scales,
                                    interpret=interpret_mode())


def paged_prefill_attn(q, k_pages, v_pages, block_tables, pos0, *,
                       k_scales=None, v_scales=None):
    """Paged chunk-prefill GQA attention: q (B,C,H,hd) chunk tokens
    attend causally vs the page pool (P,ps,KV,hd) through (B,MP) block
    tables starting at per-row positions pos0 (B,). Optional (P,ps,KV)
    fp32 scales switch the pool to int8 with in-kernel dequant."""
    return paged_prefill_attn_pallas(q, k_pages, v_pages, block_tables, pos0,
                                     k_scales=k_scales, v_scales=v_scales,
                                     interpret=interpret_mode())
