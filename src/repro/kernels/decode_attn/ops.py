"""Public wrappers for the flash-decode attention kernels (contiguous
and paged). Both entry points resolve interpret mode themselves, so the
explicit pass-through here is belt-and-braces for readability."""
from __future__ import annotations

from repro.kernels import interpret_mode
from repro.kernels.decode_attn.kernel import (
    decode_attn_pallas,
    paged_decode_attn_pallas,
)


def decode_attn(q, k, v, pos, *, window: int = 0, ring: bool = False,
                tile_s: int = 512):
    """Flash GQA decode: q (B,H,hd) vs cache (B,S,KV,hd). See kernel.py."""
    return decode_attn_pallas(q, k, v, pos, window=window, ring=ring,
                              tile_s=tile_s, interpret=interpret_mode())


def paged_decode_attn(q, k_pages, v_pages, block_tables, pos):
    """Paged flash GQA decode: q (B,H,hd) vs page pool (P,ps,KV,hd)
    addressed through (B,MP) block tables at per-row positions (B,).
    See kernel.py / ref.py for the page semantics."""
    return paged_decode_attn_pallas(q, k_pages, v_pages, block_tables, pos,
                                    interpret=interpret_mode())
