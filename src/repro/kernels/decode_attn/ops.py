"""Public wrapper for the flash-decode attention kernel."""
from __future__ import annotations

from repro.kernels import interpret_mode
from repro.kernels.decode_attn.kernel import decode_attn_pallas


def decode_attn(q, k, v, pos, *, window: int = 0, ring: bool = False,
                tile_s: int = 512):
    """Flash GQA decode: q (B,H,hd) vs cache (B,S,KV,hd). See kernel.py."""
    return decode_attn_pallas(q, k, v, pos, window=window, ring=ring,
                              tile_s=tile_s, interpret=interpret_mode())
