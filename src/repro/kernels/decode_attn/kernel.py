"""Flash-decode GQA attention kernel (one new token vs a long KV cache).

Why a kernel: decode attention is the memory-bound inner loop of the
serving engine — every step streams the whole KV cache once. Flash-decode
tiles the cache sequence into VMEM blocks and keeps online-softmax
statistics per (batch, kv-head), so HBM traffic is exactly one read of
K and V, no (S,) score materialization in HBM, and the G=H/KV query rows
of a GQA group ride along in registers/VMEM (sublane dim) for free.

Grid: (B, KV, S/TS) — S innermost (sequential). Scratch per (b, kv):
  m (G,1), l (G,1), acc (G, hd). Output written on the last S tile.

Masking (causal / sliding-window / ring-buffer slot semantics) is
computed from the absolute position scalar, prefetched via
PrefetchScalarGridSpec so block index maps could depend on it if needed.

TS defaults to 512: K tile + V tile = 2·512·hd·2B ≈ 256 KiB (hd=128
bf16) — comfortably inside VMEM with double buffering.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import interpret_mode

NEG = -2.0 ** 30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s,
            *, n_s_tiles: int, tile_s: int, window: int, ring: bool,
            seq: int, scale: float):
    si = pl.program_id(2)
    pos = pos_ref[0]

    @pl.when(si == 0)
    def _init():
        m_s[:] = jnp.full_like(m_s, NEG)
        l_s[:] = jnp.zeros_like(l_s)
        acc_s[:] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)           # (G, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)        # (TS, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)        # (TS, hd)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G, TS)

    slots = si * tile_s + jax.lax.broadcasted_iota(jnp.int32, (1, tile_s), 1)
    if ring:
        kv_pos = pos - jnp.mod(pos - slots, seq)
    else:
        kv_pos = slots
    valid = (kv_pos >= 0) & (kv_pos <= pos) & (slots < seq)  # last: seq padding
    if window > 0:
        valid &= (pos - kv_pos) < window
    s = jnp.where(valid, s, NEG)

    m_prev = m_s[:]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    r = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                        # (G, TS)
    l_s[:] = l_s[:] * r + jnp.sum(p, axis=-1, keepdims=True)
    acc_s[:] = acc_s[:] * r + jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_s[:] = m_new

    @pl.when(si == n_s_tiles - 1)
    def _finalize():
        o_ref[0, 0] = (acc_s[:] / jnp.maximum(l_s[:], 1e-30)).astype(o_ref.dtype)


def decode_attn_pallas(q, k, v, pos, *, window: int = 0, ring: bool = False,
                       tile_s: int = 512, interpret: Optional[bool] = None):
    """q: (B, H, hd); k, v: (B, S, KV, hd); pos: scalar int32.
    Returns (B, H, hd) fp32. See ref.py for slot semantics.

    ``interpret=None`` resolves via :func:`repro.kernels.interpret_mode`
    (compiled on TPU, interpreter elsewhere) — callers bypassing ops.py no
    longer silently run the Pallas interpreter on real hardware."""
    if interpret is None:
        interpret = interpret_mode()
    return _decode_attn_jit(q, k, v, pos, window=window, ring=ring,
                            tile_s=tile_s, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("window", "ring", "tile_s", "interpret"))
def _decode_attn_jit(q, k, v, pos, *, window: int, ring: bool,
                     tile_s: int, interpret: bool):
    B, S, KV, hd = k.shape
    H = q.shape[1]
    G = H // KV
    ts = min(tile_s, S)
    Sp = -(-S // ts) * ts
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        # padded slots: ring positions computed mod original seq — mask
        # them via kv_pos > pos (slots >= S get kv_pos = slot > pos in
        # non-ring; in ring mode pad is masked below via seq=S semantics)
    n_s = Sp // ts
    qr = q.reshape(B, KV, G, hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, kv, s, pos_ref: (b, kv, 0, 0)),
            pl.BlockSpec((1, ts, 1, hd), lambda b, kv, s, pos_ref: (b, s, kv, 0)),
            pl.BlockSpec((1, ts, 1, hd), lambda b, kv, s, pos_ref: (b, s, kv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, kv, s, pos_ref: (b, kv, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    kern = functools.partial(_kernel, n_s_tiles=n_s, tile_s=ts, window=window,
                             ring=ring, seq=S, scale=hd ** -0.5)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(pos, jnp.int32).reshape(1), qr, k, v)
    return out.reshape(B, H, hd)


# ----------------------------------------------------------------- paged

def _paged_kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, *rest,
                  n_pages: int, page_size: int, group: int, scale: float,
                  quantized: bool):
    """Paged flash attention body, shared by decode and chunk prefill:
    one grid step streams one owned page against R query rows.

    The S-tile index map dereferences the block table (scalar-prefetched),
    so the kernel's K/V DMAs touch only physical pages a row's table names
    — pruned/unallocated capacity is never streamed. Validity is purely
    positional; table entries past a row's position may alias a shared
    trash page and are masked here.

    Query-row positions: row r belongs to chunk token r // group at
    absolute position pos_ref[b] + r // group. Decode is the R == group
    case (every row is the same single token at pos). Causality between
    chunk tokens falls out of the same mask: a chunk token never sees a
    younger sibling's freshly written slot.

    ``quantized`` prepends per-token-head fp32 scale refs (same block-table
    indexed layout as K/V) to ``rest``; dequant happens on the VMEM tile,
    so int8 KV never materializes as fp32 in HBM."""
    if quantized:
        ks_ref, vs_ref, o_ref, m_s, l_s, acc_s = rest
    else:
        o_ref, m_s, l_s, acc_s = rest
    b = pl.program_id(0)
    li = pl.program_id(2)                         # logical page index
    pos = pos_ref[b]
    R = q_ref.shape[2]

    @pl.when(li == 0)
    def _init():
        m_s[:] = jnp.full_like(m_s, NEG)
        l_s[:] = jnp.zeros_like(l_s)
        acc_s[:] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)           # (R, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)        # (ps, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)        # (ps, hd)
    if quantized:
        k = k * ks_ref[0]                         # (ps, hd) * (ps, 1)
        v = v * vs_ref[0]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (R, ps)

    qpos = pos + jax.lax.broadcasted_iota(
        jnp.int32, (R, page_size), 0) // group
    kv_pos = li * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (R, page_size), 1)
    valid = kv_pos <= qpos
    s = jnp.where(valid, s, NEG)

    m_prev = m_s[:]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    r = jnp.exp(m_prev - m_new)
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)  # guard all-masked tiles
    l_s[:] = l_s[:] * r + jnp.sum(p, axis=-1, keepdims=True)
    acc_s[:] = acc_s[:] * r + jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_s[:] = m_new

    @pl.when(li == n_pages - 1)
    def _finalize():
        o_ref[0, 0] = (acc_s[:] / jnp.maximum(l_s[:], 1e-30)).astype(o_ref.dtype)


def paged_decode_attn_pallas(q, k_pages, v_pages, block_tables, pos, *,
                             k_scales=None, v_scales=None,
                             interpret: Optional[bool] = None):
    """Paged GQA flash-decode. q: (B, H, hd); k_pages, v_pages:
    (P, ps, KV, hd) page pools; block_tables: (B, MP) int32 physical page
    per logical page; pos: (B,) int32 per-row positions.

    ``k_scales``/``v_scales`` (P, ps, KV) fp32 activate the int8 path:
    pages are dequantized on the VMEM tile (scale pages ride the same
    block-table scalar prefetch), never as fp32 in HBM.

    Returns (B, H, hd) fp32. See ref.paged_decode_attn_ref for the page
    semantics (entries past pos may alias a trash page — masked)."""
    if interpret is None:
        interpret = interpret_mode()
    out = _paged_attn_jit(q[:, None], k_pages, v_pages, block_tables, pos,
                          k_scales, v_scales, interpret=interpret)
    return out[:, 0]


def paged_prefill_attn_pallas(q, k_pages, v_pages, block_tables, pos0, *,
                              k_scales=None, v_scales=None,
                              interpret: Optional[bool] = None):
    """Paged GQA chunk-prefill attention: C chunk tokens per row attend
    causally over the row's pages (history + the chunk's own freshly
    written slots). q: (B, C, H, hd); pos0: (B,) int32 absolute position
    of each row's first chunk token. The block table prefix is expected
    bucketed by the caller (scheduler `_chunk_args` style) so jit keys
    stay stable across chunk counts.

    ``k_scales``/``v_scales`` (P, ps, KV) fp32 activate the int8 path.
    Returns (B, C, H, hd) fp32."""
    if interpret is None:
        interpret = interpret_mode()
    return _paged_attn_jit(q, k_pages, v_pages, block_tables, pos0,
                           k_scales, v_scales, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _paged_attn_jit(q, k_pages, v_pages, block_tables, pos,
                    k_scales, v_scales, *, interpret: bool):
    B, C, H, hd = q.shape
    P, ps, KV, _ = k_pages.shape
    MP = block_tables.shape[1]
    G = H // KV
    R = C * G
    quant = k_scales is not None
    # group query rows by kv-head: row r = chunk token r // G, head r % G
    qr = (q.reshape(B, C, KV, G, hd).transpose(0, 2, 1, 3, 4)
          .reshape(B, KV, R, hd))
    bt_flat = jnp.asarray(block_tables, jnp.int32).reshape(B * MP)

    def kv_map(b, kv, l, bt_ref, pos_ref):
        # dereference the block table: stream only the row's own pages
        phys = bt_ref[b * MP + l]
        return (phys, 0, kv, 0)

    def scale_map(b, kv, l, bt_ref, pos_ref):
        phys = bt_ref[b * MP + l]
        return (phys, 0, kv)

    in_specs = [
        pl.BlockSpec((1, 1, R, hd),
                     lambda b, kv, l, bt_ref, pos_ref: (b, kv, 0, 0)),
        pl.BlockSpec((1, ps, 1, hd), kv_map),
        pl.BlockSpec((1, ps, 1, hd), kv_map),
    ]
    operands = [bt_flat, jnp.asarray(pos, jnp.int32), qr, k_pages, v_pages]
    if quant:
        in_specs += [pl.BlockSpec((1, ps, 1), scale_map),
                     pl.BlockSpec((1, ps, 1), scale_map)]
        operands += [k_scales, v_scales]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, MP),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, R, hd),
                               lambda b, kv, l, bt_ref, pos_ref: (b, kv, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, hd), jnp.float32),
        ],
    )
    kern = functools.partial(_paged_kernel, n_pages=MP, page_size=ps,
                             group=G, scale=hd ** -0.5, quantized=quant)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, R, hd), jnp.float32),
        interpret=interpret,
    )(*operands)
    return (out.reshape(B, KV, C, G, hd).transpose(0, 2, 1, 3, 4)
            .reshape(B, C, H, hd))
