"""Pure-jnp oracle for GQA flash-decode with full / ring KV caches."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def decode_attn_ref(q, k, v, pos, *, window: int = 0, ring: bool = False):
    """One-token GQA decode attention.

    q: (B, H, hd) — query for the current token (already rope'd)
    k, v: (B, S, KV, hd) — cache contents (slot s semantics below)
    pos: scalar int — absolute position of the current token (its K/V is
         already written into the cache)
    window: sliding window size (0 = global)
    ring: if True the cache is a ring buffer (slot s holds the largest
          p ≤ pos with p ≡ s mod S), else slot s holds position s.

    Returns (B, H, hd) fp32.
    """
    B, S, KV, hd = k.shape
    H = q.shape[1]
    G = H // KV

    slots = jnp.arange(S)
    if ring:
        kv_pos = pos - jnp.mod(pos - slots, S)
    else:
        kv_pos = slots
    valid = (kv_pos >= 0) & (kv_pos <= pos)
    if window > 0:
        valid &= (pos - kv_pos) < window

    qr = q.reshape(B, KV, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgh,bskh->bkgs", qr, k.astype(jnp.float32))
    scores = scores * (hd ** -0.5)
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, H, hd)


def _gather_paged_kv(k_pages, v_pages, block_tables, k_scales, v_scales):
    """Gather each row's pages into a contiguous logical fp32 view
    (B, MP*ps, KV, hd), dequantizing int8 pages when scales are given."""
    B, MP = block_tables.shape
    P, ps, KV, hd = k_pages.shape
    k = k_pages[block_tables].reshape(B, MP * ps, KV, hd).astype(jnp.float32)
    v = v_pages[block_tables].reshape(B, MP * ps, KV, hd).astype(jnp.float32)
    if k_scales is not None:
        k = k * k_scales[block_tables].reshape(B, MP * ps, KV)[..., None]
        v = v * v_scales[block_tables].reshape(B, MP * ps, KV)[..., None]
    return k, v


def paged_decode_attn_ref(q, k_pages, v_pages, block_tables, pos, *,
                          k_scales=None, v_scales=None):
    """One-token GQA decode attention over a paged KV pool.

    q: (B, H, hd) — query for the current token (already rope'd)
    k_pages, v_pages: (P, ps, KV, hd) — global page pool; physical page p
        holds ps contiguous token slots of whichever row owns it
    block_tables: (B, MP) int32 — row b's logical page l lives at physical
        page block_tables[b, l]. Entries for pages past a row's current
        position may point anywhere (a shared trash page): validity is
        purely positional, ``kv_pos <= pos[b]``, because the allocator
        only hands out pages covering positions the row will write.
    pos: (B,) int32 — per-row absolute position of the current token
        (its K/V already written into the owning page)
    k_scales, v_scales: optional (P, ps, KV) fp32 per-token-head scales
        for int8 page pools (dequantized before attention).

    Returns (B, H, hd) fp32.
    """
    B, H, hd = q.shape
    P, ps, KV, _ = k_pages.shape
    MP = block_tables.shape[1]
    G = H // KV

    k, v = _gather_paged_kv(k_pages, v_pages, block_tables,
                            k_scales, v_scales)

    kv_pos = jnp.arange(MP * ps)
    valid = kv_pos[None, :] <= jnp.asarray(pos)[:, None]        # (B, S)

    qr = q.reshape(B, KV, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgh,bskh->bkgs", qr, k)
    scores = scores * (hd ** -0.5)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, v)
    return out.reshape(B, H, hd)


def paged_prefill_attn_ref(q, k_pages, v_pages, block_tables, pos0, *,
                           k_scales=None, v_scales=None):
    """Chunk-prefill GQA attention over a paged KV pool.

    q: (B, C, H, hd) — C chunk tokens per row (already rope'd); their K/V
        is already written into the owning pages.
    pos0: (B,) int32 — absolute position of each row's first chunk token;
        chunk token c sits at pos0 + c and attends causally over
        ``kv_pos <= pos0 + c``.
    k_scales, v_scales: optional (P, ps, KV) fp32 per-token-head scales.

    Returns (B, C, H, hd) fp32.
    """
    B, C, H, hd = q.shape
    P, ps, KV, _ = k_pages.shape
    MP = block_tables.shape[1]
    G = H // KV

    k, v = _gather_paged_kv(k_pages, v_pages, block_tables,
                            k_scales, v_scales)

    kv_pos = jnp.arange(MP * ps)
    qpos = jnp.asarray(pos0)[:, None] + jnp.arange(C)[None, :]  # (B, C)
    valid = kv_pos[None, None, :] <= qpos[:, :, None]           # (B, C, S)

    qr = q.reshape(B, C, KV, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bckgh,bskh->bckgs", qr, k)
    scores = scores * (hd ** -0.5)
    scores = jnp.where(valid[:, :, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bckgs,bskh->bckgh", probs, v)
    return out.reshape(B, C, H, hd)
