"""Pure-jnp oracle for GQA flash-decode with full / ring KV caches."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def decode_attn_ref(q, k, v, pos, *, window: int = 0, ring: bool = False):
    """One-token GQA decode attention.

    q: (B, H, hd) — query for the current token (already rope'd)
    k, v: (B, S, KV, hd) — cache contents (slot s semantics below)
    pos: scalar int — absolute position of the current token (its K/V is
         already written into the cache)
    window: sliding window size (0 = global)
    ring: if True the cache is a ring buffer (slot s holds the largest
          p ≤ pos with p ≡ s mod S), else slot s holds position s.

    Returns (B, H, hd) fp32.
    """
    B, S, KV, hd = k.shape
    H = q.shape[1]
    G = H // KV

    slots = jnp.arange(S)
    if ring:
        kv_pos = pos - jnp.mod(pos - slots, S)
    else:
        kv_pos = slots
    valid = (kv_pos >= 0) & (kv_pos <= pos)
    if window > 0:
        valid &= (pos - kv_pos) < window

    qr = q.reshape(B, KV, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgh,bskh->bkgs", qr, k.astype(jnp.float32))
    scores = scores * (hd ** -0.5)
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, H, hd)
