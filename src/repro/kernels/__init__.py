"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel package has:
  kernel.py — pl.pallas_call + BlockSpec VMEM tiling (the TPU target)
  ops.py    — jit'd public wrapper (auto-selects interpret mode off-TPU)
  ref.py    — pure-jnp oracle used by the allclose test sweeps
"""
from __future__ import annotations

import jax

_FORCE_INTERPRET = None


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interpret_mode() -> bool:
    """Pallas interpret=True everywhere except a real TPU backend."""
    if _FORCE_INTERPRET is not None:
        return _FORCE_INTERPRET
    return not on_tpu()
