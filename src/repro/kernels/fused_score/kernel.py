"""Fused KAPPA score kernel: KL(p‖q) + confidence + entropy in ONE pass
over the vocabulary.

Why a kernel: KAPPA scores every live branch at every decode step. The
naive path reads the (N, V) logits row four times (max, sum-exp, KL
reduction, entropy reduction); with V up to 262k (gemma3) that's 4×
HBM traffic on a purely memory-bound op. The fused kernel streams each
logits row through VMEM once, maintaining online-softmax statistics:

  m   — running max
  l   — running Σ exp(x−m)
  ax  — running Σ exp(x−m)·x
  alq — running Σ exp(x−m)·log q

from which (identities used below):
  log Z = m + log l
  Σ p·x   = ax / l
  KL      = (Σ p·x − log Z) − alq / l
  entropy = log Z − Σ p·x
  conf    = exp(global_max − log Z) = 1 / l   (m == global max at the end)

Grid: (B/TB, V/TV) with the vocab axis innermost (sequential on TPU);
accumulators live in VMEM scratch; outputs written on the last vocab tile.
Tile defaults (TB=8, TV=2048 fp32) keep the working set ≈ 8·2048·4B =
64 KiB ≪ 16 MiB VMEM while the lane dim (2048) is a multiple of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import interpret_mode

NEG = -1e30


def _kernel(x_ref, lq_ref, kl_ref, conf_ref, ent_ref,
            m_s, l_s, ax_s, alq_s, *, n_v_tiles: int):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        m_s[:] = jnp.full_like(m_s, NEG)
        l_s[:] = jnp.zeros_like(l_s)
        ax_s[:] = jnp.zeros_like(ax_s)
        alq_s[:] = jnp.zeros_like(alq_s)

    x = x_ref[:].astype(jnp.float32)           # (TB, TV)
    lq = lq_ref[:].astype(jnp.float32)         # (1, TV)

    m_prev = m_s[:]                            # (TB, 1)
    m_tile = jnp.max(x, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_tile)
    scale = jnp.exp(m_prev - m_new)

    e = jnp.exp(x - m_new)                     # (TB, TV)
    l_s[:] = l_s[:] * scale + jnp.sum(e, axis=-1, keepdims=True)
    ax_s[:] = ax_s[:] * scale + jnp.sum(e * x, axis=-1, keepdims=True)
    alq_s[:] = alq_s[:] * scale + jnp.sum(e * lq, axis=-1, keepdims=True)
    m_s[:] = m_new

    @pl.when(vi == n_v_tiles - 1)
    def _finalize():
        m = m_s[:]
        l = l_s[:]
        log_z = m + jnp.log(l)
        mean_x = ax_s[:] / l
        mean_lq = alq_s[:] / l
        kl_ref[:] = (mean_x - log_z) - mean_lq
        ent_ref[:] = log_z - mean_x
        conf_ref[:] = 1.0 / l


def fused_score_pallas(logits, log_q, *, tile_b: int = 8, tile_v: int = 2048,
                       interpret=None):
    """logits: (B, V); log_q: (V,) fp32 → (kl, conf, ent) each (B,) fp32.

    B and V are padded to tile multiples inside (pad rows are discarded;
    pad vocab entries use −inf logits so they contribute nothing).

    ``interpret=None`` resolves via :func:`repro.kernels.interpret_mode`
    so direct callers never run the Pallas interpreter on a real TPU."""
    if interpret is None:
        interpret = interpret_mode()
    return _fused_score_jit(logits, log_q, tile_b=tile_b, tile_v=tile_v,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("tile_b", "tile_v", "interpret"))
def _fused_score_jit(logits, log_q, *, tile_b: int, tile_v: int,
                     interpret: bool):
    B, V = logits.shape
    tb = min(tile_b, max(B, 1))
    tv = min(tile_v, V)
    Bp = -(-B // tb) * tb
    Vp = -(-V // tv) * tv
    if Bp != B or Vp != V:
        logits = jnp.pad(logits, ((0, Bp - B), (0, Vp - V)),
                         constant_values=NEG)
        log_q = jnp.pad(log_q, (0, Vp - V), constant_values=0.0)
    lq2 = log_q.reshape(1, Vp).astype(jnp.float32)
    n_v = Vp // tv

    kl, conf, ent = pl.pallas_call(
        functools.partial(_kernel, n_v_tiles=n_v),
        grid=(Bp // tb, n_v),
        in_specs=[
            pl.BlockSpec((tb, tv), lambda b, v: (b, v)),
            pl.BlockSpec((1, tv), lambda b, v: (0, v)),
        ],
        out_specs=[
            pl.BlockSpec((tb, 1), lambda b, v: (b, 0)),
            pl.BlockSpec((tb, 1), lambda b, v: (b, 0)),
            pl.BlockSpec((tb, 1), lambda b, v: (b, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((Bp, 1), jnp.float32)] * 3,
        scratch_shapes=[pltpu.VMEM((tb, 1), jnp.float32)] * 4,
        interpret=interpret,
    )(logits, lq2)
    return kl[:B, 0], conf[:B, 0], ent[:B, 0]
