"""Pure-jnp oracle for the fused KAPPA score kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_score_ref(logits, log_q):
    """logits: (B, V) any float dtype; log_q: (V,) fp32.
    Returns (kl, conf, ent) each (B,) fp32 where p = softmax(logits):
      kl   = Σ p (log p − log q)
      conf = max p
      ent  = −Σ p log p
    """
    log_p = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    p = jnp.exp(log_p)
    kl = jnp.sum(p * (log_p - log_q[None, :]), axis=-1)
    conf = jnp.exp(jnp.max(log_p, axis=-1))
    ent = -jnp.sum(p * log_p, axis=-1)
    return kl, conf, ent
