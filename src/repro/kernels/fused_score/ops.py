"""Public wrapper for the fused score kernel."""
from __future__ import annotations

from repro.kernels import interpret_mode
from repro.kernels.fused_score.kernel import fused_score_pallas


def fused_score(logits, log_q, *, tile_b: int = 8, tile_v: int = 2048):
    """(kl, conf, ent) from one VMEM pass. See kernel.py."""
    return fused_score_pallas(logits, log_q, tile_b=tile_b, tile_v=tile_v,
                              interpret=interpret_mode())
