"""Msgpack-based checkpointing (orbax is not available offline)."""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack(obj: Any):
    leaves, treedef = jax.tree.flatten(obj)
    blobs = []
    for leaf in leaves:
        arr = np.asarray(leaf)
        blobs.append({"dtype": str(arr.dtype), "shape": list(arr.shape),
                      "data": arr.tobytes()})
    return blobs, treedef


def save(path: str, tree: Any) -> None:
    blobs, _ = _pack(tree)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(blobs, use_bin_type=True))
    os.replace(tmp, path)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype-checked)."""
    with open(path, "rb") as f:
        blobs = msgpack.unpackb(f.read(), raw=False)
    leaves, treedef = jax.tree.flatten(like)
    assert len(blobs) == len(leaves), \
        f"checkpoint has {len(blobs)} leaves, expected {len(leaves)}"
    out = []
    for blob, leaf in zip(blobs, leaves):
        arr = np.frombuffer(blob["data"], dtype=np.dtype(blob["dtype"]))
        arr = arr.reshape(blob["shape"])
        assert tuple(arr.shape) == tuple(np.shape(leaf)), \
            f"shape mismatch {arr.shape} vs {np.shape(leaf)}"
        out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out)
