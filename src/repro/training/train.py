"""Training substrate: masked-LM loss + jittable train_step.

``train_step`` (here, shape-polymorphic over batch) is also the dry-run
lowering target for the ``train_4k`` input shape.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import train_logits
from repro.training.optimizer import (
    AdamWState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_lr,
)


class TrainState(NamedTuple):
    params: object
    opt: AdamWState


def init_train_state(rng, cfg: ModelConfig) -> TrainState:
    from repro.models import init_params
    params = init_params(rng, cfg)
    return TrainState(params=params, opt=adamw_init(params))


def lm_loss(params, cfg: ModelConfig, tokens, loss_mask, frontend=None,
            aux_weight: float = 0.01):
    """Next-token cross-entropy over masked positions + MoE aux loss."""
    logits, aux = train_logits(params, cfg, tokens, frontend)
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    m = loss_mask[:, :-1]
    loss = jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return loss + aux_weight * aux, (loss, aux)


@functools.partial(jax.jit, static_argnums=(1,), donate_argnums=(0,))
def train_step(state: TrainState, cfg: ModelConfig, tokens, loss_mask,
               step, frontend=None, *, base_lr: float = 3e-3,
               warmup: int = 50, total: int = 2000):
    (total_loss, (loss, aux)), grads = jax.value_and_grad(
        lm_loss, has_aux=True)(state.params, cfg, tokens, loss_mask, frontend)
    grads, gnorm = clip_by_global_norm(grads, 1.0)
    lr = cosine_lr(step, base_lr=base_lr, warmup=warmup, total=total)
    params, opt = adamw_update(grads, state.opt, state.params, lr=lr)
    return TrainState(params, opt), {"loss": loss, "aux": aux,
                                     "gnorm": gnorm, "lr": lr}


def train_step_fn(cfg: ModelConfig, base_lr: float = 3e-3,
                  warmup: int = 50, total: int = 2000):
    """Non-jitted closure version (for pjit wrapping in launch/train.py)."""
    def fn(state: TrainState, tokens, loss_mask, step, frontend=None):
        (tl, (loss, aux)), grads = jax.value_and_grad(
            lm_loss, has_aux=True)(state.params, cfg, tokens, loss_mask, frontend)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        lr = cosine_lr(step, base_lr=base_lr, warmup=warmup, total=total)
        params, opt = adamw_update(grads, state.opt, state.params, lr=lr)
        return TrainState(params, opt), {"loss": loss, "aux": aux,
                                         "gnorm": gnorm, "lr": lr}
    return fn
