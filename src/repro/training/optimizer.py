"""AdamW + schedules, pure JAX (no optax dependency in this container)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: object
    nu: object


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), p)
    return AdamWState(step=jnp.int32(0), mu=zeros(params), nu=zeros(params))


def adamw_update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.01):
    step = state.step + 1
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                      state.nu, grads)
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step=step, mu=mu, nu=nu)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def cosine_lr(step, *, base_lr: float, warmup: int, total: int,
              min_frac: float = 0.1):
    s = step.astype(jnp.float32)
    warm = base_lr * s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(s < warmup, warm, cos)
