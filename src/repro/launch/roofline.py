"""Roofline-term extraction from compiled dry-run artifacts.

  compute term    = per_chip_HLO_FLOPs / peak_FLOP/s
  memory term     = per_chip_HLO_bytes / HBM_bw
  collective term = per_chip_collective_bytes / link_bw

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(). IMPORTANT:
under SPMD the compiled executable is the PER-DEVICE program, so
cost_analysis numbers are already per-chip — the roofline terms divide by
per-chip peaks only (empirically verified: rwkv6-3b decode flops match
the analytic per-chip estimate ×~3 remat factor, not the global one).
Collective bytes are NOT in cost_analysis — we parse the post-SPMD HLO
text and sum the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op (also per-device).
Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

from repro.configs.base import TPU_HBM_BW, TPU_ICI_BW, TPU_PEAK_FLOPS

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g.:  %all-gather.3 = bf16[2,1024,512]{2,1,0} all-gather(
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([\d,]*)\][^)]*?\s(" + "|".join(_COLLECTIVES) + r")\(")
# tuple-result collectives:  = (f32[8,128], f32[8,128]) all-reduce(
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]+)\)\s*(" + "|".join(_COLLECTIVES) + r")\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-kind collective result bytes summed over the module."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        m = _OP_RE.search(line)
        if m:
            out[m.group(3)] += _shape_bytes(m.group(1), m.group(2))
            continue
        mt = _TUPLE_RE.search(line)
        if mt:
            total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(mt.group(1)))
            out[mt.group(2)] += total
    return out


@dataclass
class Roofline:
    flops: float
    hbm_bytes: float          # raw HLO "bytes accessed" (overcounts copies)
    coll_bytes: float
    chips: int
    model_flops: float = 0.0
    argio_bytes: float = 0.0  # per-chip argument+output bytes — the HBM floor
    coll_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.flops / TPU_PEAK_FLOPS          # flops are per-chip

    @property
    def memory_s(self) -> float:
        """HBM floor: every argument (params + cache) must be read and
        outputs written once per step. The raw HLO bytes-accessed number
        (``memory_hlo_s``) overcounts functional cache updates ~L× (each
        layer's full-cache copy counts even when buffer donation makes it
        in-place on TPU), so the floor is the roofline-relevant term."""
        return self.argio_bytes / TPU_HBM_BW

    @property
    def memory_hlo_s(self) -> float:
        return self.hbm_bytes / TPU_HBM_BW

    @property
    def collective_s(self) -> float:
        # per-device collective bytes cross ICI; conservative single-link bw
        return self.coll_bytes / TPU_ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (per-chip HLO_FLOPs × chips) — <1 means the
        compiled program does MORE than the analytic minimum (remat,
        redundant compute); >1 means XLA undercounts (uncounted scans)."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    def summary(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "argio_bytes": self.argio_bytes,
            "coll_bytes": self.coll_bytes, "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "memory_hlo_s": self.memory_hlo_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_flops_ratio,
            "coll_by_kind": self.coll_by_kind,
        }


def from_compiled(compiled, chips: int, model_flops: float) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byt = float(ca.get("bytes accessed", 0.0))
    argio = 0.0
    try:
        ma = compiled.memory_analysis()
        argio = float(getattr(ma, "argument_size_in_bytes", 0) or 0) \
            + float(getattr(ma, "output_size_in_bytes", 0) or 0)
    except Exception:
        argio = byt
    try:
        text = compiled.as_text()
    except Exception:
        text = ""
    cb = collective_bytes(text)
    return Roofline(flops=flops, hbm_bytes=byt, coll_bytes=float(sum(cb.values())),
                    chips=chips, model_flops=model_flops, coll_by_kind=cb,
                    argio_bytes=argio)
