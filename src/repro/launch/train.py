"""Training launcher: real (CPU-scale) training of any assigned arch's
reduced variant on the synthetic CoT task, with pjit over an available
mesh and msgpack checkpointing.

  PYTHONPATH=src python -m repro.launch.train --arch deepseek-r1-distill-qwen-1.5b \
      --steps 1200 --batch 64 --out ckpt.msgpack
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import tasks
from repro.data import tokenizer as tok
from repro.launch.mesh import make_host_mesh
from repro.training import checkpoint
from repro.training.train import init_train_state, train_step


def train_loop(arch: str, *, steps: int = 1200, batch: int = 64,
               seq_len: int = 32, d_model: int = 256, num_layers: int = 2,
               seed: int = 0, out: str | None = None,
               dataset_kw: dict | None = None, log_every: int = 200,
               base_lr: float = 3e-3, verbose: bool = True):
    """Returns (cfg, trained params)."""
    cfg = get_config(arch).reduced(num_layers=num_layers, d_model=d_model,
                                   vocab_size=tok.VOCAB_SIZE)
    rng = jax.random.PRNGKey(seed)
    state = init_train_state(rng, cfg)
    dkw = dict(min_steps=2, max_steps=5, num_ops=2, max_operand=10)
    dkw.update(dataset_kw or {})
    data = tasks.make_dataset(seed, 16384, **dkw)

    from repro.models.frontends import stub_frontend
    fe = stub_frontend(jax.random.PRNGKey(1), cfg, batch)

    t0 = time.time()
    for step in range(steps):
        probs = [data[(step * batch + i) % len(data)] for i in range(batch)]
        toks, mask = tasks.pack_batch(probs, seq_len)
        state, metrics = train_step(state, cfg, jnp.asarray(toks),
                                    jnp.asarray(mask), jnp.int32(step),
                                    fe, total=steps, base_lr=base_lr)
        if verbose and (step % log_every == 0 or step == steps - 1):
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} ({time.time()-t0:.0f}s)",
                  flush=True)
    if out:
        checkpoint.save(out, state.params)
        if verbose:
            print(f"saved params -> {out}")
    return cfg, state.params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=1200)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    train_loop(args.arch, steps=args.steps, batch=args.batch,
               d_model=args.d_model, num_layers=args.layers,
               seed=args.seed, out=args.out)


if __name__ == "__main__":
    main()
