"""Sharding rules: logical roles → PartitionSpec, with divisibility-aware
fallbacks.

Weights shard on the "model" axis; batch-bearing activations shard on
("pod","data"). Rules are keyed on parameter path names (the same
rule-table approach as MaxText's logical axis rules):

  embed/unembed (V, d)     : vocab on model, else d_model on model
  attn wq/wk/wv (d, P)     : projection dim on model (tensor parallel)
  attn wo      (P, d)      : contraction dim on model
  mlp wg/wu    (d, ff)     : ff on model;  wd (ff, d): ff on model
  moe experts  (E, d, ff)  : E on model if divisible (expert parallel),
                             else ff on model (per-expert tensor parallel)
  rwkv6/rglru square mats  : output dim on model (w_o: input dim)
  norms / scalars / small loras: replicated

Every rule checks divisibility against the mesh axis size and falls back
to replication — required because the assigned archs include
non-divisible extents (granite vocab 49155, 40 experts, qwen1.5 H=20...).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _div(extent: int, mesh: Mesh, axis: str) -> bool:
    return extent % _axis_size(mesh, axis) == 0


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return "/".join(parts)


def param_spec(path: str, shape: tuple, mesh: Mesh, cfg: ModelConfig) -> P:
    """PartitionSpec for one parameter leaf (leading stack dims allowed)."""
    ms = _axis_size(mesh, "model")
    nd = len(shape)

    def spec_last(axis="model"):
        """Shard the last dim."""
        if _div(shape[-1], mesh, axis):
            return P(*([None] * (nd - 1) + [axis]))
        return P()

    def spec_dim(i, axis="model"):
        if _div(shape[i], mesh, axis):
            s = [None] * nd
            s[i] = axis
            return P(*s)
        return P()

    name = path.split("/")[-1]
    # ---- embeddings: prefer vocab sharding, fall back to d_model
    if name in ("embed", "unembed"):
        if _div(shape[0], mesh, "model"):
            return P("model", None)
        if _div(shape[1], mesh, "model"):
            return P(None, "model")
        return P()
    # ---- MoE experts: (…, E, d, ff) / (…, E, ff, d)
    if "ffn" in path and name in ("wg", "wu", "wd") and cfg.is_moe:
        e_dim = nd - 3
        if _div(shape[e_dim], mesh, "model"):
            return spec_dim(e_dim)                   # expert parallel
        # tensor parallel inside each expert: shard the ff dim
        ff_dim = nd - 1 if name in ("wg", "wu") else nd - 2
        return spec_dim(ff_dim)
    if name == "router":
        return P()
    # ---- dense mlp
    if name in ("wg", "wu"):
        return spec_last()
    if name == "wd":
        return spec_dim(nd - 2)
    # ---- attention
    if name in ("wq", "wk", "wv"):
        return spec_last()
    if name in ("bq", "bk", "bv"):
        return spec_last()
    if name == "wo":
        return spec_dim(nd - 2)
    # ---- rwkv6 time-mix / channel-mix
    if name in ("w_r", "w_k", "w_v", "w_g", "cm_wk", "cm_wr"):
        return spec_last()
    if name in ("w_o", "cm_wv"):
        return spec_dim(nd - 2)
    if name in ("u", "gn_scale", "w0", "mu", "cm_mu_k", "cm_mu_r",
                "lora_b", "wb"):
        return spec_last()
    if name in ("lora_a", "wa"):
        return P()
    # ---- rg-lru
    if name in ("w_gx", "w_gy", "w_i", "w_r_g"):
        return spec_last()
    if name == "w_out":
        return spec_dim(nd - 2)
    if name in ("lam", "conv"):
        return spec_last()
    # ---- norms etc.
    return P()


def params_shardings(params_shapes, mesh: Mesh, cfg: ModelConfig):
    """Pytree of NamedSharding matching a pytree of ShapeDtypeStructs."""
    def leaf(path, x):
        spec = param_spec(_path_str(path), tuple(x.shape), mesh, cfg)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(leaf, params_shapes)


def batch_spec(mesh: Mesh, extra_dims: int = 1) -> P:
    """Batch-leading activation spec: batch over (pod, data)."""
    dp = tuple(n for n in ("pod", "data") if n in mesh.axis_names)
    return P(dp, *([None] * extra_dims))


def cache_shardings(cache_shapes, mesh: Mesh, cfg: ModelConfig,
                    *, seq_shard: bool = False):
    """KV/state cache shardings, keyed on leaf names.

    Stacked leaves are (K, B, ...): batch at axis 1; rem leaves (B, ...).

    Attention k/v caches (…, B, S, KV, hd): batch on (pod,data); the
    SEQUENCE dim shards on "model" — distributed flash-decode: XLA lowers
    softmax/contraction over the sharded seq axis into all-reduces of the
    per-shard (max, sumexp, partial-V) stats, which are O(B·H·hd), instead
    of all-gathering the multi-GB cache (measured: granite-3-8b decode
    dropped from 86 GB to ~MB-scale collectives per step). KV-head
    sharding is NOT used: 7/10 assigned archs have kv < 16.

    ``seq_shard=True`` (long_500k, batch=1): seq shards on ("data","model")
    so the 512k cache spreads over the whole pod.

    Recurrent/rwkv6 state leaves shard their feature dim on "model"
    (matching the w_o/w_out contraction sharding).
    """
    dp = tuple(n for n in ("pod", "data") if n in mesh.axis_names)
    dp_size = int(np.prod([_axis_size(mesh, n) for n in dp]))

    def leaf(path, x):
        shape = tuple(x.shape)
        pstr = _path_str(path)
        name = pstr.split("/")[-1]
        stacked = pstr.split("/", 1)[0].endswith("stack")
        b_ax = 1 if stacked else 0
        spec = [None] * len(shape)
        if shape[b_ax] % dp_size == 0 and shape[b_ax] >= dp_size:
            spec[b_ax] = dp

        if name in ("k", "v", "k_s", "v_s") and len(shape) >= b_ax + 3:
            s_ax = b_ax + 1
            if "xkv" in pstr:
                return NamedSharding(mesh, P(*spec))  # enc K/V: 1500 — batch only
            if seq_shard:
                axes = tuple(a for a in ("data", "model")
                             if shape[s_ax] % _axis_size(mesh, a) == 0)
                if axes and shape[s_ax] % int(np.prod(
                        [_axis_size(mesh, a) for a in axes])) == 0:
                    spec[s_ax] = axes if len(axes) > 1 else axes[0]
            elif _div(shape[s_ax], mesh, "model"):
                spec[s_ax] = "model"
            return NamedSharding(mesh, P(*spec))

        if name == "S":          # rwkv6 state (…, B, H, hd_k, hd_v)
            if _div(shape[-1], mesh, "model"):
                spec[-1] = "model"
            return NamedSharding(mesh, P(*spec))
        if name in ("x_tm", "x_cm", "h", "conv"):
            if _div(shape[-1], mesh, "model"):
                spec[-1] = "model"
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(leaf, cache_shapes)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
