"""Serving launcher: run Greedy / BoN / ST-BoN / KAPPA over synthetic
task prompts with a trained (or fresh) model and print the paper's
metric columns.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b \
      --method kappa --n 5 --problems 20 [--ckpt ckpt.msgpack]

``--scheduler`` serves the same prompts through the continuous-batching
row pool (repro.serving.scheduler) instead of one at a time, and adds
throughput columns (requests/s, tokens/s, row utilization).

``--frontend`` drives the same pool through the async streaming
front-end (repro.serving.frontend) instead of batch ``run()``: every
request is submitted and consumed as a concurrent event stream;
``--stream`` additionally asserts each stream's tokens reassemble the
terminal result exactly (the §9 equivalence contract, end to end).
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import KappaConfig
from repro.data import tasks
from repro.data import tokenizer as tok
from repro.models import init_params
from repro.models.frontends import stub_frontend
from repro.serving import engine
from repro.serving import faults as faults_lib
from repro.serving import strategies
from repro.serving.frontend import ServingFrontend
from repro.serving.scheduler import ContinuousBatchingScheduler, PagedScheduler
from repro.training import checkpoint

METHODS = {
    "greedy": engine.generate_greedy,
    "bon": engine.generate_bon,
    "stbon": engine.generate_stbon,
    "kappa": engine.generate_kappa,
}


def _strategy_factory(method: str, kcfg: KappaConfig):
    if method == "stbon":
        # ST-BoN's fixed buffer window scales with the gating horizon so
        # truncation happens well before EOS at toy sequence lengths
        return lambda: strategies.STBoNStrategy(
            buffer_window=max(2, kcfg.horizon))
    return lambda: strategies.make_strategy(method)


def _serve_frontend(sched, test, *, deadline_s, stream: bool):
    """Drive every prompt through the async streaming front-end
    concurrently; returns results in submission order. With ``stream``,
    asserts each stream's token events reassemble the terminal result
    exactly (committed-prefix + terminal-flush contract)."""

    async def go():
        t0 = sched.clock()
        async with ServingFrontend(sched) as fe:

            async def one(i, prob):
                toks, res = [], None
                async for ev in fe.submit_stream(
                        np.array(prob.prompt), jax.random.PRNGKey(i),
                        deadline_s=deadline_s):
                    if ev.kind == "token":
                        toks.append(ev.token)
                    else:
                        res = ev.result
                if stream:
                    assert toks == res.tokens, \
                        f"rid stream diverged from result ({res.status})"
                return res

            gens = await asyncio.gather(
                *[one(i, p) for i, p in enumerate(test)])
        # no batch run() ran, so stamp elapsed for throughput() ourselves
        sched.elapsed = sched.clock() - t0
        return gens

    return asyncio.run(go())


def serve_eval(arch: str, method: str, *, n: int = 5, problems: int = 20,
               ckpt: str | None = None, d_model: int = 256,
               num_layers: int = 2, seed: int = 999, max_new: int = 48,
               kcfg_kw: dict | None = None, dataset_kw: dict | None = None,
               params=None, cfg=None, verbose: bool = True,
               scheduler: bool = False, sched_rows: int | None = None,
               paged: bool = False, page_size: int = 64,
               num_pages: int | None = None,
               prefill_chunk: int | None = None,
               prefix_cache: bool = False,
               inject_faults: str | None = None,
               max_queue: int | None = None,
               deadline_s: float | None = None,
               frontend_serve: bool = False,
               stream: bool = False,
               kv_dtype: str = "model") -> dict:
    if cfg is None:
        cfg = get_config(arch).reduced(num_layers=num_layers, d_model=d_model,
                                       vocab_size=tok.VOCAB_SIZE)
    if kv_dtype != "model":
        # quantized KV pages: the paged pool stores int8 values + fp32
        # per-token-head scales and halves HBM per page vs bf16/fp32
        cfg = dataclasses.replace(cfg, kv_cache_dtype=kv_dtype)
    if params is None:
        params = init_params(jax.random.PRNGKey(0), cfg)
        if ckpt:
            params = checkpoint.restore(ckpt, params)

    kw = dict(num_branches=n, max_new_tokens=max_new, max_cutoff=6,
              horizon=8, window=8, mom_buckets=4)
    kw.update(kcfg_kw or {})
    kcfg = KappaConfig(**kw)
    # paged pool / streaming front-end both imply the scheduler path
    scheduler = scheduler or paged or frontend_serve
    dkw = dict(min_steps=2, max_steps=5, num_ops=2, max_operand=10)
    dkw.update(dataset_kw or {})
    test = tasks.make_dataset(seed, problems, **dkw)

    fe = stub_frontend(jax.random.PRNGKey(1), cfg, 1)
    factory = _strategy_factory(method, kcfg)
    t0 = time.time()
    if scheduler:
        n_prefix = engine._n_prefix(cfg)
        max_seq = max(len(p.prompt) for p in test) + max_new + n_prefix
        fan_out = factory().rows(kcfg)
        plan = (faults_lib.parse_fault_spec(inject_faults)
                if inject_faults else None)
        sched_kw = dict(rows=sched_rows or 2 * fan_out, max_seq=max_seq,
                        method=method, eos_id=tok.EOS, bos_id=tok.BOS,
                        frontend=fe, strategy_factory=factory,
                        prefill_chunk=prefill_chunk, faults=plan,
                        max_queue=max_queue)
        if paged:
            sched = PagedScheduler(params, cfg, kcfg, page_size=page_size,
                                   num_pages=num_pages,
                                   prefix_cache=prefix_cache, **sched_kw)
        else:
            sched = ContinuousBatchingScheduler(params, cfg, kcfg, **sched_kw)
        if frontend_serve:
            gens = _serve_frontend(sched, test, deadline_s=deadline_s,
                                   stream=stream)
        else:
            rids = [sched.submit(np.array(prob.prompt),
                                 jax.random.PRNGKey(i),
                                 deadline_s=deadline_s)
                    for i, prob in enumerate(test)]
            res = sched.run()
            gens = [res[rid] for rid in rids]
    else:
        gens = []
        for i, prob in enumerate(test):
            strategy = factory()
            gens.append(engine._decode_loop(
                params, cfg, kcfg, np.array(prob.prompt),
                jax.random.PRNGKey(i), strategy, eos_id=tok.EOS,
                bos_id=tok.BOS, frontend=fe))

    acc = lt = ct = 0
    fbt = 0.0
    peak = 0
    for prob, r in zip(test, gens):
        acc += tasks.check_answer(r.tokens, prob)
        lt += r.logical_tokens
        ct += r.compute_tokens
        fbt += len(r.tokens)
        peak = max(peak, r.peak_cache_bytes)
    out = {
        "arch": arch, "method": method, "n": n,
        "accuracy": acc / len(test),
        "final_branch_tokens": fbt / len(test),
        "total_tokens": lt / len(test),
        "compute_tokens": ct / len(test),
        "peak_memory_mb": peak / 1e6,
        "time_s": time.time() - t0,
    }
    if scheduler:
        tp = sched.throughput()
        out.update({
            "tokens_per_s": tp["tokens_per_s"],
            "requests_per_s": tp["requests_per_s"],
            "row_utilization": tp["row_utilization"],
            "ticks": tp["ticks"],
            "status_counts": tp["status_counts"],
            "retries": tp["retries"],
            "failures": tp["failures"],
            "timeouts": tp["timeouts"],
            "shed": tp["shed"],
            "cancelled": tp["cancelled"],
            "faults_injected": tp["faults_injected"],
        })
        out["ttft_p99_s"] = tp["ttft_p99_s"]
        out["itl_p99_s"] = tp["itl_p99_s"]
        # goodput: OK tokens per wall second — comparable between the
        # batch run() path and the streaming front-end path
        ok_tokens = sum(r.logical_tokens for r in gens if r.status == "OK")
        out["goodput_tokens_per_s"] = ok_tokens / max(tp["time_s"], 1e-9)
        if paged:
            out["page_utilization"] = tp["page_utilization"]
            out["page_peak"] = tp["page_peak"]
            out["preemptions"] = tp["preemptions"]
            if prefix_cache:
                out["prefix_hit_rate"] = tp["prefix_hit_rate"]
                out["prefix_tokens_saved"] = tp["prefix_tokens_saved"]
                out["prefix_evictions"] = tp["prefix_evictions"]
                out["prefix_pinned_pages"] = tp["prefix_pinned_pages"]
    if verbose:
        line = (f"{arch} {method:7s} N={n:3d} acc={out['accuracy']:.3f} "
                f"total_toks={out['total_tokens']:8.1f} "
                f"peak={out['peak_memory_mb']:8.3f}MB t={out['time_s']:.1f}s")
        if scheduler:
            mode = "frontend" if frontend_serve else "sched"
            line += (f" | {mode}: {out['tokens_per_s']:.1f} tok/s "
                     f"goodput={out['goodput_tokens_per_s']:.1f} tok/s "
                     f"{out['requests_per_s']:.2f} req/s "
                     f"util={out['row_utilization']:.2f}")
        if paged and prefix_cache:
            line += (f" | prefix: hit={out['prefix_hit_rate']:.2f} "
                     f"saved={out['prefix_tokens_saved']} "
                     f"evict={out['prefix_evictions']} "
                     f"pinned={out['prefix_pinned_pages']}")
        print(line)
        if scheduler:
            # per-terminal-status summary — every submission lands in
            # exactly one of these buckets (DESIGN.md §8)
            sc = out["status_counts"]
            print("  status: "
                  + " ".join(f"{k}={sc.get(k, 0)}" for k in
                             ("OK", "CANCELLED", "TIMEOUT", "FAILED",
                              "SHED"))
                  + f" | retries={out['retries']} "
                    f"faults_injected={out['faults_injected']}")
    if scheduler and inject_faults:
        # chaos-smoke contract (CI): faults actually fired, the run
        # survived, and nothing leaked — pages all free, no pins left
        assert out["faults_injected"] > 0, \
            "fault plan injected nothing — raise its probabilities"
        assert out["retries"] > 0, "no fault-triggered retries recorded"
        if paged:
            if sched.pcache is not None:
                sched.pcache.drop()
            assert sched.alloc.free_count == sched.num_pages, \
                f"leaked pages: {sched.num_pages - sched.alloc.free_count}"
            assert int(sched.alloc.pinned.sum()) == 0, "leaked pins"
        if verbose:
            print("  chaos smoke: zero leaked pages/pins, "
                  f"{out['retries']} retries survived")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--method", default="kappa", choices=sorted(METHODS))
    ap.add_argument("--n", type=int, default=5)
    ap.add_argument("--problems", type=int, default=20)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--scheduler", action="store_true",
                    help="serve through the continuous-batching row pool")
    ap.add_argument("--rows", type=int, default=None,
                    help="pool rows for --scheduler (default 2x fan-out)")
    ap.add_argument("--paged", action="store_true",
                    help="use the paged KV pool scheduler (implies --scheduler)")
    ap.add_argument("--page-size", type=int, default=64,
                    help="token slots per KV page for --paged")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="allocatable KV pages for --paged (default: no "
                         "page pressure, rows*max_seq/page_size)")
    ap.add_argument("--kv-dtype", default="model",
                    choices=("model", "int8"),
                    help="KV cache dtype: 'model' keeps the model dtype; "
                         "'int8' quantizes KV pages (per-token-head fp32 "
                         "scales, in-kernel dequant) for ~2x pages per "
                         "HBM byte")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="enable the cross-request radix prefix cache "
                         "(--paged only): admissions alias previously "
                         "published prompt/winner pages and skip their "
                         "prefill")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked-prefill size: admissions advance this "
                         "many prompt tokens per tick interleaved with "
                         "decode instead of one blocking whole-prompt "
                         "prefill (scheduler paths only)")
    ap.add_argument("--inject-faults", default=None, metavar="SPEC",
                    help="seeded fault injection for chaos smoke runs, "
                         "e.g. 'seed:7' or 'seed:7,step:0.1,alloc:0.2' "
                         "(scheduler paths only); asserts zero leaked "
                         "pages/pins and nonzero retries on completion")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded admission queue: submissions beyond "
                         "this depth are shed with a SHED result")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock deadline; expired "
                         "requests truncate to a TIMEOUT result")
    ap.add_argument("--frontend", action="store_true",
                    help="drive the pool through the async streaming "
                         "front-end (concurrent per-request event "
                         "streams) instead of batch run(); implies "
                         "--scheduler")
    ap.add_argument("--stream", action="store_true",
                    help="with --frontend: assert every stream's token "
                         "events reassemble its terminal result exactly")
    args = ap.parse_args(argv)
    serve_eval(args.arch, args.method, n=args.n, problems=args.problems,
               ckpt=args.ckpt, max_new=args.max_new,
               scheduler=args.scheduler or args.paged, sched_rows=args.rows,
               paged=args.paged, page_size=args.page_size,
               num_pages=args.num_pages, prefill_chunk=args.prefill_chunk,
               prefix_cache=args.prefix_cache,
               inject_faults=args.inject_faults, max_queue=args.max_queue,
               deadline_s=args.deadline_s,
               frontend_serve=args.frontend or args.stream,
               stream=args.stream, kv_dtype=args.kv_dtype)


if __name__ == "__main__":
    main()
