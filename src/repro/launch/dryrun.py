import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "3")  # silence SPMD chatter

"""Multi-pod dry-run: prove the distribution config lowers + compiles for
every (architecture × input shape × mesh) — no real allocation, only
ShapeDtypeStructs.

  train_4k    → train_step   (grads + AdamW update)
  prefill_32k → prefill      (prompt processing, cache fill)
  decode_32k  → serve_step   (ONE token, 32k KV, KAPPA scoring+sampling)
  long_500k   → serve_step   (ONE token, 512k cache, batch 1; sequence-
                              sharded cache — sub-quadratic archs only)

Usage:
  python -m repro.launch.dryrun --arch gemma3-4b --shape decode_32k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""
import argparse
import functools
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, applicable_shapes, get_config
from repro.configs.base import KappaConfig, ModelConfig
from repro.core import kappa as kappa_lib
from repro.launch import shardings as sh
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import Roofline, from_compiled


def _struct(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _frontend_struct(cfg: ModelConfig, batch: int):
    if cfg.frontend is None:
        return None
    return _struct((batch, cfg.frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype))


def input_specs(cfg: ModelConfig, shape_name: str, kcfg: KappaConfig):
    """ShapeDtypeStruct stand-ins for every input of the lowered fn."""
    from repro.models import init_cache, init_params
    from repro.training.train import init_train_state

    spec = INPUT_SHAPES[shape_name]
    B, S = spec.global_batch, spec.seq_len

    if spec.kind == "train":
        state = jax.eval_shape(
            functools.partial(init_train_state, cfg=cfg), jax.random.PRNGKey(0))
        return {
            "state": state,
            "tokens": _struct((B, S), jnp.int32),
            "loss_mask": _struct((B, S), jnp.float32),
            "step": _struct((), jnp.int32),
            "frontend": _frontend_struct(cfg, B),
        }

    params = jax.eval_shape(functools.partial(init_params, cfg=cfg),
                            jax.random.PRNGKey(0))

    if spec.kind == "prefill":
        # VLM prefix tokens extend the cached sequence (prompt + patches)
        S_cache = S + (cfg.frontend_tokens
                       if cfg.frontend and not cfg.is_encoder_decoder else 0)
        cache = jax.eval_shape(functools.partial(init_cache, cfg, B, S_cache))
        return {
            "params": params,
            "tokens": _struct((B, S), jnp.int32),
            "cache": cache,
            "frontend": _frontend_struct(cfg, B),
        }

    # decode: ONE new token with a seq_len KV cache
    cache = jax.eval_shape(functools.partial(init_cache, cfg, B, S))
    kstate = jax.eval_shape(
        functools.partial(kappa_lib.init_state,
                          KappaConfig(num_branches=B, window=kcfg.window)))
    return {
        "params": params,
        "token": _struct((B,), jnp.int32),
        "pos": _struct((), jnp.int32),
        "cache": cache,
        "kstate": kstate,
        "log_q": _struct((cfg.vocab_size,), jnp.float32),
        "rng": jax.eval_shape(lambda: jax.random.PRNGKey(0)),
    }


def _replicate_tree(tree, mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def lower_pair(cfg: ModelConfig, shape_name: str, mesh, kcfg: KappaConfig):
    """Build the jit, lower and compile one (arch, shape, mesh) pair.
    Returns (lowered, compiled)."""
    from repro.models import prefill as model_prefill
    from repro.serving.engine import serve_step
    from repro.training.train import train_step_fn

    spec = INPUT_SHAPES[shape_name]
    ins = input_specs(cfg, shape_name, kcfg)
    bspec = sh.batch_spec(mesh)

    def _param_sh(tree):
        return jax.tree_util.tree_map_with_path(
            lambda p, x: NamedSharding(
                mesh, sh.param_spec(sh._path_str(p), tuple(x.shape), mesh, cfg)),
            tree)

    if spec.kind == "train":
        fn = train_step_fn(cfg)
        in_sh = [_param_sh(ins["state"]), NamedSharding(mesh, bspec),
                 NamedSharding(mesh, bspec), sh.replicated(mesh)]
        args = [ins["state"], ins["tokens"], ins["loss_mask"], ins["step"]]
        if ins["frontend"] is not None:
            in_sh.append(NamedSharding(mesh, sh.batch_spec(mesh, extra_dims=2)))
            args.append(ins["frontend"])
        with mesh:
            lowered = jax.jit(fn, in_shardings=tuple(in_sh)).lower(*args)

    elif spec.kind == "prefill":
        cache_sh = sh.cache_shardings(ins["cache"], mesh, cfg)

        def pf(params, tokens, cache, frontend=None):
            return model_prefill(params, cfg, tokens, cache, frontend)

        in_sh = [_param_sh(ins["params"]), NamedSharding(mesh, bspec), cache_sh]
        args = [ins["params"], ins["tokens"], ins["cache"]]
        if ins["frontend"] is not None:
            in_sh.append(NamedSharding(mesh, sh.batch_spec(mesh, extra_dims=2)))
            args.append(ins["frontend"])
        with mesh:
            lowered = jax.jit(pf, in_shardings=tuple(in_sh)).lower(*args)

    else:  # decode
        seq_shard = spec.global_batch == 1  # long_500k: shard cache seq
        param_sh = _param_sh(ins["params"])
        cache_sh = sh.cache_shardings(ins["cache"], mesh, cfg,
                                      seq_shard=seq_shard)
        tok_sh = sh.replicated(mesh) if seq_shard else NamedSharding(mesh, P(("pod", "data") if "pod" in mesh.axis_names else ("data",)))
        kcfg_b = KappaConfig(num_branches=spec.global_batch, window=kcfg.window)

        def step(params, token, pos, cache, kstate, log_q, rng):
            return serve_step(params, cfg, kcfg_b, token, pos, cache,
                              kstate, log_q, rng)

        in_sh = (param_sh, tok_sh, sh.replicated(mesh), cache_sh,
                 _replicate_tree(ins["kstate"], mesh), sh.replicated(mesh),
                 sh.replicated(mesh))
        args = (ins["params"], ins["token"], ins["pos"], ins["cache"],
                ins["kstate"], ins["log_q"], ins["rng"])
        with mesh:
            lowered = jax.jit(step, in_shardings=in_sh).lower(*args)

    compiled = lowered.compile()
    return lowered, compiled


def _attn_flops_per_token(cfg: ModelConfig, kv_len_of) -> float:
    """Attention/state flops per generated token (beyond the 2·N matmuls):
    scores + probs·V = 4·hd·S_attended per head per layer."""
    hd = cfg.resolved_head_dim
    total = 0.0
    for bt in cfg.block_types():
        if bt == "global":
            total += 4.0 * cfg.num_heads * hd * kv_len_of(None)
        elif bt == "local":
            total += 4.0 * cfg.num_heads * hd * min(kv_len_of(None), cfg.window_size)
        elif bt == "rwkv6":
            # state read+update: ~6 flops per (hd_k × hd_v) cell per head
            total += 6.0 * cfg.num_heads * hd * hd
        elif bt == "recurrent":
            total += 8.0 * cfg.d_model  # elementwise recurrence
    return total


def model_flops_estimate(cfg: ModelConfig, shape_name: str) -> float:
    """Analytic MODEL_FLOPS (the useful-compute floor):
      matmuls — 6·N_active·D (train) / 2·N_active·D (forward)
      + attention — 4·H·hd·S_kv per token per attn layer (·3 for train bwd)
    """
    spec = INPUT_SHAPES[shape_name]
    n_active = cfg.active_param_count()
    B, S = spec.global_batch, spec.seq_len
    if spec.kind == "train":
        # causal: average attended length S/2
        attn = B * S * _attn_flops_per_token(cfg, lambda _: S / 2) * 3.0
        return 6.0 * n_active * B * S + attn
    if spec.kind == "prefill":
        attn = B * S * _attn_flops_per_token(cfg, lambda _: S / 2)
        return 2.0 * n_active * B * S + attn
    # decode: one token per row, full cache attended
    attn = B * _attn_flops_per_token(cfg, lambda _: S)
    return 2.0 * n_active * B + attn


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            out_dir: str | None = None, verbose: bool = True,
            unroll: bool = True, cfg_override: ModelConfig | None = None) -> dict:
    import dataclasses
    cfg = cfg_override or get_config(arch)
    # unrolled layer stack → cost_analysis sees every layer (scan bodies
    # are counted once by XLA); scan mode stays available for A/B checks
    cfg = dataclasses.replace(cfg, unroll=unroll)
    kcfg = KappaConfig()
    mesh = make_production_mesh(multi_pod=multi_pod)
    if cfg.moe_impl == "expert_parallel":
        from repro.models import moe as moe_lib
        moe_lib.set_mesh(mesh)
    chips = mesh.devices.size
    t0 = time.time()
    lowered, compiled = lower_pair(cfg, shape_name, mesh, kcfg)
    compile_s = time.time() - t0

    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "peak_bytes": getattr(ma, "peak_memory_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem = {"error": str(e)[:200]}

    roof = from_compiled(compiled, chips,
                         model_flops_estimate(cfg, shape_name))
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "compile_s": round(compile_s, 1),
        "memory": mem, "roofline": roof.summary(),
    }
    if verbose:
        r = roof
        print(f"{arch:28s} {shape_name:12s} mesh={rec['mesh']:8s} "
              f"compile={compile_s:6.1f}s flops={r.flops:.3e} "
              f"bytes={r.hbm_bytes:.3e} coll={r.coll_bytes:.3e} "
              f"dom={r.dominant:10s} useful={r.useful_flops_ratio:.2f}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{rec['mesh']}.json"
        with open(os.path.join(out_dir, tag), "w") as f:
            json.dump(rec, f, indent=2, default=str)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    from repro.configs import ASSIGNED_ARCHS

    pairs = []
    if args.all:
        for arch in ASSIGNED_ARCHS:
            cfg = get_config(arch)
            for s in ["train_4k", "prefill_32k", "decode_32k", "long_500k"]:
                if s in applicable_shapes(cfg):
                    pairs.append((arch, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    failures = []
    for arch, s in pairs:
        mesh_tag = "2x16x16" if args.multi_pod else "16x16"
        tag = os.path.join(args.out, f"{arch}_{s}_{mesh_tag}.json")
        if args.skip_existing and os.path.exists(tag):
            print(f"skip {arch} {s} (exists)")
            continue
        try:
            run_one(arch, s, multi_pod=args.multi_pod, out_dir=args.out)
        except Exception as e:  # noqa: BLE001
            failures.append((arch, s, repr(e)[:300]))
            print(f"FAIL {arch:28s} {s:12s}: {repr(e)[:300]}")
            traceback.print_exc(limit=3)
    if failures:
        print(f"\n{len(failures)} failures")
        sys.exit(1)
    print("\nall dry-runs OK")


if __name__ == "__main__":
    main()
