"""Production mesh construction.

Single pod: (data=16, model=16) — one TPU v5e pod of 256 chips.
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the "pod" axis is an
outer data axis (only gradient all-reduce crosses it in train_step).

Defined as functions (not module constants) so importing never touches
jax device state — the dry-run sets XLA_FLAGS before first jax use.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1×1 mesh for CPU smoke runs."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Axis names that act as data parallelism (includes "pod")."""
    names = mesh.axis_names
    return tuple(n for n in names if n in ("pod", "data"))
