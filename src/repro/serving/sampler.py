"""Jittable sampling: temperature + top-k + top-p (paper §4.1:
T=0.7, k=20, p=0.95)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def sample(rng, logits, *, temperature: float = 0.7, top_k: int = 20,
           top_p: float = 0.95):
    """logits: (B, V) fp32 → (B,) int32 sampled tokens.
    temperature <= 0 → greedy argmax."""
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    l = logits.astype(jnp.float32) / temperature
    k = min(top_k, l.shape[-1]) if top_k > 0 else l.shape[-1]
    vals, idx = jax.lax.top_k(l, k)                       # (B, k) sorted desc
    if 0.0 < top_p < 1.0:
        probs = jax.nn.softmax(vals, axis=-1)
        csum = jnp.cumsum(probs, axis=-1)
        # keep tokens whose *previous* cumulative mass < p (always keep 1st)
        keep = (csum - probs) < top_p
        vals = jnp.where(keep, vals, NEG_INF)
    choice = jax.random.categorical(rng, vals, axis=-1)   # (B,)
    return jnp.take_along_axis(idx, choice[:, None], axis=-1)[:, 0].astype(jnp.int32)


def greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


_argmax = greedy


def sample_step(rng, logits, kcfg, *, greedy: bool = False):
    """One sampling step under a KappaConfig's sampling hyperparameters.
    ``greedy=True`` forces argmax (the greedy strategy's row)."""
    if greedy:
        return _argmax(logits)
    return sample(rng, logits, temperature=kcfg.temperature,
                  top_k=kcfg.top_k, top_p=kcfg.top_p)
