"""Jittable sampling: temperature + top-k + top-p (paper §4.1:
T=0.7, k=20, p=0.95).

Two batching regimes:
  * :func:`sample` — one RNG key for a whole (B, V) batch (lockstep
    branches of a single request; the paper's setting).
  * :func:`sample_rows` — one key *per row*. This is what lets the
    continuous-batching scheduler sample every active request's rows in
    ONE fused dispatch per tick: rows belong to different requests with
    different RNG streams, so each row carries its own key, and a vmap
    over rows is bitwise identical to sampling each request separately
    (the scheduler/engine equivalence guarantee).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def sample(rng, logits, *, temperature: float = 0.7, top_k: int = 20,
           top_p: float = 0.95):
    """logits: (B, V) fp32 → (B,) int32 sampled tokens.
    temperature <= 0 → greedy argmax."""
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    l = logits.astype(jnp.float32) / temperature
    k = min(top_k, l.shape[-1]) if top_k > 0 else l.shape[-1]
    vals, idx = jax.lax.top_k(l, k)                       # (B, k) sorted desc
    if 0.0 < top_p < 1.0:
        probs = jax.nn.softmax(vals, axis=-1)
        csum = jnp.cumsum(probs, axis=-1)
        # keep tokens whose *previous* cumulative mass < p (always keep 1st)
        keep = (csum - probs) < top_p
        vals = jnp.where(keep, vals, NEG_INF)
    choice = jax.random.categorical(rng, vals, axis=-1)   # (B,)
    return jnp.take_along_axis(idx, choice[:, None], axis=-1)[:, 0].astype(jnp.int32)


def greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def _picked_lp(logits, tokens):
    """(B,) log-prob of each row's picked token (fp32 softmax)."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.take_along_axis(lp, tokens[:, None], axis=-1)[:, 0]


_picked_logprob_jit = jax.jit(_picked_lp)

# device-dispatch counters: schedulers promise ONE fused sampling call
# per tick regardless of active-request count — tests and the
# throughput-benchmark breakdown assert against these (reset freely)
DISPATCHES = {"sample_rows": 0, "picked_logprob": 0}


def reset_dispatch_counters() -> None:
    for k in DISPATCHES:
        DISPATCHES[k] = 0


def picked_logprob(logits, tokens):
    DISPATCHES["picked_logprob"] += 1
    return _picked_logprob_jit(logits, tokens)


def sample_rows(keys, logits, greedy_mask, kcfg, *, want_picked_lp=False):
    """Per-row-keyed sampling — ONE device dispatch for any mix of rows.

    keys: (R,) PRNG keys (one per row; rows of the same request share a
        split of that request's stream). logits: (R, V). greedy_mask:
        (R,) bool — True rows take argmax and ignore their key.
    Returns (R,) int32 tokens; with ``want_picked_lp`` a
    ((R,) tokens, (R,) picked-token log-prob) pair from the same fused
    dispatch (BoN-style strategies consume the log-prob, so the
    scheduler gets both for one kernel launch and one transfer).

    vmap over rows with per-row keys means row i's token depends only on
    (keys[i], logits[i]) — independent of R or which other rows ride in
    the batch. The scheduler exploits this to fuse all active requests
    into one call per tick while staying token-for-token equivalent to
    sequential serving."""
    # jit keyed on the sampling hyperparameters only — NOT the whole
    # kcfg, which would retrace for every per-request max_new override
    DISPATCHES["sample_rows"] += 1
    return _sample_rows(keys, logits, greedy_mask,
                        temperature=kcfg.temperature, top_k=kcfg.top_k,
                        top_p=kcfg.top_p, want_lp=want_picked_lp)


@functools.partial(jax.jit,
                   static_argnames=("temperature", "top_k", "top_p",
                                    "want_lp"))
def _sample_rows(keys, logits, greedy_mask, *, temperature, top_k, top_p,
                 want_lp):
    def one(key, row, g):
        s = sample(key, row[None], temperature=temperature,
                   top_k=top_k, top_p=top_p)[0]
        return jnp.where(g, jnp.argmax(row).astype(jnp.int32), s)
    toks = jax.vmap(one)(keys, logits, greedy_mask)
    if not want_lp:
        return toks
    return toks, _picked_lp(logits, toks)
