"""Seeded deterministic fault injection for the serving schedulers
(DESIGN.md §8).

A :class:`FaultPlan` is a pure function of ``(seed, site, tick)``: every
draw comes from ``np.random.default_rng([seed, site_id, tick])``, so a
fault schedule is reproducible across runs, idempotent if a site is
consulted twice in one tick, and independent of consultation *order*
(the property that lets the two scheduler backends — whose tick counts
differ — each get a deterministic schedule from one seed).

Three injection sites, mirroring the real failure classes a serving
pool sees:

  * ``alloc`` — transient allocator exhaustion: for a faulting tick the
    :class:`~repro.serving.cache.PageAllocator` embargoes ``holdback``
    free pages (``can_alloc`` sees a smaller heap; raw ``free_count``
    accounting is untouched so leak checks stay exact). The scheduler's
    existing eviction/preemption machinery reacts exactly as it would
    to genuine pressure; evictions forced while the embargo is active
    are charged to the victim's retry budget.
  * ``step`` — a device-step failure: :class:`InjectedStepFault` raised
    at the top of the fused decode dispatch, BEFORE any pool mutation,
    modeling a failed dispatch whose donated buffers were never
    consumed. The scheduler catches it, tears down a victim request and
    replays it from its original submission RNG.
  * ``nan`` — NaN-poisoned logits: a deterministic subset of pool rows
    gets non-finite logits after the model step. The scheduler detects
    the poisoned rows from a fused finite-mask and replays the owning
    requests; the pooled KAPPA controller's finite-guard
    (``core/kappa.py``) keeps the poison out of sibling branches'
    z-scores for the one dispatch that consumed it.

``max_faults`` caps the total number of fires (a storm that never ends
would starve every request past its retry budget); the cap consumes
fires in tick order so it is deterministic for a fixed tick sequence.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


class InjectedStepFault(RuntimeError):
    """A FaultPlan-scheduled device-step failure (never raised by real
    device code — the scheduler's recovery path catches exactly this)."""


_SITE_IDS = {"step": 1, "alloc": 2, "nan": 3}


@dataclasses.dataclass
class FaultPlan:
    """Deterministic per-tick fault schedule. Default probabilities are
    tuned so a ``FaultPlan(seed=N)`` built from a bare ``seed:N`` CLI
    spec injects all three fault classes within a ~100-tick serve run."""

    seed: int
    p_step: float = 0.04       # device-step exception per tick
    p_alloc: float = 0.08      # allocator-exhaustion embargo per tick
    p_nan: float = 0.04        # NaN/Inf-poisoned logits per tick
    holdback: int = 2          # pages embargoed when an alloc fault fires
    nan_rows: int = 1          # pool rows poisoned when a nan fault fires
    max_faults: Optional[int] = None   # total fires before the plan goes quiet
    fired: int = 0             # fires so far (mutable bookkeeping)
    history: dict = dataclasses.field(default_factory=dict)

    def _rng(self, site: str, tick: int) -> np.random.Generator:
        return np.random.default_rng([self.seed, _SITE_IDS[site], tick])

    def _fire(self, site: str, tick: int, p: float) -> bool:
        # per-(site, tick) memo: a re-consulted tick (the scheduler may
        # re-enter a tick that didn't advance) replays the recorded
        # outcome without double-counting toward max_faults
        key = (site, tick)
        if key in self.history:
            return self.history[key]
        hit = False
        if p > 0.0 and (self.max_faults is None
                        or self.fired < self.max_faults):
            hit = bool(self._rng(site, tick).random() < p)
            if hit:
                self.fired += 1
        self.history[key] = hit
        return hit

    def step_fault(self, tick: int) -> bool:
        """Whether a device-step exception is scheduled for ``tick``."""
        return self._fire("step", tick, self.p_step)

    def page_holdback(self, tick: int) -> int:
        """Pages the allocator must embargo this tick (0 = no fault)."""
        return self.holdback if self._fire("alloc", tick, self.p_alloc) \
            else 0

    def nan_rows_for(self, tick: int, rows: int) -> np.ndarray:
        """Pool rows whose logits get poisoned this tick (possibly
        empty). Row choice is part of the same deterministic draw."""
        if not self._fire("nan", tick, self.p_nan):
            return np.empty((0,), np.int64)
        rng = self._rng("nan", tick)
        k = min(self.nan_rows, rows)
        return rng.choice(rows, size=k, replace=False)


def parse_fault_spec(spec: str) -> FaultPlan:
    """Build a FaultPlan from a CLI spec like ``seed:7`` or
    ``seed:7,step:0.1,alloc:0.2,nan:0.05,holdback:4,max:20``."""
    kw: dict = {}
    keys = {"seed": ("seed", int), "step": ("p_step", float),
            "alloc": ("p_alloc", float), "nan": ("p_nan", float),
            "holdback": ("holdback", int), "rows": ("nan_rows", int),
            "max": ("max_faults", int)}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        k, _, v = part.partition(":")
        if k not in keys or not v:
            raise ValueError(f"bad fault spec entry {part!r} "
                             f"(known keys: {sorted(keys)})")
        field_name, conv = keys[k]
        kw[field_name] = conv(v)
    if "seed" not in kw:
        raise ValueError(f"fault spec {spec!r} needs a seed (e.g. 'seed:7')")
    return FaultPlan(**kw)
