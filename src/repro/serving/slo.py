"""SLO-adaptive admission controller over the tick schedulers.

Closes the loop the ROADMAP's async-front-end item asks for: watch the
windowed TTFT/ITL percentiles the scheduler accumulates (DESIGN.md §9,
``snapshot(reset_window=True)``) and adapt the admission/prefill knobs
each window to hold a target ITL p99, degrading to SHED before latency
collapses instead of after.

The controller is a small hysteretic state machine over an escalation
``level``; each level turns one more knob:

======  ======================================================
level   action (cumulative)
======  ======================================================
0       steady state — base ``prefill_chunk``, unbounded
        ``prefill_budget``, base ``max_queue``
1       pace admission: set ``sched.prefill_budget`` to one base
        chunk of prompt tokens per tick, so burst arrivals are
        admitted one per tick instead of riding the same fused
        dispatch (k same-tick chunks k-fold inflate every active
        request's ITL for that tick); also halve
        ``prefill_chunk`` (smaller chunks interleave finer with
        decode ticks) unless ``min_prefill_chunk`` pins it
2       pause admission (``sched.admit_paused``) — queued work
        waits, active requests drain
3       halve the effective ``max_queue`` — the bounded queue now
        sheds at the door (terminal SHED) rather than queueing
        into certain deadline misses
======  ======================================================

A *violated* window (``itl_p99 > target``, with enough samples to
trust the percentile) escalates one level; a *healthy* window
(``itl_p99 <= recover_frac * target``, or too few samples to judge —
an idle/draining pool must not stay wedged shut) de-escalates one
level; anything in between holds (hysteresis). Every evaluation is
appended to ``history`` so benchmarks can plot the controller's path.

Windows are tick-counted (``window_ticks``), not wall-timed: the
driving loop calls ``on_tick()`` after every scheduler tick and the
controller evaluates every N ticks through the injectable scheduler
clock — fully deterministic under a fake clock in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass
class SLOConfig:
    """Targets + controller shape. ``target_itl_p99_s`` is the held
    SLO; ``target_ttft_p99_s`` optionally escalates on TTFT too."""
    target_itl_p99_s: float
    target_ttft_p99_s: Optional[float] = None
    window_ticks: int = 32          # evaluate every N scheduler ticks
    min_itl_samples: int = 8        # below this a percentile is noise
    recover_frac: float = 0.7       # healthy when p99 <= frac * target
    max_level: int = 3
    min_prefill_chunk: int = 1
    # conservative start: begin at this escalation level and let healthy
    # windows relax it — a controller that only reacts AFTER a violated
    # window has already served that window's burst at full blast
    start_level: int = 0


class SLOController:
    """Attach to a scheduler and call :meth:`on_tick` after every tick
    (the :class:`~repro.serving.frontend.ServingFrontend` does this for
    you). ``update()`` may also be called directly to force a window
    evaluation — the unit tests drive it that way."""

    def __init__(self, sched, cfg: SLOConfig):
        self.sched = sched
        self.cfg = cfg
        self.level = cfg.start_level
        self.history: List[Dict] = []
        # base knob values to restore on de-escalation
        self._base_chunk: Optional[int] = sched.prefill_chunk
        self._base_budget: Optional[int] = getattr(
            sched, "prefill_budget", None)
        self._base_queue: Optional[int] = sched.max_queue
        self._shed_queue: Optional[int] = None
        self._last_eval_tick = sched.ticks
        if self.level:
            self._apply()

    # ------------------------------------------------------------ driving

    def on_tick(self) -> Optional[Dict]:
        """Window boundary check; evaluates every ``window_ticks``."""
        if self.sched.ticks - self._last_eval_tick < self.cfg.window_ticks:
            return None
        return self.update()

    def update(self) -> Dict:
        """Evaluate one window: read-and-reset the scheduler's windowed
        percentiles, move the escalation level, apply the knobs."""
        cfg = self.cfg
        snap = self.sched.snapshot(reset_window=True)
        self._last_eval_tick = self.sched.ticks

        enough = snap["itl_count"] >= cfg.min_itl_samples
        violated = enough and snap["itl_p99_s"] > cfg.target_itl_p99_s
        if cfg.target_ttft_p99_s is not None \
                and snap["ttft_count"] >= cfg.min_itl_samples:
            violated = violated or (snap["ttft_p99_s"]
                                    > cfg.target_ttft_p99_s)
        # healthy = clearly under target, or nothing to measure (an
        # idle/drained pool must unwedge a paused admission gate)
        healthy = (not violated
                   and (not enough
                        or snap["itl_p99_s"]
                        <= cfg.recover_frac * cfg.target_itl_p99_s))

        if violated:
            self.level = min(self.level + 1, cfg.max_level)
        elif healthy:
            self.level = max(self.level - 1, 0)
        self._apply()

        snap.update({"level": self.level, "violated": violated,
                     "healthy": healthy,
                     "prefill_chunk": self.sched.prefill_chunk,
                     "prefill_budget": getattr(self.sched,
                                               "prefill_budget", None),
                     "max_queue": self.sched.max_queue})
        self.history.append(snap)
        return snap

    # ------------------------------------------------------------- knobs

    def _apply(self) -> None:
        s = self.sched
        # level >= 1: pace admission to one base chunk of new prompt
        # tokens per tick and halve the chunks themselves (both only
        # meaningful when the scheduler prefills chunked at all)
        if self._base_chunk is not None:
            s.prefill_budget = (self._base_budget if self.level < 1
                                else max(1, self._base_chunk))
            s.prefill_chunk = (self._base_chunk if self.level < 1 else
                               max(self.cfg.min_prefill_chunk,
                                   self._base_chunk // 2))
        # level >= 2: stop admitting — active requests drain first
        s.admit_paused = self.level >= 2
        # level >= 3: shrink the bounded queue so overload sheds at the
        # door; sized once per episode off the base (or current) depth
        if self.level >= self.cfg.max_level:
            if self._shed_queue is None:
                base = (self._base_queue if self._base_queue is not None
                        else len(s.queue))
                self._shed_queue = max(1, base // 2)
            s.max_queue = self._shed_queue
        else:
            s.max_queue = self._base_queue
            self._shed_queue = None
