"""Async streaming front-end over the tick schedulers (DESIGN.md §9).

``ServingFrontend`` owns a scheduler (contiguous or paged, any strategy
mix) and drives its incremental ``step()`` surface from a background
task, streaming each request's committed tokens back as
:class:`~repro.serving.scheduler.TokenEvent` objects the moment the
tick that produced them retires. Two interchangeable drive backends:

* **asyncio** (``async with ServingFrontend(sched) as fe``): the tick
  loop runs as an event-loop task. After every tick it yields once
  (``await asyncio.sleep(0)``), which deterministically runs every
  consumer woken by that tick's events *before* the next tick starts —
  streams interleave with decoding without threads.
* **thread** (``with ServingFrontend(sched) as fe``): for callers
  without an event loop. The tick loop runs on a daemon thread, events
  flow through thread-safe queues, and the sync twins
  (``stream()`` / ``wait_result()``) block instead of awaiting.

Either way the scheduler itself is single-threaded: every scheduler
touch (submit, cancel, tick, metrics) happens under one re-entrant
lock, and the SLO controller's ``on_tick`` runs inside it.

Equivalence contract: an undisturbed streamed request yields exactly
the token sequence batch ``run()`` produces on the same seed — the
committed-prefix emission rule guarantees every streamed prefix is a
prefix of the final ``GenResult.tokens``, and the terminal event flushes
the rest.
"""
from __future__ import annotations

import asyncio
import queue as _queue
import threading
import time
from typing import AsyncIterator, Dict, Iterator, List, Optional

from .scheduler import GenResult, TokenEvent


class ServingFrontend:
    """Streaming front-end over one scheduler instance.

    The scheduler must be exclusively owned: the frontend installs
    itself as the scheduler's ``event_sink`` and drives every tick.
    """

    def __init__(self, sched, *, slo=None, idle_sleep_s: float = 0.001):
        if sched.event_sink is not None:
            raise ValueError("scheduler already has an event_sink")
        self.sched = sched
        self.slo = slo
        self.idle_sleep_s = idle_sleep_s
        sched.event_sink = self._on_event
        self._lock = threading.RLock()
        self._done_cv = threading.Condition(self._lock)
        self._chan: Dict[int, object] = {}      # rid -> event queue
        self._futures: Dict[int, asyncio.Future] = {}
        # events emitted synchronously inside sched.submit (SHED at the
        # door) land here before the rid has a channel; submit_nowait
        # drains them under the same lock, so none are ever dropped
        self._pending: List[TokenEvent] = []
        self._mode: Optional[str] = None        # "asyncio" | "thread"
        self._stop = False
        self._task: Optional[asyncio.Task] = None
        self._thread: Optional[threading.Thread] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None

    # ------------------------------------------------------------ lifecycle

    async def __aenter__(self) -> "ServingFrontend":
        self.start_async()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    def __enter__(self) -> "ServingFrontend":
        self.start_thread()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def start_async(self) -> None:
        """Start the tick loop as a task on the running event loop."""
        assert self._mode is None, "frontend already started"
        self._mode = "asyncio"
        self._loop = asyncio.get_running_loop()
        self._task = self._loop.create_task(self._tick_loop_async())

    def start_thread(self) -> None:
        """Start the tick loop on a background daemon thread."""
        assert self._mode is None, "frontend already started"
        self._mode = "thread"
        self._thread = threading.Thread(
            target=self._tick_loop_thread, name="serving-tick", daemon=True)
        self._thread.start()

    async def aclose(self) -> None:
        """Drain all in-flight work, then stop the tick task."""
        await self.drain()
        self._stop = True
        if self._task is not None:
            await self._task
            self._task = None
        self._shutdown()

    def close(self) -> None:
        """Thread-backend twin of :meth:`aclose`."""
        self.join()
        self._stop = True
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._shutdown()

    def _shutdown(self) -> None:
        with self._lock:
            self.sched._end_run()      # clear tick-scoped fault state so
            self.sched.event_sink = None   # leak checks see a clean pool

    # ------------------------------------------------------------ tick loop

    def _tick_once(self) -> bool:
        """One locked scheduler tick (+ SLO window check); returns
        whether there was work."""
        with self._lock:
            if not self.sched.has_work:
                return False
            self.sched.step()
            if self.slo is not None:
                self.slo.on_tick()
            return True

    async def _tick_loop_async(self) -> None:
        while not self._stop:
            worked = self._tick_once()
            # sleep(0) after a working tick: consumers woken by this
            # tick's put_nowait calls were queued on the loop BEFORE
            # this continuation, so they all run before the next tick —
            # deterministic stream/tick interleaving without threads
            await asyncio.sleep(0 if worked else self.idle_sleep_s)

    def _tick_loop_thread(self) -> None:
        while not self._stop:
            if not self._tick_once():
                # idle pacing of a live OS thread: wall-clock by nature,
                # never observable in tokens (replay is RNG-driven)
                # repro-lint: disable-next-line=replay-determinism
                time.sleep(self.idle_sleep_s)

    # ------------------------------------------------------------- events

    def _on_event(self, ev: TokenEvent) -> None:
        # always called under self._lock (submit and tick both hold it)
        ch = self._chan.get(ev.rid)
        if ch is not None:
            ch.put_nowait(ev)
        else:
            self._pending.append(ev)
        if ev.kind == "end":
            fut = self._futures.pop(ev.rid, None)
            if fut is not None and not fut.done():
                fut.set_result(ev.result)
            self._done_cv.notify_all()

    def _new_channel(self):
        return asyncio.Queue() if self._mode == "asyncio" \
            else _queue.Queue()

    # ------------------------------------------------------------- submit

    def submit_nowait(self, prompt, rng, **kw) -> int:
        """Submit without waiting; returns the rid. Thread-safe. The
        rid's event channel is registered under the same lock as the
        submit, so even a synchronous SHED terminal event is captured."""
        with self._lock:
            rid = self.sched.submit(prompt, rng, **kw)
            ch = self._new_channel()
            self._chan[rid] = ch
            mine = [e for e in self._pending if e.rid == rid]
            if mine:
                self._pending = [e for e in self._pending if e.rid != rid]
                for e in mine:
                    ch.put_nowait(e)
            return rid

    async def submit(self, prompt, rng, **kw) -> GenResult:
        """Submit and await the terminal :class:`GenResult`."""
        rid = self.submit_nowait(prompt, rng, **kw)
        return await self.result(rid)

    async def submit_stream(self, prompt, rng, **kw) \
            -> AsyncIterator[TokenEvent]:
        """Submit and stream the request's events: committed tokens in
        strict decode order, then exactly one terminal ``kind="end"``
        event (carrying the full ``GenResult``), after which the
        iterator ends."""
        rid = self.submit_nowait(prompt, rng, **kw)
        async for ev in self.events(rid):
            yield ev

    # ------------------------------------------------------------ consume

    async def events(self, rid: int) -> AsyncIterator[TokenEvent]:
        """Async-iterate a submitted rid's events through its terminal
        event."""
        ch = self._chan[rid]
        try:
            while True:
                ev = await ch.get()
                yield ev
                if ev.kind == "end":
                    return
        finally:
            with self._lock:
                self._chan.pop(rid, None)

    def stream(self, rid: int, timeout: Optional[float] = None) \
            -> Iterator[TokenEvent]:
        """Sync twin of :meth:`events` for the thread backend."""
        ch = self._chan[rid]
        try:
            while True:
                ev = ch.get(timeout=timeout)
                yield ev
                if ev.kind == "end":
                    return
        finally:
            with self._lock:
                self._chan.pop(rid, None)

    async def result(self, rid: int) -> GenResult:
        """Await the terminal result of a submitted rid."""
        with self._lock:
            res = self.sched.results.get(rid)
            if res is not None:
                return res
            fut = self._futures.get(rid)
            if fut is None:
                fut = self._loop.create_future()
                self._futures[rid] = fut
        return await fut

    def wait_result(self, rid: int,
                    timeout: Optional[float] = None) -> GenResult:
        """Sync twin of :meth:`result` for the thread backend."""
        with self._done_cv:
            if not self._done_cv.wait_for(
                    lambda: rid in self.sched.results, timeout):
                raise TimeoutError(f"rid {rid} not terminal in {timeout}s")
            return self.sched.results[rid]

    def cancel(self, rid: int) -> None:
        """Cancel a request anywhere in its lifecycle; its stream ends
        with a CANCELLED terminal event."""
        with self._lock:
            self.sched.cancel(rid)

    # -------------------------------------------------------------- drain

    async def drain(self) -> None:
        """Wait until the scheduler has no queued/prefilling/active
        work (all submitted requests reached a terminal event)."""
        while True:
            with self._lock:
                if not self.sched.has_work:
                    return
            await asyncio.sleep(0)

    def join(self, timeout_s: Optional[float] = None) -> None:
        """Sync twin of :meth:`drain`."""
        # join() guards a LIVE thread against hanging: the timeout must
        # follow real wall-clock even when the scheduler runs on a fake
        # clock, and the pacing sleep yields the GIL to the tick thread
        deadline = None
        if timeout_s is not None:
            # repro-lint: disable-next-line=replay-determinism
            deadline = time.monotonic() + timeout_s
        while True:
            with self._lock:
                if not self.sched.has_work:
                    return
            # repro-lint: disable-next-line=replay-determinism
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("scheduler still has work")
            # repro-lint: disable-next-line=replay-determinism
            time.sleep(self.idle_sleep_s)

    def snapshot(self, reset_window: bool = False) -> Dict:
        """Locked passthrough to the scheduler's windowed metrics."""
        with self._lock:
            return self.sched.snapshot(reset_window=reset_window)
