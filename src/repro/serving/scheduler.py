"""Continuous-batching multi-request schedulers (DESIGN.md §4–§5).

The sequential engine serves one prompt at a time: N branch rows, pruned
to 1 by KAPPA/ST-BoN, then a long single-row tail to EOS — poor device
utilization exactly when pruning succeeds. These schedulers turn freed
capacity into throughput, the serving-level payoff the early-pruning
papers point at (ST-BoN, Wang et al. 2025; Bi et al. 2025). Two pool
backends share one driver:

  * :class:`ContinuousBatchingScheduler` — PR 1's contiguous
    ``(rows, max_seq)`` device pool with FIFO admission counted in rows.
    Every row reserves (and streams through attention) ``max_seq`` KV
    slots regardless of the request's actual length.
  * :class:`PagedScheduler` — a paged KV pool (DESIGN.md §5): global
    attention layers share a page pool, rows hold ``(max_pages,)`` block
    tables, fan-out branches share the prompt pages copy-on-write,
    decode pages are allocated lazily at page-boundary crossings (with
    youngest-admitted preemption when the pool runs dry), pruning drops
    page references the moment it happens, and queued requests are
    admitted shortest-job-first with bounded bypass among those that
    fit. With ``prefix_cache=True`` a cross-request radix tree
    (DESIGN.md §7) pins completed requests' prompt/winner pages so
    later admissions alias them and prefill only the uncached tail;
    under pressure, least-recently-hit cached pages are evicted
    before any request is preempted.

Shared driver behaviour per tick:

  * admit whatever the backend's policy allows. With ``prefill_chunk``
    set, admission enters a **PREFILLING** state (DESIGN.md §6): the
    request owns its row slots (and, paged, the pages written so far)
    and advances one prompt chunk per tick — the oldest one *inside*
    the fused decode dispatch itself — so decode rows never stall for
    more than one chunk's latency on a long-prompt admission; the final
    chunk's logits are bitwise-equal to the one-shot prefill and feed
    the same strategy start path. Without chunking (or for
    frontend/enc-dec requests) admission falls back to a one-shot
    batch-1 prefill through a transient cache sized to the prompt (the
    contiguous pool broadcasts inside its install scatter, the paged
    pool aliases shared prompt pages copy-on-write across the N branch
    block tables);
  * one fused decode step over the whole pool with per-row positions;
  * ONE fused sampler dispatch for every active request's rows
    (per-row RNG keys — :func:`repro.serving.sampler.sample_rows`)
    instead of a per-request ``sample_step`` call;
  * ONE pooled KAPPA-controller dispatch for every active kappa request
    (:class:`repro.serving.strategies.PooledKappaController`): the
    stacked controller state consumes the pool logits and just-sampled
    tokens device-to-device, and its alive/traj/cutoff outputs ride the
    tick's single blocking transfer — replacing the per-request
    ``kappa_step`` dispatch + ``np.asarray(alive)`` sync that made the
    controller the bottleneck (dispatch/sync counters in ``counters``
    assert the ≤1-per-tick contract; ``tick_time`` records the per-tick
    model/sampler/controller/sync/host breakdown);
  * per-request strategies (repro.serving.strategies) drive pruning and
    compaction decisions on their own row groups (host-side, from the
    published controller mirrors); freed capacity is backfilled by
    queued prefills on the next tick;
  * per-request ``GenResult``s emitted on completion with the same
    accounting as sequential serving. ``submit(..., method=...)`` lets
    one pool serve mixed kappa/bon/stbon/greedy traffic with
    per-request ``max_new``.

Equivalence guarantee: the batched decode step is row-independent, the
per-row-keyed sampler is row-independent, and the host-side per-request
logic is shared verbatim with the engine loop — so with the same
per-request keys and the same ``max_seq`` both schedulers reproduce the
sequential engine token for token (tests/test_scheduler.py,
tests/test_paged.py).

Request lifecycle (DESIGN.md §8): every submission reaches exactly one
terminal status — ``OK`` (normal completion), ``CANCELLED``
(:meth:`cancel` from any state, partial tokens returned), ``TIMEOUT``
(per-request ``deadline_s`` / ``max_wall_ticks`` watchdog,
truncate-and-return), ``FAILED`` (quarantined after ``max_retries``
fault-triggered replays), or ``SHED`` (bounded admission queue
overflowed at submit time). Injected faults (``serving.faults``) are
answered with the preemption-replay machinery: tear down, requeue with
exponential backoff, replay token-for-token from the original
submission RNG.

Streaming surface (DESIGN.md §9): every tick emits :class:`TokenEvent`s
through ``event_sink`` (or collects them per-:meth:`step` call) — one
``kind="token"`` event per newly *committed* generated token (a token
whose membership in the final output can no longer change, per the
strategy's ``decided_branch``) and exactly one ``kind="end"`` terminal
event per submission, carrying the ``GenResult``. All wall-clock reads
(submit stamps, deadlines, TTFT/ITL stamps, run elapsed) go through the
injectable ``clock=`` callable (default ``time.monotonic``) so latency
behaviour is testable without sleeping; retry backoff stays tick-counted
and needs no clock. :meth:`snapshot` reads the per-window TTFT/ITL
percentiles and goodput counters the SLO controller (``serving.slo``)
and the open-loop arrival sweeps consume.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import KappaConfig, ModelConfig
from repro.models import decode_step, init_cache, init_paged_cache
from repro.serving import cache as cache_lib
from repro.serving import engine
from repro.serving import faults as faults_lib
from repro.serving import sampler
from repro.serving import strategies
from repro.serving.strategies import GenResult


class Unservable(ValueError):
    """Raised at ``submit()`` time for a request this scheduler can NEVER
    serve (too many positions, too much fan-out, worst-case pages beyond
    the whole pool) — as opposed to transient pressure, which queues.
    Subclasses ValueError so callers that guarded the old assertions
    keep working."""

_scatter = jax.jit(cache_lib.scatter_batch_prefix, donate_argnums=(0,))
_install_shared = jax.jit(cache_lib.install_paged_shared,
                          static_argnums=(0, 6), donate_argnums=(1,))
_paged_step = jax.jit(decode_step, static_argnums=(1,), donate_argnums=(4,))
_copy_pages = jax.jit(cache_lib.copy_pages, static_argnums=(0,),
                      donate_argnums=(1,))
_install_aux = jax.jit(cache_lib.install_rows_aux, static_argnums=(0,),
                       donate_argnums=(1,))


@dataclasses.dataclass
class _Queued:
    rid: int
    prompt: np.ndarray
    rng: object
    kcfg: KappaConfig          # per-request (max_new may be overridden)
    need: int                  # prompt + n_prefix + max_new token slots
    fan_out: int
    factory: Callable[[], strategies.DecodeStrategy]  # per-request strategy
    bypasses: int = 0          # times a younger request was admitted first
    deadline_s: Optional[float] = None   # wall-clock budget from submit
    max_wall_ticks: Optional[int] = None  # tick budget from submit
    n_retries: int = 0         # fault-triggered replays so far
    not_before: int = 0        # backoff: earliest tick for re-admission
    submit_tick: int = 0       # tick at submission (max_wall_ticks base)


@dataclasses.dataclass
class _Prefill:
    """A request in the PREFILLING state (DESIGN.md §6): it owns its row
    slots (and, in the paged backend, the pages written so far through
    slot[0]'s block table) and advances one prompt chunk per tick inside
    the same scheduler tick as the active decode rows."""
    item: _Queued
    slots: List[int]
    filled: int = 0            # prompt tokens written so far
    cache1: object = None      # contiguous backend: prompt-sized side cache
    aux: object = None         # paged backend: batch-1 per-row-family state


@dataclasses.dataclass
class TokenEvent:
    """One streaming event for one request (DESIGN.md §9).

    ``kind="token"``: one committed generated token (``token`` /
    ``index`` — indices are strictly increasing per rid and match the
    final ``GenResult.tokens`` positions). ``kind="end"``: the terminal
    event, exactly one per submission, carrying ``status`` and the full
    ``result``; ``index`` is the total token count. ``t`` is a
    scheduler-clock stamp."""
    rid: int
    kind: str                              # "token" | "end"
    t: float
    index: int = 0
    token: Optional[int] = None
    status: Optional[str] = None           # terminal status on "end"
    result: Optional[GenResult] = None


class _SchedulerBase:
    """Queue + row-slot lifecycle + fused tick, independent of how KV
    storage is reserved. Subclasses implement the storage policy."""

    def __init__(self, params, cfg: ModelConfig, kcfg: KappaConfig, *,
                 rows: int, max_seq: int, method: str = "kappa",
                 eos_id: int, bos_id: int = 0, frontend=None,
                 strategy_factory: Optional[Callable[[], strategies.DecodeStrategy]] = None,
                 fused_sampling: bool = True,
                 prefill_chunk: Optional[int] = None,
                 faults: Optional[faults_lib.FaultPlan] = None,
                 max_retries: int = 3, retry_backoff: int = 2,
                 max_queue: Optional[int] = None,
                 clock: Optional[Callable[[], float]] = None,
                 event_sink: Optional[Callable[[TokenEvent], None]] = None):
        self.params = params
        self.cfg = cfg
        self.kcfg = kcfg
        self.rows = rows
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.bos_id = bos_id
        self.frontend = frontend
        self.strategy_factory = strategy_factory or (
            lambda: strategies.make_strategy(method))
        # False = PR 1 dispatch pattern (one sample_step call + host sync
        # per request per tick) — kept as a benchmark baseline; tokens
        # are identical either way (sample_rows is row-independent)
        self.fused_sampling = fused_sampling
        self.n_prefix = engine._n_prefix(cfg)

        need = self.strategy_factory().rows(kcfg)
        if rows < need:
            raise ValueError(f"pool rows={rows} < request fan-out {need}")
        if cfg.is_moe and cfg.moe_capacity_factor > 0:
            # capacity-limited MoE routing drops tokens *per batch*, so
            # pool rows are not independent: one request's rows (and the
            # free rows' garbage tokens) would contend for expert capacity
            # with another's, breaking the equivalence guarantee. Dropless
            # routing (capacity_factor <= 0) is exact and row-independent.
            raise ValueError(
                "continuous batching requires dropless MoE routing "
                "(cfg.moe_capacity_factor <= 0): capacity-limited dispatch "
                "couples pool rows across requests")

        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        self.prefill_chunk = prefill_chunk
        # chunked admission needs a chunkable token stream; frontend /
        # enc-dec requests keep the one-shot prefill path
        self._chunked_ok = (prefill_chunk is not None
                            and engine.chunkable(cfg, frontend))
        self.row_token = np.zeros((rows,), np.int32)
        self.row_pos = np.zeros((rows,), np.int32)
        self.free: List[int] = list(range(rows))
        self.queue: deque = deque()          # _Queued items
        self.prefilling: Dict[int, _Prefill] = {}  # rid -> PREFILLING state
        self._fused_rids: List[int] = []     # chunks riding this tick's
        self._fused_chunk_out = None         # fused decode dispatch
        self.active: Dict[int, tuple] = {}   # rid -> (RequestState, slots)
        self._slots_dev: Dict[int, object] = {}  # rid -> device slot idx
        self._items: Dict[int, _Queued] = {}  # rid -> original submission
        self._admit_seq: Dict[int, int] = {}  # rid -> admission order
        self._admit_counter = 0
        self.results: Dict[int, GenResult] = {}
        self._next_rid = 0
        self.ticks = 0
        self._occupied_ticks = 0             # Σ occupied rows over ticks
        # pooled KAPPA controller (lazily built on first kappa admission;
        # shared by every kappa request whose controller-relevant kcfg
        # matches — per-request max_new overrides still share it)
        self._kappa_pool: Optional[strategies.PooledKappaController] = None
        self._ctrl_key = strategies.controller_key(kcfg)
        # dispatch / blocking-transfer counters (the batched-controller
        # contract: ≤1 controller dispatch and ≤1 controller-carrying
        # blocking transfer per tick, independent of active-request count)
        self.counters: Dict[str, int] = {
            "controller_dispatches": 0, "controller_syncs": 0,
            "sampler_dispatches": 0, "host_syncs": 0, "preemptions": 0,
            "retries": 0, "failures": 0, "cancelled": 0, "timeouts": 0,
            "shed": 0, "faults_injected": 0,
        }
        # request-lifecycle hardening (DESIGN.md §8): fault plan, bounded
        # retry-with-backoff, and the bounded admission queue
        self.faults = faults
        self.max_retries = max_retries
        self.retry_backoff = retry_backoff
        self.max_queue = max_queue
        self._fault_tick = False     # an alloc embargo is live this tick
        self._has_deadlines = False  # sticky: any submit set a deadline
        # per-tick wall-time breakdown (seconds, cumulative over run)
        self.tick_time: Dict[str, float] = {
            "model": 0.0, "prefill": 0.0, "sampler": 0.0,
            "controller": 0.0, "sync": 0.0, "host": 0.0,
        }
        # admission-side peak: bytes of the largest transient prefill
        # structure (prompt-sized side cache / chunked aux state) — the
        # regression knob for the old max_seq-sized throwaway cache
        self.admit_peak_bytes = 0
        # injectable monotonic clock: every user-visible latency read
        # (submit stamps, deadlines, TTFT/ITL, run elapsed) goes through
        # it so tests advance time without sleeping. The tick_time
        # profiling breakdown keeps real perf_counter deltas — it
        # measures compute cost, not request-visible latency.
        self.clock: Callable[[], float] = clock or time.monotonic
        # latency bookkeeping: submit walltime, time-to-first-token and
        # per-tick token emission stamps (ITL = consecutive diffs)
        self._submit_t: Dict[int, float] = {}
        self.ttft: Dict[int, float] = {}
        self.token_times: Dict[int, List[float]] = {}
        # streaming surface (DESIGN.md §9): per-event callback, per-step
        # capture list, and the per-rid count of already-emitted tokens
        self.event_sink = event_sink
        self._tick_events: Optional[List[TokenEvent]] = None
        self._streamed: Dict[int, int] = {}
        # SLO-controller admission knob: while True, _admit_one admits
        # nothing (queued work waits; the bounded queue still sheds at
        # the door) — serving.slo flips it per latency window
        self.admit_paused = False
        # admission pacing knob: at most this many NEW prompt tokens
        # enter PREFILLING per tick (None = unbounded). k same-tick
        # admissions each ride a full chunk through the fused dispatch,
        # k-fold inflating every active request's ITL for that tick —
        # the budget spreads bursts across ticks instead. Greedy-spend:
        # admission proceeds while budget remains, so the last admit may
        # overshoot by one prompt; a budget >= 1 always admits when idle.
        self.prefill_budget: Optional[int] = None
        self._admit_left: Optional[int] = None
        # windowed latency/goodput accounting read by snapshot()
        self._win_t0 = self.clock()
        self._win_tick0 = 0
        self._win_ttft: List[float] = []
        self._win_itl: List[float] = []
        self._win_counts = {"completed": 0, "ok": 0, "ok_tokens": 0,
                            "shed": 0}

    # ----------------------------------------------------- storage hooks

    def _check_servable(self, item: _Queued) -> None:
        """Raise if the request can never be admitted."""

    def _admissible(self, item: _Queued) -> bool:
        """Whether the request fits the free capacity right now."""
        raise NotImplementedError

    def _select_admit(self) -> Optional[int]:
        """Queue index to admit next, or None. Defines the policy."""
        raise NotImplementedError

    def _install(self, slots: List[int], item: _Queued, sub1) -> None:
        """Install the batch-1 prefilled sub-cache into the row slots
        (fanning out / aliasing is the backend's storage policy)."""
        raise NotImplementedError

    def _release_storage(self, slots: List[int]) -> None:
        """Return the slots' KV reservation (pages / nothing extra)."""

    def _publish_prompt_pages(self, prompt: np.ndarray, slot: int,
                              upto: int) -> None:
        """Teardown hook, called BEFORE a departing (preempted /
        cancelled / timed-out) request's storage is released: backends
        may retain its fully-written prompt extent (the paged backend
        pins it into the radix prefix cache). Base: nothing to retain."""

    def _begin_fault_tick(self) -> bool:
        """Consult the fault plan for tick-scoped allocator faults; True
        while an embargo is live (preemptions this tick are charged to
        the victim's retry budget). Base: no allocator, nothing to do."""
        return False

    def _end_run(self) -> None:
        """Post-run hook: clear any tick-scoped fault state so leak
        checks and later manual ticks see a clean pool."""

    def _decode_tick(self):
        """One fused model step over the pool; returns pool logits."""
        raise NotImplementedError

    # ------------------------------------------ chunked-prefill hooks

    def _has_local(self) -> bool:
        return any(bt == "local" for bt in self.cfg.block_types())

    def _ring_window(self) -> int:
        """Pool rows' ring-cache window — the transient prefill cache
        must match it so ring layouts line up at install time."""
        return min(self.cfg.window_size, self.max_seq) \
            if self._has_local() else 0

    def _prefill_seq(self, item: _Queued) -> int:
        """Sequence capacity of the transient admission prefill cache:
        the prompt itself (not max_seq — the PR 5 sizing fix), floored
        at the pool's ring window so ring layouts stay identical."""
        return max(len(item.prompt) + self.n_prefix, self._ring_window(), 1)

    def _begin_prefill(self, item: _Queued, slots: List[int]) -> _Prefill:
        """Enter the PREFILLING state for an admitted request."""
        raise NotImplementedError

    def _prefill_step(self, pf: _Prefill) -> Optional[object]:
        """Advance one prompt chunk. Returns the last-position logits
        (V,) once the whole prompt is written, else None (also None if
        the backend had to preempt ``pf`` itself to stay within its
        page budget — the request is then back in the queue)."""
        raise NotImplementedError

    def _finish_prefill(self, pf: _Prefill) -> bool:
        """Finalize storage for a fully prefilled request (install /
        share pages across the fan-out). False iff the request had to be
        preempted instead (paged pool dry)."""
        raise NotImplementedError

    # ------------------------------------------------------------ submit

    def submit(self, prompt: np.ndarray, rng, *,
               max_new: Optional[int] = None,
               method: Optional[str] = None,
               strategy_factory: Optional[Callable[
                   [], strategies.DecodeStrategy]] = None,
               deadline_s: Optional[float] = None,
               max_wall_ticks: Optional[int] = None) -> int:
        """Queue one prompt with its own RNG stream; returns request id.
        ``max_new`` overrides ``kcfg.max_new_tokens`` for this request
        (mixed-length serving — the paged pool sizes its reservation to
        the request's own need). ``method`` / ``strategy_factory``
        override the scheduler-level strategy for this request, so one
        pool can serve mixed kappa/bon/greedy/stbon traffic.

        ``deadline_s`` (wall-clock seconds from submission) and
        ``max_wall_ticks`` (scheduler ticks from submission — the
        deterministic twin for tests) bound the request's lifetime: the
        watchdog truncates it to a TIMEOUT result instead of raising.
        Raises :class:`Unservable` for a request no amount of waiting
        can serve; a full bounded queue (``max_queue``) sheds the
        request immediately with a SHED result instead."""
        kcfg = self.kcfg if max_new is None else dataclasses.replace(
            self.kcfg, max_new_tokens=max_new)
        need = len(prompt) + self.n_prefix + kcfg.max_new_tokens
        if need > self.max_seq:
            raise Unservable(
                f"prompt needs {need} positions > pool max_seq={self.max_seq}")
        if strategy_factory is None:
            strategy_factory = (self.strategy_factory if method is None
                                else lambda: strategies.make_strategy(method))
        fan_out = strategy_factory().rows(kcfg)
        if fan_out > self.rows:
            raise Unservable(
                f"request fan-out {fan_out} > pool rows={self.rows}")
        rid = self._next_rid
        self._next_rid += 1
        item = _Queued(rid, np.asarray(prompt), rng, kcfg, need, fan_out,
                       strategy_factory, deadline_s=deadline_s,
                       max_wall_ticks=max_wall_ticks,
                       submit_tick=self.ticks)
        self._check_servable(item)
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            # graceful overload degradation: reject at the door with a
            # terminal SHED result rather than queueing into certain
            # deadline misses (the admitted requests' ITL is protected)
            self.counters["shed"] += 1
            self._record_result(rid, self._empty_result(item, "SHED"))
            return rid
        if deadline_s is not None or max_wall_ticks is not None:
            self._has_deadlines = True
        self._submit_t.setdefault(rid, self.clock())
        self.queue.append(item)
        return rid

    # ------------------------------------------------- request lifecycle

    def _empty_result(self, item: _Queued, status: str) -> GenResult:
        """Terminal result for a request that returns no tokens (shed,
        cancelled while queued, timed out while queued, quarantined)."""
        n = item.fan_out
        return GenResult(
            tokens=[], chosen_branch=-1,
            all_tokens=np.full((n, 1), -1, np.int32),
            lengths=np.zeros((n,), np.int64),
            logical_tokens=0, compute_tokens=0, peak_cache_bytes=0,
            steps=0, status=status, n_retries=item.n_retries)

    # ---------------------------------------------------- event emission

    @property
    def _emitting(self) -> bool:
        return self.event_sink is not None or self._tick_events is not None

    def _emit(self, ev: TokenEvent) -> None:
        if self._tick_events is not None:
            self._tick_events.append(ev)
        if self.event_sink is not None:
            self.event_sink(ev)

    def _emit_committed(self, rid: int, now: float) -> None:
        """Emit TokenEvents for an active request's newly *committed*
        tokens: tokens on the strategy's ``decided_branch`` — the branch
        certain to be the final choice (greedy always, kappa once pruned
        to one survivor, ST-BoN once truncated; BoN stays undecided until
        the terminal flush). A preempted/faulted request replays
        token-identically, so the streamed prefix stays valid across
        teardown: ``_streamed`` survives requeue and emission resumes
        past it."""
        if not self._emitting:
            return
        rs, _ = self.active[rid]
        b = rs.strategy.decided_branch(rs.branch_ids, rs.done)
        if b is None:
            return
        hi = int(rs.log.len[b])
        start = self._streamed.get(rid, 0)
        if hi <= start:
            return
        buf = rs.log.buf[b]
        for i in range(start, hi):
            self._emit(TokenEvent(rid=rid, kind="token", t=now, index=i,
                                  token=int(buf[i])))
        self._streamed[rid] = hi

    def _record_result(self, rid: int, res: GenResult) -> GenResult:
        """Single funnel for terminal results: store, window-account,
        flush any not-yet-streamed tokens (the committed prefix already
        emitted is always a prefix of ``res.tokens``), and emit the
        exactly-once terminal event."""
        assert rid not in self.results, f"duplicate terminal result {rid}"
        self.results[rid] = res
        self._win_counts["completed"] += 1
        if res.status == "OK":
            self._win_counts["ok"] += 1
            self._win_counts["ok_tokens"] += res.logical_tokens
        elif res.status == "SHED":
            self._win_counts["shed"] += 1
        start = self._streamed.pop(rid, 0)
        if self._emitting:
            now = self.clock()
            for i in range(start, len(res.tokens)):
                self._emit(TokenEvent(rid=rid, kind="token", t=now,
                                      index=i, token=int(res.tokens[i])))
            self._emit(TokenEvent(rid=rid, kind="end", t=now,
                                  index=len(res.tokens), status=res.status,
                                  result=res))
        return res

    def _finalize(self, rid: int, status: str) -> GenResult:
        """Terminal teardown for an ADMITTED request (mid-PREFILLING or
        mid-decode): emit its result under ``status`` and release every
        resource, in the completion path's exact order — result() reads
        the pooled controller mirrors, the prefix publication adopts
        live page refs, and only then do the pool slot and pages go
        away. An active request returns its partial tokens; a
        PREFILLING one has produced none yet."""
        item = self._items.pop(rid)
        self._admit_seq.pop(rid, None)
        if rid in self.prefilling:
            pf = self.prefilling.pop(rid)
            self._publish_prompt_pages(item.prompt, pf.slots[0], pf.filled)
            self._release(pf.slots)
            res = self._empty_result(item, status)
        else:
            rs, slots = self.active.pop(rid)
            self._slots_dev.pop(rid, None)
            res = rs.result()            # BEFORE release_pool (mirrors)
            res.status = status
            res.n_retries = item.n_retries
            self._publish_prefix(item, rs, slots)
            rs.strategy.release_pool()
            self._release(slots)
        return self._record_result(rid, res)

    def _requeue(self, rid: int) -> _Queued:
        """Non-terminal teardown: free an admitted request's rows (and
        storage) and hand back its original submission for replay. The
        paged backend pins the fully-written prompt extent into the
        prefix cache first, so the replay aliases it back as a hit. The
        replay decodes from the original submission RNG stream —
        token-for-token identical to a never-disturbed run."""
        if rid in self.prefilling:
            pf = self.prefilling.pop(rid)
            self._publish_prompt_pages(pf.item.prompt, pf.slots[0],
                                       pf.filled)
            self._release(pf.slots)
        else:
            rs, slots = self.active.pop(rid)
            self._slots_dev.pop(rid, None)
            item = self._items[rid]
            self._publish_prompt_pages(item.prompt, slots[0],
                                       len(item.prompt))
            rs.strategy.release_pool()
            self._release(slots)
        self._admit_seq.pop(rid, None)
        # latency stamps restart with the replay
        self.ttft.pop(rid, None)
        self.token_times.pop(rid, None)
        return self._items.pop(rid)

    def _retry_or_quarantine(self, item: _Queued) -> None:
        """Requeue a fault-hit request for replay with exponential
        backoff; after ``max_retries`` replays quarantine it as FAILED
        (post-fault partial state is suspect, so no tokens are
        returned) instead of letting one poisoned request grind the
        pool forever."""
        if item.n_retries >= self.max_retries:
            self.counters["failures"] += 1
            self._record_result(item.rid, self._empty_result(item, "FAILED"))
            return
        item.n_retries += 1
        self.counters["retries"] += 1
        item.not_before = self.ticks \
            + self.retry_backoff * 2 ** (item.n_retries - 1)
        self.queue.appendleft(item)

    def _youngest_started(self) -> int:
        """Youngest-admitted request holding pool resources — decoding
        OR still PREFILLING (a half-written prefill is the cheapest
        thing to evict: no decoded tokens are thrown away)."""
        cands = list(self.active) + list(self.prefilling)
        return max(cands, key=lambda r: self._admit_seq[r])

    def _recover_step_fault(self) -> None:
        """A device-step fault aborted the tick before any pool or
        allocator mutation (the injection point is ahead of page growth
        and the dispatch, and the donated buffers were never consumed).
        Tear down ONE victim — youngest-started, matching the
        preemption policy — and route it through the retry budget;
        everyone else simply retries the tick."""
        victim = self._youngest_started()
        self._retry_or_quarantine(self._requeue(victim))

    def _watchdog(self) -> None:
        """Deadline enforcement at tick entry: expire requests past
        their wall-clock deadline or tick budget. Truncate-and-return —
        an expired active request keeps the tokens it already has."""
        if not self._has_deadlines:
            return
        now = self.clock()

        def expired(item: _Queued) -> bool:
            if item.max_wall_ticks is not None \
                    and self.ticks - item.submit_tick >= item.max_wall_ticks:
                return True
            return item.deadline_s is not None \
                and now - self._submit_t[item.rid] >= item.deadline_s

        for rid in [r for r in list(self.active) + list(self.prefilling)
                    if expired(self._items[r])]:
            self._finalize(rid, "TIMEOUT")
            self.counters["timeouts"] += 1
        if any(expired(i) for i in self.queue):
            keep: deque = deque()
            for item in self.queue:
                if expired(item):
                    self._record_result(item.rid,
                                        self._empty_result(item, "TIMEOUT"))
                    self.counters["timeouts"] += 1
                else:
                    keep.append(item)
            self.queue = keep

    def cancel(self, rid: int) -> GenResult:
        """Tear down ``rid`` wherever it is in its lifecycle: a queued
        request is removed outright, a PREFILLING or active one is
        finalized with its resources released (rows, pages, pooled
        controller slot) under the publish-before-release protocol.
        Returns the terminal result — partial tokens if the request was
        mid-decode. Idempotent once terminal; unknown rids raise
        KeyError."""
        if rid in self.results:
            return self.results[rid]
        if rid in self.active or rid in self.prefilling:
            self.counters["cancelled"] += 1
            return self._finalize(rid, "CANCELLED")
        for i, item in enumerate(self.queue):
            if item.rid == rid:
                del self.queue[i]
                self.counters["cancelled"] += 1
                return self._record_result(
                    rid, self._empty_result(item, "CANCELLED"))
        raise KeyError(f"unknown request id {rid}")

    # --------------------------------------------------------- admission

    def _admit_one(self) -> bool:
        if self.admit_paused:
            return False
        if self._admit_left is not None and self._admit_left <= 0:
            return False            # this tick's prefill budget is spent
        idx = self._select_admit()
        if idx is None:
            return False
        item = self.queue[idx]
        del self.queue[idx]
        if self._admit_left is not None:
            self._admit_left -= len(item.prompt)
        n = item.fan_out
        slots = sorted(self.free[:n])
        del self.free[:n]
        self._items[item.rid] = item        # kept for preemption requeue
        self._admit_seq[item.rid] = self._admit_counter
        self._admit_counter += 1

        if self._chunked_ok:
            # PREFILLING state: the request owns its slots now and
            # advances one chunk per tick; decode rows never wait
            self.prefilling[item.rid] = self._begin_prefill(item, slots)
            return True

        # one-shot fallback: whole prompt in one dispatch, through a
        # transient cache sized to the PROMPT (not max_seq)
        pf_logits, cache1 = engine._prefill_one(
            self.params, self.cfg, item.prompt, self._prefill_seq(item),
            self.frontend)
        self.admit_peak_bytes = max(self.admit_peak_bytes,
                                    cache_lib.cache_bytes(cache1))
        # backends install the batch-1 prefill directly (the paged pool
        # aliases shared prompt pages; the contiguous pool broadcasts in
        # the scatter) — no N-row broadcast_batch tile on this path
        self._install(slots, item, cache1)
        self._start_request(item, slots, pf_logits)
        return True

    def _start_request(self, item: _Queued, slots: List[int],
                       pf_logits) -> None:
        """Shared admission tail: build the RequestState, sample the
        fan-out's first tokens from the prefill logits, and either
        activate the request or (already finished) emit its result.
        Identical for one-shot and chunked admissions — the bitwise
        equality of the final chunk's logits makes the two paths
        token-for-token interchangeable."""
        rs = strategies.RequestState(
            item.factory(), self.params, self.cfg, item.kcfg,
            len(item.prompt), item.rng, eos_id=self.eos_id,
            bos_id=self.bos_id, max_seq=self.max_seq,
            n_prefix=self.n_prefix, frontend=self.frontend)
        self._maybe_pool_controller(rs, item)
        rs.first_tokens(pf_logits)
        now = self.clock()
        self.ttft[item.rid] = now - self._submit_t[item.rid]
        self._win_ttft.append(self.ttft[item.rid])
        self.token_times[item.rid] = [now]
        if rs.finished:  # e.g. greedy whose first token is already EOS
            res = rs.result()
            res.n_retries = item.n_retries
            self._record_result(item.rid, res)
            self._publish_prefix(item, rs, slots)
            rs.strategy.release_pool()
            self._release(slots)
            self._items.pop(item.rid, None)
            self._admit_seq.pop(item.rid, None)
        else:
            self.active[item.rid] = (rs, slots)
            self._slots_dev[item.rid] = jnp.asarray(slots)
            self.row_token[slots] = rs.cur
            self.row_pos[slots] = rs.pos

    def _fuse_candidates(self) -> List[int]:
        """rids of the PREFILLING requests whose next chunks should ride
        the tick's fused decode dispatch instead of their own standalone
        dispatches (backends that support it return all of them in
        admission order; base: none)."""
        return []

    def _account_pages_tick(self) -> None:
        """Page-usage accounting for ticks that skip the decode path
        (prefill-only); the paged backend overrides."""

    def _advance_one_prefill(self, rid: int) -> None:
        """One standalone chunk for ``rid`` (absent = already preempted
        by a sibling's page growth), with finalize + activation when it
        was the prompt's last chunk."""
        pf = self.prefilling.get(rid)
        if pf is None:
            return
        logits = self._prefill_step(pf)
        if logits is not None and rid in self.prefilling:
            if self._finish_prefill(pf):
                del self.prefilling[rid]
                self._start_request(pf.item, pf.slots, logits)

    def _advance_prefills(self) -> None:
        """Advance every PREFILLING request by one chunk (admission
        order). A request whose final chunk just ran is finalized and
        activated in the same tick, so its rows join this tick's fused
        decode step exactly like a one-shot admission would. Fuse
        candidates are skipped here — their chunks run inside the decode
        dispatch and complete in ``_post_tick_prefill``."""
        t0 = time.perf_counter()
        self._fused_rids = self._fuse_candidates()
        fused = set(self._fused_rids)
        for rid in sorted(list(self.prefilling),
                          key=lambda r: self._admit_seq[r]):
            if rid not in fused:
                self._advance_one_prefill(rid)
        self.tick_time["prefill"] += time.perf_counter() - t0

    def _post_tick_prefill(self) -> None:
        """Finalize a fused chunk that completed its prompt this tick
        (the activated request joins the NEXT decode tick)."""

    def _publish_prefix(self, item: Optional[_Queued], rs, slots) -> None:
        """Completion hook, called BEFORE the request's storage is
        released: backends may retain its prefix extent (the paged
        backend publishes prompt + winner pages into the radix prefix
        cache). Base: nothing to retain."""

    def _release(self, slots: List[int]) -> None:
        self._release_storage(slots)
        self.row_token[slots] = 0
        self.row_pos[slots] = 0
        self.free.extend(slots)
        self.free.sort()

    def _maybe_pool_controller(self, rs: strategies.RequestState,
                               item: _Queued) -> None:
        """Attach a pooled-controller slot to a kappa request. Pooling
        needs the fused tick (signals come from the pool logits) and a
        controller-compatible kcfg; anything else keeps the per-request
        local controller, which stays correct — just slower."""
        if not (self.fused_sampling
                and isinstance(rs.strategy, strategies.KappaStrategy)
                and strategies.controller_key(item.kcfg) == self._ctrl_key):
            return
        if self._kappa_pool is None:
            # slots = rows: every concurrent kappa request holds >= 1 pool
            # row, so this bounds the slot count with ONE compiled tick
            # shape. Inactive slots ride the dispatch (gather row 0, result
            # discarded) — wasted compute is bounded by rows x fan_out x V
            # and avoids a bucketed-shape retrace chain; revisit if pools
            # grow to where idle-slot compute shows in the tick breakdown.
            self._kappa_pool = strategies.PooledKappaController(
                self.params, self.cfg, self.kcfg, slots=self.rows,
                bos_id=self.bos_id, frontend=self.frontend)
        slot = self._kappa_pool.acquire(rs.n)
        rs.strategy.attach_pool(self._kappa_pool, slot, rs.n)

    # -------------------------------------------------------------- tick

    def _pooled_kappa_dispatch(self, logits, toks_dev):
        """Build the slot→pool-row gather map for every pooled kappa
        request and advance ALL their controllers in one device dispatch.
        Returns the device (alive, traj, cutoff) tuple, or None when no
        pooled kappa request is active."""
        pool = self._kappa_pool
        if pool is None:
            return None
        pooled = [(rs, slots) for rs, slots in self.active.values()
                  if getattr(rs.strategy, "pool", None) is pool]
        if not pooled:
            return None
        gather_idx = np.zeros((pool.slots, pool.nmax), np.int32)
        done_prev = np.ones((pool.slots, pool.nmax), bool)
        for rs, slots in pooled:
            st = rs.strategy
            gather_idx[st.slot, st.ctrl_rows] = slots
            done_prev[st.slot, st.ctrl_rows] = rs.done[rs.branch_ids]
        self.counters["controller_dispatches"] += 1
        return pool.dispatch(logits, toks_dev, gather_idx, done_prev,
                             self.eos_id)

    def tick(self) -> None:
        """Admit what fits, advance every PREFILLING request one chunk,
        run one fused decode step over the pool, one fused sampler
        dispatch over all active rows, one fused pooled kappa-controller
        dispatch, ONE blocking device transfer carrying tokens +
        controller outputs, then advance every active request on its own
        rows (pure host work). Decode rows therefore never wait for a
        whole admission prefill — at most one chunk of it runs inside
        their tick."""
        self._watchdog()
        self._fault_tick = self._begin_fault_tick()
        self._admit_left = self.prefill_budget
        while self._admit_one():
            pass
        self._advance_prefills()
        if not self.active:
            # pure-backoff and embargo-blocked ticks still count as
            # progress: the tick index must advance for `not_before`
            # stamps to expire and for the next tick's fault draw
            progressed = bool(self.prefilling) \
                or any(i.not_before > self.ticks for i in self.queue) \
                or (self._fault_tick and bool(self.queue)) \
                or (self.admit_paused and bool(self.queue)) \
                or (self._admit_left is not None and self._admit_left <= 0
                    and bool(self.queue))
            if self._fused_rids:
                # the decode dispatch these chunks were to ride vanished
                # (a sibling's page growth preempted the whole pool) —
                # run them standalone so no prefill loses its turn
                rids, self._fused_rids = self._fused_rids, []
                for rid in rids:
                    self._advance_one_prefill(rid)
            if progressed:
                # PREFILLING requests hold rows (and, paged, pages) —
                # account them so utilization metrics stay honest over
                # chunked-admission-heavy stretches
                self._occupied_ticks += self.rows - len(self.free)
                self._account_pages_tick()
                self.ticks += 1
            return
        self._occupied_ticks += self.rows - len(self.free)

        t0 = time.perf_counter()
        try:
            logits = self._decode_tick()
        except faults_lib.InjectedStepFault:
            # the injection point is BEFORE any pool/allocator mutation,
            # so the tick simply didn't happen: tear one victim down
            # through the retry budget and let everyone else retry
            self.counters["faults_injected"] += 1
            self._recover_step_fault()
            self.tick_time["model"] += time.perf_counter() - t0
            self.ticks += 1
            return
        finite_dev = None
        if self.faults is not None:
            bad = self.faults.nan_rows_for(self.ticks, self.rows)
            if bad.size:
                self.counters["faults_injected"] += 1
                logits = logits.at[jnp.asarray(bad)].set(jnp.nan)
            # detection is device-side (a fused finite-mask riding the
            # tick's blocking transfer), not host knowledge of `bad` —
            # the same path a real numerics blowup would take
            finite_dev = engine.rows_finite(logits)
        t1 = time.perf_counter()
        self.tick_time["model"] += t1 - t0

        toks = picked = finite = None
        if self.fused_sampling:
            # one fused per-row-keyed sampling dispatch for the whole
            # pool; free rows ride along as masked argmax (ignored)
            keys = np.zeros((self.rows, 2), np.uint32)
            gmask = np.ones((self.rows,), bool)
            want_lp = False
            key_devs = {}
            for rid, (rs, slots) in self.active.items():
                key_devs[rid] = rs.step_keys()   # device splits, no sync
                gmask[slots] = rs.strategy.greedy
                want_lp |= rs.strategy.wants_picked_lp
            key_np = jax.device_get(key_devs)    # one blocking transfer
            self.counters["host_syncs"] += 1
            for rid, (rs, slots) in self.active.items():
                keys[slots] = key_np[rid]
            # picked-token log-probs fused into the sampling dispatch
            # so BoN-style strategies do zero device work per request
            out_dev = sampler.sample_rows(
                jnp.asarray(keys), logits, jnp.asarray(gmask), self.kcfg,
                want_picked_lp=want_lp)
            self.counters["sampler_dispatches"] += 1
            toks_dev = out_dev[0] if want_lp else out_dev
            t2 = time.perf_counter()
            self.tick_time["sampler"] += t2 - t1

            # the pooled controller consumes the pool logits and the
            # just-sampled tokens device-to-device — no host round-trip
            ctrl_dev = self._pooled_kappa_dispatch(logits, toks_dev)
            t3 = time.perf_counter()
            self.tick_time["controller"] += t3 - t2

            # ONE blocking transfer for sampled tokens, picked log-probs
            # AND all pooled controller outputs (alive/traj/cutoff of
            # every kappa request), independent of active-request count
            out, ctrl_host, finite = jax.device_get(
                (out_dev, ctrl_dev, finite_dev))
            self.counters["host_syncs"] += 1
            if ctrl_host is not None:
                self.counters["controller_syncs"] += 1
                self._kappa_pool.publish(ctrl_host)
            toks, picked = out if want_lp else (out, None)
            self.tick_time["sync"] += time.perf_counter() - t3
        elif finite_dev is not None:
            finite = jax.device_get(finite_dev)

        t4 = time.perf_counter()
        if finite is not None and not bool(np.all(finite)):
            # NaN-poisoned rows: tear the owning requests down BEFORE
            # the advance loop, so poisoned tokens never reach a token
            # log or a result. The pooled controller consumed the
            # poison for one dispatch, but its finite-guard
            # (core/kappa.py) kept it out of sibling branches' scores,
            # the victim's slot is reset on re-acquire, and other slots
            # are untouched (vmap independence).
            for rid in [r for r, (_, s) in list(self.active.items())
                        if not bool(np.all(finite[s]))]:
                self._retry_or_quarantine(self._requeue(rid))
        stamped = list(self.active)
        for rid in list(self.active):
            rs, slots = self.active[rid]
            if toks is None:
                dec = rs.sample_and_advance(logits[self._slots_dev[rid]])
            else:
                lp = picked[slots] if (picked is not None
                                       and rs.strategy.wants_picked_lp) else None
                # skip the per-request device gather when the strategy
                # won't read the logits (greedy; BoN once lp is fused;
                # pooled kappa — its signals come from the pool logits)
                if rs.strategy.needs_step_logits and lp is None:
                    req_logits = logits[self._slots_dev[rid]]
                else:
                    req_logits = None
                dec = rs.advance(req_logits, toks[slots], picked_lp=lp)
            if dec.keep is not None:
                kept = [slots[i] for i in dec.keep]
                self._release(sorted(set(slots) - set(kept)))
                slots = kept
                self.active[rid] = (rs, slots)
                self._slots_dev[rid] = jnp.asarray(slots)
            self.row_token[slots] = rs.cur
            self.row_pos[slots] = rs.pos
            if rs.finished:
                # publish-before-release ordering lives in _finalize:
                # the radix pin must adopt live refs, and kappa's winner
                # check reads the pooled controller mirrors
                self._finalize(rid, "OK")
        self._post_tick_prefill()
        now = self.clock()
        for rid in stamped:
            times = self.token_times.get(rid)
            if times is not None:      # absent iff preempted mid-tick
                self._win_itl.append(now - times[-1])
                times.append(now)
            if rid in self.active:     # finalized rids flushed already
                self._emit_committed(rid, now)
        self.tick_time["host"] += time.perf_counter() - t4
        self.ticks += 1

    # --------------------------------------------------------------- run

    def run(self) -> Dict[int, GenResult]:
        """Drive queue + pool to completion; returns rid -> GenResult."""
        t0 = self.clock()

        def state():
            return (len(self.queue), len(self.active), len(self.prefilling),
                    sum(pf.filled for pf in self.prefilling.values()))

        while self.queue or self.active or self.prefilling:
            before = state()
            pre = self.ticks
            self.tick()
            if not self.active and not self.prefilling and self.queue \
                    and state() == before:
                # compare backoff stamps against the PRE-tick counter: an
                # item whose not_before equals the new tick index was
                # still backing off during the tick that just ran and
                # deserves one more tick to be admitted. When the tick
                # made no progress (counter unchanged) pre == self.ticks
                # and this degenerates to the strict stall check.
                if self._fault_tick \
                        or any(i.not_before > pre
                               for i in self.queue):
                    continue   # backoff / embargo, not a stall: the
                    #              tick advanced, the next one re-draws
                raise RuntimeError(
                    "scheduler stalled: queued request cannot be admitted "
                    f"(free={len(self.free)} rows, "
                    f"admit_paused={self.admit_paused})")
        self._end_run()
        self.elapsed = self.clock() - t0
        return dict(sorted(self.results.items()))

    # ------------------------------------------------ incremental surface

    @property
    def has_work(self) -> bool:
        """True while anything is queued, prefilling, or decoding."""
        return bool(self.queue or self.active or self.prefilling)

    def step(self) -> List[TokenEvent]:
        """One incremental tick with event capture: returns the
        ``TokenEvent``s emitted during that tick (committed streamed
        tokens plus terminal events), in emission order.  This is the
        front-end's drive surface — unlike ``run()`` it never blocks past
        a single tick, and it makes no stall judgment (an idle step on a
        backed-off or paused queue just returns ``[]``; the caller owns
        liveness).  ``event_sink`` still fires for every captured event,
        so push and pull consumers see the same stream."""
        self._tick_events = []
        try:
            if self.has_work:
                self.tick()
            return self._tick_events
        finally:
            self._tick_events = None

    def snapshot(self, reset_window: bool = False) -> Dict[str, float]:
        """Windowed latency/throughput counters accumulated since the
        last ``snapshot(reset_window=True)`` (or construction).  The SLO
        controller and the open-loop arrival sweeps read per-window
        percentiles here instead of the run-lifetime aggregates in
        ``latency_stats()``/``throughput()``, so a transient overload is
        visible the window it happens rather than diluted over the run."""
        now = self.clock()
        win_s = max(now - self._win_t0, 1e-9)
        ttft, itl = self._win_ttft, self._win_itl
        out = {
            "window_s": win_s,
            "window_ticks": self.ticks - self._win_tick0,
            "queued": len(self.queue),
            "active": len(self.active),
            "prefilling": len(self.prefilling),
            "admit_paused": bool(self.admit_paused),
            "prefill_budget": self.prefill_budget,
            "ttft_count": len(ttft),
            "itl_count": len(itl),
            "completed": self._win_counts["completed"],
            "ok": self._win_counts["ok"],
            "shed": self._win_counts["shed"],
            "ok_tokens": self._win_counts["ok_tokens"],
            "goodput_tokens_per_s": self._win_counts["ok_tokens"] / win_s,
            "ttft_p50_s": float(np.percentile(ttft, 50)) if ttft else 0.0,
            "ttft_p99_s": float(np.percentile(ttft, 99)) if ttft else 0.0,
            "itl_p50_s": float(np.percentile(itl, 50)) if itl else 0.0,
            "itl_p99_s": float(np.percentile(itl, 99)) if itl else 0.0,
        }
        if reset_window:
            self._win_t0 = now
            self._win_tick0 = self.ticks
            self._win_ttft = []
            self._win_itl = []
            self._win_counts = {"completed": 0, "ok": 0,
                                "ok_tokens": 0, "shed": 0}
        return out

    # ----------------------------------------------------------- metrics

    def request_bytes(self) -> Dict[int, int]:
        """Per-request paged-view bytes currently referenced in the pool."""
        return cache_lib.per_request_bytes(
            self.cfg, {rid: (len(slots), rs.pos)
                       for rid, (rs, slots) in self.active.items()},
            self.max_seq)

    def throughput(self) -> Dict[str, float]:
        """Aggregate serving metrics over a completed ``run()``."""
        total_logical = sum(r.logical_tokens for r in self.results.values())
        total_compute = sum(r.compute_tokens for r in self.results.values())
        elapsed = max(getattr(self, "elapsed", 0.0), 1e-9)
        out = {
            "requests": len(self.results),
            "ticks": self.ticks,
            "time_s": elapsed,
            "logical_tokens": total_logical,
            "compute_tokens": total_compute,
            "tokens_per_s": total_logical / elapsed,
            "requests_per_s": len(self.results) / elapsed,
            "row_utilization": (self._occupied_ticks
                                / max(self.ticks * self.rows, 1)),
        }
        # per-tick breakdown: model step vs sampler dispatch vs pooled
        # controller dispatch vs the blocking transfer vs per-request
        # host work (which absorbs UNPOOLED controller dispatch + sync —
        # the regression the breakdown exists to make visible)
        for k, v in self.tick_time.items():
            out[f"time_{k}_s"] = v
        out.update(self.counters)
        status_counts: Dict[str, int] = {}
        for r in self.results.values():
            status_counts[r.status] = status_counts.get(r.status, 0) + 1
        out["status_counts"] = status_counts
        out["admit_peak_bytes"] = self.admit_peak_bytes
        out.update(self.latency_stats())
        return out

    def latency_stats(self) -> Dict[str, float]:
        """TTFT / inter-token-latency percentiles over every request
        served so far (per-request stamps stay in ``token_times`` for
        finer-grained windows — the interleaving benchmark reads them
        directly)."""
        ttft = np.asarray(sorted(self.ttft.values()) or [0.0])
        itl = np.asarray([d for ts in self.token_times.values()
                          for d in np.diff(ts)] or [0.0])
        return {
            "ttft_p50_s": float(np.percentile(ttft, 50)),
            "ttft_p99_s": float(np.percentile(ttft, 99)),
            "itl_p50_s": float(np.percentile(itl, 50)),
            "itl_p99_s": float(np.percentile(itl, 99)),
            "itl_max_s": float(itl.max()),
        }


class ContinuousBatchingScheduler(_SchedulerBase):
    """Contiguous-pool scheduler: a fixed ``(rows, max_seq)`` device
    cache allocated once, FIFO admission counted in rows (no head-of-line
    bypass, keeping completion order fair). Every admitted row reserves
    ``max_seq`` KV slots for its whole life — the reservation slack the
    paged backend removes.

    Parameters
    ----------
    rows : total branch slots in the device pool. Must be >= the fan-out
        of a single request (``strategy.rows(kcfg)``).
    max_seq : shared sequence capacity of every pool row. Each admitted
        prompt must satisfy ``len(prompt) + n_prefix + max_new <= max_seq``.
    method : one of "greedy" | "bon" | "stbon" | "kappa"; or pass
        ``strategy_factory`` for custom construction (e.g. ST-BoN with a
        non-default buffer window).
    """

    def __init__(self, params, cfg: ModelConfig, kcfg: KappaConfig, *,
                 rows: int, max_seq: int, method: str = "kappa",
                 eos_id: int, bos_id: int = 0, frontend=None,
                 strategy_factory=None, fused_sampling: bool = True,
                 prefill_chunk: Optional[int] = None,
                 faults: Optional[faults_lib.FaultPlan] = None,
                 max_retries: int = 3, retry_backoff: int = 2,
                 max_queue: Optional[int] = None,
                 clock: Optional[Callable[[], float]] = None,
                 event_sink: Optional[Callable[[TokenEvent], None]] = None):
        super().__init__(params, cfg, kcfg, rows=rows, max_seq=max_seq,
                         method=method, eos_id=eos_id, bos_id=bos_id,
                         frontend=frontend, strategy_factory=strategy_factory,
                         fused_sampling=fused_sampling,
                         prefill_chunk=prefill_chunk, faults=faults,
                         max_retries=max_retries, retry_backoff=retry_backoff,
                         max_queue=max_queue, clock=clock,
                         event_sink=event_sink)
        self.pool = init_cache(cfg, rows, max_seq)

    def _admissible(self, item: _Queued) -> bool:
        return len(self.free) >= item.fan_out

    def _select_admit(self) -> Optional[int]:
        # FIFO among READY items: admit the first one not backing off,
        # or nothing — head-or-nothing, so ready requests keep FIFO
        # completion order while a retry waits out its backoff
        for i, item in enumerate(self.queue):
            if item.not_before > self.ticks:
                continue
            return i if self._admissible(item) else None
        return None

    def _install(self, slots, item, sub1) -> None:
        # the batch-1 prefill broadcasts across the n slots inside the
        # scatter itself (prefix-extent: the sub-cache is prompt-sized,
        # row tails past the prompt are never read) — no separate N-row
        # tile materialized
        self.pool = _scatter(self.pool, jnp.asarray(slots), sub1)

    # ------------------------------------------------- chunked prefill

    def _begin_prefill(self, item, slots) -> _Prefill:
        cache1 = init_cache(self.cfg, 1, self._prefill_seq(item))
        self.admit_peak_bytes = max(self.admit_peak_bytes,
                                    cache_lib.cache_bytes(cache1))
        return _Prefill(item=item, slots=slots, cache1=cache1)

    def _prefill_step(self, pf: _Prefill):
        plen = len(pf.item.prompt)
        c = min(self.prefill_chunk, plen - pf.filled)
        piece = pf.item.prompt[pf.filled:pf.filled + c]
        logits, pf.cache1, _ = engine._prefill_chunk_contig(
            self.params, self.cfg, jnp.asarray(piece)[None],
            jnp.full((1,), pf.filled, jnp.int32), pf.filled, pf.cache1)
        pf.filled += c
        return logits[0] if pf.filled >= plen else None

    def _finish_prefill(self, pf: _Prefill) -> bool:
        self._install(pf.slots, pf.item, pf.cache1)
        pf.cache1 = None
        return True

    def _decode_tick(self):
        engine.check_step_fault(self.faults, self.ticks)
        logits, self.pool = engine._model_step(
            self.params, self.cfg, jnp.asarray(self.row_token),
            jnp.asarray(self.row_pos), self.pool)
        return logits


class PagedScheduler(_SchedulerBase):
    """Paged-pool scheduler (DESIGN.md §5).

    Global-attention KV lives in a shared page pool; each row addresses
    it through a ``(max_pages,)`` block table. Fan-out branches *share*
    the fully-written prompt pages copy-on-write: admission allocates
    them once, aliases them into all N branch tables, and gives each
    branch a private copy of the partially-written boundary page (where
    divergent decode writes land) plus one decode page — so admission
    costs ``prompt_pages + N × (1 + boundary)`` pages instead of
    ``N × ceil(need / page_size)``. Decode pages are acquired *lazily*,
    one page per row as its position crosses a page boundary; when the
    free list runs dry the scheduler preempts the youngest-admitted
    request (pages freed, request requeued and replayed from its
    original RNG — token-for-token identical to an un-preempted run)
    instead of deadlocking. Pruning a branch drops its page references
    immediately; a page returns to the free heap when its last
    reference goes.

    Queued requests are admitted shortest-job-first among those whose
    rows *and* initial pages fit (FIFO tie-break on equal need), with
    bounded bypass: once the queue head has been bypassed
    ``max_bypass`` times, it is admitted next or nothing is — a steady
    stream of short submissions can no longer starve a long request.

    Parameters
    ----------
    rows : row slots (block tables / position vector entries).
    max_seq : upper bound on any request's ``prompt + n_prefix + max_new``
        (rounded up to a page multiple internally).
    page_size : token slots per page. On TPU this should match the
        flash-decode kernel's S-tile so one page = one VMEM tile DMA.
    num_pages : allocatable pages in the pool — the real memory knob.
        Defaults to ``rows * max_seq / page_size`` (no page pressure);
        set lower to serve more rows than a contiguous pool of the same
        byte budget could.
    page_budget_bytes : alternative memory knob — an HBM byte budget
        for the global-layer page pool, converted to ``num_pages`` via
        allocator-truth :func:`cache.page_bytes` (so an int8
        ``kv_cache_dtype`` yields ≈2× the pages of fp32/bf16 under the
        same budget). Mutually exclusive with ``num_pages``.
    max_bypass : SJF aging bound (see above).
    prefix_cache : enable the cross-request radix prefix cache
        (DESIGN.md §7). Completed/preempted requests publish their
        fully-written prompt pages (and the winner's generated prefix)
        into a radix tree that pins them in the allocator; later
        admissions alias every matched page and chunk-prefill only the
        uncached tail. Requires chunked admission (``prefill_chunk``)
        and an all-global layer pattern — anything else silently keeps
        the cache off (aux ring/recurrent state cannot be recovered
        from pages, and only the chunked path can resume a prefill at a
        nonzero offset).
    """

    def __init__(self, params, cfg: ModelConfig, kcfg: KappaConfig, *,
                 rows: int, max_seq: int, page_size: int = 64,
                 num_pages: Optional[int] = None, method: str = "kappa",
                 eos_id: int, bos_id: int = 0, frontend=None,
                 strategy_factory=None, fused_sampling: bool = True,
                 max_bypass: int = 4, prefill_chunk: Optional[int] = None,
                 prefix_cache: bool = False,
                 faults: Optional[faults_lib.FaultPlan] = None,
                 max_retries: int = 3, retry_backoff: int = 2,
                 max_queue: Optional[int] = None,
                 clock: Optional[Callable[[], float]] = None,
                 event_sink: Optional[Callable[[TokenEvent], None]] = None,
                 page_budget_bytes: Optional[int] = None):
        max_seq = -(-max_seq // page_size) * page_size
        if page_budget_bytes is not None:
            if num_pages is not None:
                raise ValueError("pass num_pages or page_budget_bytes, "
                                 "not both")
            num_pages = page_budget_bytes \
                // cache_lib.page_bytes(cfg, page_size)
            if num_pages < 1:
                raise ValueError(
                    f"page_budget_bytes={page_budget_bytes} below one "
                    f"page ({cache_lib.page_bytes(cfg, page_size)}B)")
        super().__init__(params, cfg, kcfg, rows=rows, max_seq=max_seq,
                         method=method, eos_id=eos_id, bos_id=bos_id,
                         frontend=frontend, strategy_factory=strategy_factory,
                         fused_sampling=fused_sampling,
                         prefill_chunk=prefill_chunk, faults=faults,
                         max_retries=max_retries, retry_backoff=retry_backoff,
                         max_queue=max_queue, clock=clock,
                         event_sink=event_sink)
        self.page_size = page_size
        self.max_pages = max_seq // page_size
        self.num_pages = num_pages if num_pages is not None \
            else rows * self.max_pages
        self.max_bypass = max_bypass
        self.alloc = cache_lib.PageAllocator(self.num_pages, page_size,
                                             rows, self.max_pages,
                                             fault_plan=self.faults)
        self.pool = init_paged_cache(cfg, rows, self.num_pages, page_size,
                                     max_seq)
        # radix prefix cache: only sound when every layer's KV is page-
        # resident (all-global) and admission can resume a prefill at
        # the cached extent (chunked)
        self.pcache: Optional[cache_lib.RadixPrefixCache] = None
        if prefix_cache and self._chunked_ok \
                and all(bt == "global" for bt in cfg.block_types()):
            self.pcache = cache_lib.RadixPrefixCache(self.alloc, page_size)
        self.counters.update({
            "prefix_hits": 0, "prefix_misses": 0,
            "prefix_tokens_saved": 0, "prefix_evictions": 0,
            "fused_chunks": 0,
        })
        self._page_ticks = 0                 # Σ pages in use over ticks
        self._page_peak = 0                  # max pages in use at any tick
        self._bt_dev = None                  # device block tables (cached)

    # --------------------------------------------------- page accounting

    def _prompt_pos(self, item: _Queued) -> int:
        """First decode-write position (= installed prompt length)."""
        return len(item.prompt) + self.n_prefix

    def _shared_pages(self, item: _Queued) -> int:
        """Prompt pages installed once from the prefill. With fan-out
        N > 1 these are the fully-written pages all branches alias
        read-only; a single-branch request has no sibling to share with,
        so its partially-written boundary page is installed directly too
        (it is refcount-1 either way — no COW copy needed)."""
        pos0 = self._prompt_pos(item)
        if item.fan_out == 1:
            return self.alloc.pages_for(pos0)
        return pos0 // self.page_size

    def _boundary(self, item: _Queued) -> int:
        """1 if each branch needs a private COW copy of a mid-page
        prompt boundary, else 0 (page-aligned prompt, or fan-out 1 —
        see :meth:`_shared_pages`)."""
        if item.fan_out == 1:
            return 0
        return 1 if self._prompt_pos(item) % self.page_size else 0

    def _priv_worst(self, item: _Queued) -> int:
        """Private pages one branch can grow to (its ``need`` positions
        minus the shared prompt pages)."""
        return self.alloc.pages_for(item.need) - self._shared_pages(item)

    def _initial_priv(self, item: _Queued) -> int:
        """Private pages per branch at admission: the boundary COW copy
        (if any) plus one decode page, capped at the branch's worst case
        (a short request may never leave its boundary page)."""
        return min(1 + self._boundary(item), self._priv_worst(item))

    def _initial_pages(self, item: _Queued) -> int:
        """Pages allocated at admission: shared prompt pages once, plus
        each branch's initial private pages."""
        return self._shared_pages(item) \
            + item.fan_out * self._initial_priv(item)

    def _worst_pages(self, item: _Queued) -> int:
        """Lifetime peak with lazy growth: shared prompt pages once plus
        each branch's private pages grown to cover ``need`` positions."""
        return self._shared_pages(item) \
            + item.fan_out * self._priv_worst(item)

    # ----------------------------------------------------------- storage

    def _check_servable(self, item: _Queued) -> None:
        # worst case must fit the pool ALONE: this is what guarantees
        # preemption always unblocks growth (see _ensure_pages)
        total = self._worst_pages(item)
        if total > self.num_pages:
            raise Unservable(
                f"request needs {total} pages > pool num_pages="
                f"{self.num_pages} (page_size={self.page_size})")

    def _admissible(self, item: _Queued) -> bool:
        # pin-only cached pages count as free capacity: admission may
        # rely on eviction (see _reclaim) — without this slack a pool
        # whose free heap is all pinned prefixes would refuse every
        # admission and stall run() with nothing active to preempt.
        # avail_count (not free_count): an injected allocator embargo
        # must gate admission and growth consistently within the tick
        slack = self.pcache.evictable_count if self.pcache is not None else 0
        return (len(self.free) >= item.fan_out
                and self.alloc.avail_count + slack
                >= self._initial_pages(item))

    def _select_admit(self) -> Optional[int]:
        # shortest-job-first among fitting requests, FIFO tie-break —
        # with bounded bypass so a steady short stream cannot starve the
        # oldest request: after max_bypass bypasses the head is admitted
        # next-fit-or-nothing (admission pauses until it fits). Items
        # backing off after a fault retry are skipped until their
        # not_before tick; the aged head keeps its fast path only once
        # it is ready itself.
        if not self.queue:
            return None
        head = self.queue[0]
        if head.not_before <= self.ticks \
                and head.bypasses >= self.max_bypass:
            return 0 if self._admissible(head) else None
        best, best_need = None, None
        for i, item in enumerate(self.queue):
            if item.not_before > self.ticks:
                continue
            if self._admissible(item) and (best is None
                                           or item.need < best_need):
                best, best_need = i, item.need
        if best is not None:
            for i in range(best):
                self.queue[i].bypasses += 1
        return best

    def _install(self, slots, item, sub1) -> None:
        full = self._shared_pages(item)
        boundary = self._boundary(item)
        n_priv = self._initial_priv(item)
        shared = self.alloc.alloc_pages(full)
        # (src logical page -> dst physical page) scatter map: shared
        # prompt pages once, the boundary page once per branch (its COW
        # copy), nothing for the empty first decode page
        src = list(range(full))
        phys = list(shared)
        for s in slots:
            priv = self.alloc.alloc_pages(n_priv)
            if boundary:
                src.append(full)
                phys.append(priv[0])
            self.alloc.set_row_pages(s, list(shared) + priv)
        self._bt_dev = None
        self.pool = _install_shared(
            self.cfg, self.pool, jnp.asarray(slots),
            jnp.asarray(np.asarray(src, np.int32)),
            jnp.asarray(np.asarray(phys, np.int32)), sub1, self.page_size)

    def _release_storage(self, slots) -> None:
        for s in slots:
            self.alloc.free_row(s)
        self._bt_dev = None

    # ------------------------------------------- lazy growth / preemption

    def _begin_fault_tick(self) -> bool:
        hb = self.alloc.begin_tick(self.ticks)
        if hb:
            self.counters["faults_injected"] += 1
        return hb > 0

    def _end_run(self) -> None:
        self.alloc.holdback = 0

    def _publish_prompt_pages(self, prompt: np.ndarray, slot: int,
                              upto: int) -> None:
        """Pin the fully-written pages covering ``prompt[:upto]`` (row
        ``slot``'s block-table prefix) into the radix tree — the
        preemption-side publication point: the pages are about to lose
        their table references, and re-prefilling them on re-admission
        (or by any sharer) would be pure waste."""
        if self.pcache is None:
            return
        k = upto // self.page_size
        if k:
            pages = [int(p) for p in self.alloc.block[slot, :k]]
            self.pcache.publish(np.asarray(prompt)[:k * self.page_size],
                                pages)

    def _preempt(self, rid: int) -> None:
        """Evict ``rid`` (active or mid-PREFILLING): free its pages and
        rows (:meth:`_requeue` — fully-written prompt pages are
        published into the prefix cache first, so the replay aliases
        them back as a hit), return its original submission to the
        queue head. On re-admission it replays prefill and decode from
        its original RNG stream, so the final tokens are identical to a
        never-preempted run. Preemptions forced by an injected
        allocator embargo are charged to the victim's retry budget —
        genuine pressure requeues for free."""
        item = self._requeue(rid)
        self.counters["preemptions"] += 1
        if self._fault_tick:
            self._retry_or_quarantine(item)
        else:
            self.queue.appendleft(item)

    def _reclaim(self, n: int) -> bool:
        """Make ``n`` pages allocatable by evicting least-recently-hit
        pin-only pages from the prefix cache. Eviction is ordered BEFORE
        preemption at every allocation site: dropping cached-but-idle
        prefix pages only costs a future re-prefill, while preemption
        throws away live decode progress — and without this ordering
        pinned pages could hold the heap dry forever (nothing ever
        unpins them) and deadlock admission. Returns False when the free
        heap is still short and nothing is evictable (the caller falls
        through to preemption)."""
        while not self.alloc.can_alloc(n):
            if self.pcache is None or self.pcache.evict_one() is None:
                return False
            self.counters["prefix_evictions"] += 1
        return True

    def _ensure_pages(self) -> None:
        """Lazy growth: before the fused decode step, every active row
        whose position has crossed into an unallocated logical page
        acquires the next page from the free heap (evicting cached
        prefix pages first — :meth:`_reclaim`). Requests grow in
        admission order (oldest first); when nothing more is evictable
        the youngest-admitted request is preempted — possibly the grower
        itself, when everything younger is already gone."""
        for rid in sorted(self.active, key=lambda r: self._admit_seq[r]):
            if rid not in self.active:       # preempted below
                continue
            rs, slots = self.active[rid]
            evicted = False
            for s in slots:
                lp = int(self.row_pos[s]) // self.page_size
                while int(self.alloc.owned[s]) <= lp:
                    if self._reclaim(1):
                        self.alloc.append_page(s)
                        self._bt_dev = None
                        continue
                    victim = self._youngest_started()
                    self._preempt(victim)
                    if victim == rid:
                        evicted = True
                        break
                if evicted:
                    break

    # ------------------------------------------------- chunked prefill
    #
    # Chunk K/V goes STRAIGHT into allocator-owned pages through
    # slot[0]'s block table — no batch-1 side cache for the global
    # layers, no install scatter for the prompt phase. Only the O(window)
    # / O(1) per-row families (ring / recurrent / rwkv6) ride a tiny
    # batch-1 aux cache, installed per-branch at completion (they cannot
    # be shared copy-on-write anyway). Pages are acquired lazily chunk by
    # chunk; the heap running dry preempts the youngest-started request,
    # possibly this prefill itself.

    def _prefill_seq(self, item: _Queued) -> int:
        # the one-shot fallback's install scatter reshapes the transient
        # cache into whole pages
        s = super()._prefill_seq(item)
        return -(-s // self.page_size) * self.page_size

    def _begin_prefill(self, item, slots) -> _Prefill:
        aux = init_cache(self.cfg, 1, max(self._ring_window(), 1))
        self.admit_peak_bytes = max(self.admit_peak_bytes,
                                    cache_lib.cache_bytes(aux))
        pf = _Prefill(item=item, slots=slots, aux=aux)
        if self.pcache is not None:
            # alias every cached prefix page into slot[0]'s table and
            # start the chunked prefill at the first uncached token.
            # Cap: the LAST prompt token always re-prefills — sampling
            # needs the final position's logits, which only a live
            # prefill chunk produces — so a "full hit" still runs one
            # short tail chunk (and, page-aligned, rewrites the final
            # page; its fresh copy doubles as the COW write target)
            plen = len(item.prompt)
            pages = self.pcache.lookup(item.prompt)
            pages = pages[:(plen - 1) // self.page_size]
            if pages:
                self.alloc.set_row_pages(slots[0], pages)
                pf.filled = len(pages) * self.page_size
                self._bt_dev = None
                self.counters["prefix_hits"] += 1
                self.counters["prefix_tokens_saved"] += pf.filled
            else:
                self.counters["prefix_misses"] += 1
        return pf

    # compile-count bound for long prompts: the chunk's block-table
    # prefix width is bucketed to a page multiple, so a P-page prompt
    # compiles ~P/_BT_BUCKET chunk shapes instead of one per chunk.
    # Padding entries alias the trash page; their view positions trail
    # every chunk query, so the bitwise-equality argument is unchanged.
    _BT_BUCKET = 8

    def _grow_for_chunk(self, pf: _Prefill) -> Optional[int]:
        """Acquire the pages covering the next chunk (preempting the
        youngest-started request when the heap is dry). Returns the
        chunk length, or None if ``pf`` itself had to be evicted."""
        item, s0 = pf.item, pf.slots[0]
        c = min(self.prefill_chunk, len(item.prompt) - pf.filled)
        need = self.alloc.pages_for(pf.filled + c)
        while int(self.alloc.owned[s0]) < need:
            if self._reclaim(1):
                if int(self.alloc.owned[s0]) == 0:
                    self.alloc.set_row_pages(s0, self.alloc.alloc_pages(1))
                else:
                    self.alloc.append_page(s0)
                self._bt_dev = None
                continue
            victim = self._youngest_started()
            self._preempt(victim)
            if victim == item.rid:
                return None          # self-evicted; replay from the queue
        return c

    def _chunk_args(self, pf: _Prefill, c: int):
        """Device operands for one chunk: tokens, per-row pos0, the
        bucketed PREFIX of slot[0]'s block table (attention cost scales
        with the filled prompt, not max_seq), and the physical page of
        every chunk token."""
        item, s0 = pf.item, pf.slots[0]
        piece = item.prompt[pf.filled:pf.filled + c]
        qpos = np.arange(pf.filled, pf.filled + c)
        cpages = self.alloc.block[s0][qpos // self.page_size]
        need = self.alloc.pages_for(pf.filled + c)
        width = min(self.max_pages,
                    -(-need // self._BT_BUCKET) * self._BT_BUCKET)
        return (jnp.asarray(piece)[None],
                jnp.full((1,), pf.filled, jnp.int32),
                jnp.asarray(self.alloc.block[s0:s0 + 1, :width]),
                jnp.asarray(cpages.astype(np.int32))[None])

    def _prefill_step(self, pf: _Prefill):
        """Standalone chunk dispatch — used when no decode tick runs
        this tick (empty pool) or for PREFILLING requests beyond the
        fused candidate."""
        c = self._grow_for_chunk(pf)
        if c is None:
            return None
        toks, pos0, bt, cpages = self._chunk_args(pf, c)
        logits, self.pool, pf.aux = engine._prefill_chunk_paged(
            self.params, self.cfg, toks, pos0, 0, self.pool, bt, cpages,
            pf.aux)
        pf.filled += c
        return logits[0] if pf.filled >= len(pf.item.prompt) else None

    def _finish_prefill(self, pf: _Prefill) -> bool:
        """Share the fully-written prompt pages across the fan-out:
        slot[0] keeps its table (it wrote the pages), siblings alias the
        full prompt pages read-only and get a private device copy of the
        mid-page boundary (their COW write target); the per-row aux
        state broadcasts into every branch row. Decode pages then grow
        lazily exactly as for one-shot admissions."""
        item, s0 = pf.item, pf.slots[0]
        n = item.fan_out
        pos0 = self._prompt_pos(item)
        full = pos0 // self.page_size
        boundary = 1 if (n > 1 and pos0 % self.page_size) else 0
        if n > 1:
            need = boundary * (n - 1)
            while not self._reclaim(need):
                victim = self._youngest_started()
                self._preempt(victim)
                if victim == item.rid:
                    return False
            shared = [int(p) for p in self.alloc.block[s0, :full]]
            copies: List[int] = []
            if boundary:
                b_src = int(self.alloc.block[s0, full])
                copies = self.alloc.alloc_pages(need)
                self.pool = _copy_pages(
                    self.cfg, self.pool,
                    jnp.asarray(np.full((need,), b_src, np.int32)),
                    jnp.asarray(np.asarray(copies, np.int32)))
            for i, s in enumerate(pf.slots[1:]):
                self.alloc.set_row_pages(
                    s, shared + ([copies[i]] if boundary else []))
        self.pool = _install_aux(self.cfg, self.pool,
                                 jnp.asarray(pf.slots), pf.aux)
        pf.aux = None
        self._bt_dev = None
        return True

    def _fuse_candidates(self) -> List[int]:
        # EVERY prefilling request rides the decode dispatch: one tick =
        # one fused device program = decode + all concurrent prompt
        # chunks (PR 5 fused only the oldest; with prefix-cache hits
        # shortening prefills, several short tails per tick are the
        # common case, and each younger one used to dispatch standalone)
        if not self.active or not self.prefilling:
            return []
        return sorted(self.prefilling, key=lambda r: self._admit_seq[r])

    def _account_pages_tick(self) -> None:
        self._page_ticks += self.alloc.used_count
        self._page_peak = max(self._page_peak, self.alloc.used_count)

    def _decode_tick(self):
        # step-fault injection point: BEFORE chunk growth and
        # _ensure_pages, so a fault aborts the tick with the allocator
        # and pool untouched (retry is then trivially sound — the
        # donated device buffers were never consumed either)
        engine.check_step_fault(self.faults, self.ticks)
        # grow every fused chunk's pages FIRST — growth can evict or
        # preempt, which must settle before write pages are certified
        # below (growth runs in admission order, matching the standalone
        # dispatch order a non-fusing backend would use)
        fused = []                           # (rid, pf, chunk_len)
        for rid in self._fused_rids:
            pf = self.prefilling.get(rid)
            if pf is None:
                continue                     # preempted by an older grower
            c = self._grow_for_chunk(pf)
            if c is not None:
                fused.append((rid, pf, c))
        self._ensure_pages()
        # a younger fused chunk may have been preempted by a LATER
        # grower or by active-row growth — keep only survivors
        fused = [f for f in fused if f[0] in self.prefilling]
        self._fused_rids = [f[0] for f in fused]
        # COW guard: every active row's write page must be refcount-1
        # (allocator truth); the certified pages are pinned into the
        # decode step so a write physically cannot land on a shared page
        wp = np.full((self.rows,), self.alloc.trash, np.int32)
        occ = np.array([s for _, slots in self.active.values()
                        for s in slots], np.int64)
        if occ.size:
            wp[occ] = self.alloc.write_page(occ, self.row_pos[occ])
        self._account_pages_tick()
        if self._bt_dev is None:
            self._bt_dev = jnp.asarray(self.alloc.block)
        if fused:
            self.counters["fused_chunks"] += len(fused)
            chunks, auxs_in = [], []
            for rid, pf, c in fused:
                chunks.append(self._chunk_args(pf, c))
                auxs_in.append(pf.aux)
            logits, clogits, self.pool, auxs = engine._fused_decode_chunks(
                self.params, self.cfg, jnp.asarray(self.row_token),
                jnp.asarray(self.row_pos), self.pool, self._bt_dev,
                jnp.asarray(wp), tuple(chunks), tuple(auxs_in))
            out = {}
            for (rid, pf, c), cl, aux in zip(fused, clogits, auxs):
                pf.filled += c
                pf.aux = aux
                out[rid] = cl
            self._fused_chunk_out = out
            return logits
        logits, self.pool = _paged_step(
            self.params, self.cfg, jnp.asarray(self.row_token),
            jnp.asarray(self.row_pos), self.pool, self._bt_dev,
            jnp.asarray(wp))
        return logits

    def _post_tick_prefill(self) -> None:
        rids, self._fused_rids = self._fused_rids, []
        out, self._fused_chunk_out = self._fused_chunk_out, None
        if not rids or out is None:
            return
        for rid in rids:
            pf = self.prefilling.get(rid)
            # absent = preempted by an older sibling's finalize below
            if pf is None or pf.filled < len(pf.item.prompt):
                continue
            if self._finish_prefill(pf):
                del self.prefilling[rid]
                # rows join the NEXT decode tick (the chunk's logits
                # only materialized with this tick's compute)
                self._start_request(pf.item, pf.slots, out[rid][0])

    # ------------------------------------------- prefix-cache publication

    def _winner_extent(self, rs) -> Optional[int]:
        """Index into ``rs.branch_ids``/slots of the branch whose
        fed-token sequence is exactly reconstructible from the token log
        (prompt ++ logged tokens ++ forced-EOS tail), or None → publish
        the prompt extent only. Reconstruction fails when the chosen
        branch's rows were already released (BoN's eager EOS freeing) or
        when kappa chose a pruned-but-uncompacted branch (its post-prune
        fed tokens were sampled, not EOS, and never logged)."""
        chosen = rs.strategy.choose(rs.branch_ids, rs.done)
        where = np.nonzero(rs.branch_ids == chosen)[0]
        if where.size == 0:
            return None
        idx = int(where[0])
        if isinstance(rs.strategy, strategies.KappaStrategy):
            alive, _ = rs.strategy._alive_traj()
            if not bool(alive[idx]):
                return None
        return idx

    def publish_generated_prefix(self, item: _Queued, rs, slots) -> None:
        """Completion-side publication (the Path-Consistency scenario):
        pin the winner's full fully-written extent — prompt AND
        surviving generated prefix — into the radix tree, so a later
        sampling of the same problem that extends this prefix aliases
        the winner's pages instead of re-prefilling them. The fed
        sequence is prompt ++ log[:-1] (the last logged token was
        sampled but never fed) padded with the forced-EOS feeds of
        post-done ticks; when that reconstruction isn't certain
        (:meth:`_winner_extent`) only the prompt pages are published."""
        if self.pcache is None or item is None or not slots:
            return
        prompt = item.prompt    # already a host ndarray (submit())
        idx = self._winner_extent(rs)
        if idx is None:
            self._publish_prompt_pages(prompt, slots[0], len(prompt))
            return
        chosen = int(rs.branch_ids[idx])
        L = int(rs.log.len[chosen])
        fed = rs.log.buf[chosen, :max(L - 1, 0)]
        gap = int(rs.pos) - len(prompt) - len(fed)
        seq = np.concatenate(
            [prompt, fed,
             np.full((max(gap, 0),), self.eos_id)])[:int(rs.pos)]
        k = len(seq) // self.page_size
        if k:
            pages = [int(p) for p in self.alloc.block[slots[idx], :k]]
            self.pcache.publish(seq[:k * self.page_size], pages)

    def _publish_prefix(self, item, rs, slots) -> None:
        self.publish_generated_prefix(item, rs, slots)

    # ----------------------------------------------------------- metrics

    def request_bytes(self) -> Dict[int, int]:
        """Per-request bytes from allocator truth: pages the request's
        rows reference — shared prompt pages charged ONCE — times the
        per-page byte cost, plus the analytic per-row cost of the
        non-paged leaf families (ring / recurrent / rwkv6 / cross-KV)."""
        pb = cache_lib.page_bytes(self.cfg, self.page_size)
        out = {}
        for rid, (rs, slots) in self.active.items():
            pages = {int(p) for s in slots for p in self.alloc.row_pages(s)}
            out[rid] = len(pages) * pb + cache_lib.used_cache_bytes(
                self.cfg, len(slots), rs.pos, self.max_seq, skip_global=True)
        return out

    def throughput(self) -> Dict[str, float]:
        out = super().throughput()
        out["page_utilization"] = (self._page_ticks
                                   / max(self.ticks * self.num_pages, 1))
        out["page_peak"] = self._page_peak
        # prefix-cache observability (zeros when the cache is off): the
        # prefix_hits/misses/tokens_saved/evictions counters ride along
        # via the shared counters dict above
        looked = (self.counters["prefix_hits"]
                  + self.counters["prefix_misses"])
        out["prefix_hit_rate"] = self.counters["prefix_hits"] / max(looked, 1)
        out["prefix_pinned_pages"] = (self.pcache.pinned_count
                                      if self.pcache is not None else 0)
        return out
