"""Continuous-batching multi-request scheduler (DESIGN.md §4).

The sequential engine serves one prompt at a time: N branch rows, pruned
to 1 by KAPPA/ST-BoN, then a long single-row tail to EOS — poor device
utilization exactly when pruning succeeds. This scheduler turns freed
rows into throughput, the serving-level payoff the early-pruning papers
point at (ST-BoN, Wang et al. 2025; Bi et al. 2025):

  * a fixed ``(rows, max_seq)`` device cache pool allocated once — one
    compiled decode shape, no per-request recompilation;
  * a FIFO request queue; a request is admitted when its branch fan-out
    fits in the free slots (prefill at batch 1, broadcast to N rows,
    scattered into the slots);
  * one fused decode step per tick over the *whole* pool with per-row
    positions (rows of different requests sit at different offsets);
  * per-request strategies (repro.serving.strategies) drive sampling,
    controller updates and pruning on their own row groups; compaction
    frees slots which are immediately backfilled by queued prefills;
  * per-request ``GenResult``s emitted on completion with the same
    accounting as sequential serving.

Equivalence guarantee: the batched decode step is row-independent, the
host-side per-request logic is shared verbatim with the engine loop, and
each request consumes its own RNG stream — so with the same per-request
keys and the same ``max_seq`` the scheduler reproduces the sequential
engine token for token (tests/test_scheduler.py).
"""
from __future__ import annotations

import time
from collections import deque
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import KappaConfig, ModelConfig
from repro.models import init_cache
from repro.serving import cache as cache_lib
from repro.serving import engine
from repro.serving import strategies
from repro.serving.strategies import GenResult

_scatter = jax.jit(cache_lib.scatter_batch, donate_argnums=(0,))


class ContinuousBatchingScheduler:
    """Admit prompts into a fixed row pool and decode them concurrently.

    Parameters
    ----------
    rows : total branch slots in the device pool. Must be >= the fan-out
        of a single request (``strategy.rows(kcfg)``).
    max_seq : shared sequence capacity of every pool row. Each admitted
        prompt must satisfy ``len(prompt) + n_prefix + max_new <= max_seq``.
    method : one of "greedy" | "bon" | "stbon" | "kappa"; or pass
        ``strategy_factory`` for custom construction (e.g. ST-BoN with a
        non-default buffer window).
    """

    def __init__(self, params, cfg: ModelConfig, kcfg: KappaConfig, *,
                 rows: int, max_seq: int, method: str = "kappa",
                 eos_id: int, bos_id: int = 0, frontend=None,
                 strategy_factory: Optional[Callable[[], strategies.DecodeStrategy]] = None):
        self.params = params
        self.cfg = cfg
        self.kcfg = kcfg
        self.rows = rows
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.bos_id = bos_id
        self.frontend = frontend
        self.strategy_factory = strategy_factory or (
            lambda: strategies.make_strategy(method))
        self.n_prefix = engine._n_prefix(cfg)

        need = self.strategy_factory().rows(kcfg)
        if rows < need:
            raise ValueError(f"pool rows={rows} < request fan-out {need}")
        if cfg.is_moe and cfg.moe_capacity_factor > 0:
            # capacity-limited MoE routing drops tokens *per batch*, so
            # pool rows are not independent: one request's rows (and the
            # free rows' garbage tokens) would contend for expert capacity
            # with another's, breaking the equivalence guarantee. Dropless
            # routing (capacity_factor <= 0) is exact and row-independent.
            raise ValueError(
                "continuous batching requires dropless MoE routing "
                "(cfg.moe_capacity_factor <= 0): capacity-limited dispatch "
                "couples pool rows across requests")

        self.pool = init_cache(cfg, rows, max_seq)
        self.row_token = np.zeros((rows,), np.int32)
        self.row_pos = np.zeros((rows,), np.int32)
        self.free: List[int] = list(range(rows))
        self.queue: deque = deque()          # (rid, prompt, rng)
        self.active: Dict[int, tuple] = {}   # rid -> (RequestState, slots)
        self.results: Dict[int, GenResult] = {}
        self._next_rid = 0
        self.ticks = 0
        self._occupied_ticks = 0             # Σ occupied rows over ticks

    # ------------------------------------------------------------ submit

    def submit(self, prompt: np.ndarray, rng) -> int:
        """Queue one prompt with its own RNG stream; returns request id."""
        need = len(prompt) + self.n_prefix + self.kcfg.max_new_tokens
        if need > self.max_seq:
            raise ValueError(
                f"prompt needs {need} positions > pool max_seq={self.max_seq}")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append((rid, np.asarray(prompt), rng))
        return rid

    # --------------------------------------------------------- admission

    def _try_admit(self) -> bool:
        """Admit the queue head if its fan-out fits the free slots
        (FIFO — no head-of-line bypass, keeping completion order fair)."""
        if not self.queue:
            return False
        rid, prompt, rng = self.queue[0]
        strategy = self.strategy_factory()
        n = strategy.rows(self.kcfg)
        if len(self.free) < n:
            return False
        self.queue.popleft()
        slots = sorted(self.free[:n])
        del self.free[:n]

        pf_logits, cache1 = engine._prefill_one(
            self.params, self.cfg, prompt, self.max_seq, self.frontend)
        rs = strategies.RequestState(
            strategy, self.params, self.cfg, self.kcfg, len(prompt), rng,
            eos_id=self.eos_id, bos_id=self.bos_id, max_seq=self.max_seq,
            n_prefix=self.n_prefix, frontend=self.frontend)
        sub = cache_lib.broadcast_batch(cache1, n) if n > 1 else cache1
        self.pool = _scatter(self.pool, jnp.asarray(slots), sub)
        rs.first_tokens(pf_logits)
        if rs.finished:  # e.g. greedy whose first token is already EOS
            self.results[rid] = rs.result()
            self._release(slots)
        else:
            self.active[rid] = (rs, slots)
            self.row_token[slots] = rs.cur
            self.row_pos[slots] = rs.pos
        return True

    def _release(self, slots: List[int]) -> None:
        self.row_token[slots] = 0
        self.row_pos[slots] = 0
        self.free.extend(slots)
        self.free.sort()

    # -------------------------------------------------------------- tick

    def tick(self) -> None:
        """Admit what fits, then run one fused decode step over the pool
        and advance every active request on its own rows."""
        while self._try_admit():
            pass
        if not self.active:
            return
        self._occupied_ticks += self.rows - len(self.free)

        logits, self.pool = engine._model_step(
            self.params, self.cfg, jnp.asarray(self.row_token),
            jnp.asarray(self.row_pos), self.pool)

        for rid in list(self.active):
            rs, slots = self.active[rid]
            dec = rs.advance(logits[jnp.asarray(slots)])
            if dec.keep is not None:
                kept = [slots[i] for i in dec.keep]
                self._release(sorted(set(slots) - set(kept)))
                slots = kept
                self.active[rid] = (rs, slots)
            self.row_token[slots] = rs.cur
            self.row_pos[slots] = rs.pos
            if rs.finished:
                self.results[rid] = rs.result()
                del self.active[rid]
                self._release(slots)
        self.ticks += 1

    # --------------------------------------------------------------- run

    def run(self) -> Dict[int, GenResult]:
        """Drive queue + pool to completion; returns rid -> GenResult."""
        t0 = time.time()
        while self.queue or self.active:
            before = (len(self.queue), len(self.active))
            self.tick()
            if not self.active and self.queue and \
                    (len(self.queue), len(self.active)) == before:
                raise RuntimeError(
                    "scheduler stalled: queued request cannot be admitted "
                    f"(free={len(self.free)} rows)")
        self.elapsed = time.time() - t0
        return dict(sorted(self.results.items()))

    # ----------------------------------------------------------- metrics

    def request_bytes(self) -> Dict[int, int]:
        """Per-request paged-view bytes currently referenced in the pool."""
        return cache_lib.per_request_bytes(
            self.cfg, {rid: (len(slots), rs.pos)
                       for rid, (rs, slots) in self.active.items()},
            self.max_seq)

    def throughput(self) -> Dict[str, float]:
        """Aggregate serving metrics over a completed ``run()``."""
        total_logical = sum(r.logical_tokens for r in self.results.values())
        total_compute = sum(r.compute_tokens for r in self.results.values())
        elapsed = max(getattr(self, "elapsed", 0.0), 1e-9)
        return {
            "requests": len(self.results),
            "ticks": self.ticks,
            "time_s": elapsed,
            "logical_tokens": total_logical,
            "compute_tokens": total_compute,
            "tokens_per_s": total_logical / elapsed,
            "requests_per_s": len(self.results) / elapsed,
            "row_utilization": (self._occupied_ticks
                                / max(self.ticks * self.rows, 1)),
        }
