"""Serving engine: batched branch decoding for Greedy / BoN / ST-BoN /
KAPPA with bucketed cache compaction.

One shared decode loop (``_decode_loop``) drives any
``repro.serving.strategies.DecodeStrategy``: a host-side Python loop over
a **jitted step** (the same architecture as production serving stacks:
device step + host scheduler). Branch lifecycle:

  prefill(prompt, B=1) ─ broadcast cache to N ─▶ step* ─▶ compaction at
  power-of-two buckets as the strategy prunes ─▶ survivor decodes to EOS

The four public ``generate_*`` functions are thin wrappers binding a
strategy to the loop. Multi-request continuous batching lives in
``repro.serving.scheduler`` and reuses the same strategies and jitted
steps, so both execution modes are token-for-token equivalent.

Two token accountings are kept (see DESIGN.md §2):
  * logical — tokens sampled on live branches (the paper's accounting;
    an eager-freeing implementation generates exactly these)
  * compute — tokens actually processed on TPU rows (bucketed shapes
    decode dead rows until the next compaction)

``serve_step`` at the bottom is the dry-run lowering target for the
decode input shapes: one model step + fused KAPPA scoring.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import KappaConfig, ModelConfig
from repro.core import kappa as kappa_lib
from repro.models import decode_step, init_cache, prefill, prefill_chunk
from repro.serving import cache as cache_lib
from repro.serving import faults as faults_lib
from repro.serving import sampler
from repro.serving import strategies
from repro.serving.strategies import GenResult  # noqa: F401  (public API)


def check_step_fault(plan, tick: int) -> None:
    """Raise :class:`repro.serving.faults.InjectedStepFault` if ``plan``
    schedules a device-step failure for ``tick``. Called at the very top
    of the fused decode dispatch, before any pool mutation — the donated
    buffers are never consumed, so a retry replays on intact state."""
    if plan is not None and plan.step_fault(tick):
        raise faults_lib.InjectedStepFault(
            f"injected device-step fault at tick {tick}")


@jax.jit
def rows_finite(logits):
    """(rows,) bool — which pool rows produced all-finite logits. Fused
    into the tick's existing blocking transfer so NaN detection costs no
    extra device sync."""
    return jnp.all(jnp.isfinite(logits), axis=-1)


# ------------------------------------------------------------ shared bits

_prefill_jit = jax.jit(prefill, static_argnums=(1,))

# chunked-prefill steps (DESIGN.md §6). hist_len is static: each chunk
# index is its own specialization, bounded by ceil(max_seq / chunk) and
# shared across requests of equal chunking — the same trade prefill
# already makes by being keyed on prompt length. The paged variant
# donates the pool AND the batch-1 aux state so chunk k+1 reuses chunk
# k's buffers.
_prefill_chunk_contig = jax.jit(prefill_chunk, static_argnums=(1, 4),
                                donate_argnums=(5,))
_prefill_chunk_paged = jax.jit(prefill_chunk, static_argnums=(1, 4),
                               donate_argnums=(5, 8))


def fused_decode_chunks(params, cfg: ModelConfig, token, pos, cache,
                        block_tables, write_pages, chunks, auxs):
    """ONE device program advancing the whole decode pool AND every
    PREFILLING request's next prompt chunk (DESIGN.md §6): the chunks
    ride the tick's existing dispatch, so interleaved admission adds
    chunk *compute* to a tick but no second host dispatch — with prefix
    -cache hits shortening prefills, several short tails per tick are
    the common case, and each used to dispatch standalone. ``chunks`` is
    a tuple of per-request ``(tokens, pos0, block_table, pages)``
    operands; ``auxs`` the matching batch-1 aux states (donated — chunk
    k+1's tick reuses chunk k's buffers). All parts touch disjoint pool
    state — decode writes its rows' allocator-certified pages, each
    chunk writes its own refcount-1 prompt pages and its own aux."""
    logits, cache = decode_step(params, cfg, token, pos, cache,
                                block_tables, write_pages)
    outs, auxs_out = [], []
    for (chunk_tokens, chunk_pos0, chunk_bt, chunk_pages), aux \
            in zip(chunks, auxs):
        clogits, cache, aux = prefill_chunk(params, cfg, chunk_tokens,
                                            chunk_pos0, 0, cache, chunk_bt,
                                            chunk_pages, aux)
        outs.append(clogits)
        auxs_out.append(aux)
    return logits, tuple(outs), cache, tuple(auxs_out)


_fused_decode_chunks = jax.jit(fused_decode_chunks, static_argnums=(1,),
                               donate_argnums=(4, 8))


def _prefill_one(params, cfg: ModelConfig, prompt: np.ndarray, max_seq: int,
                 frontend=None):
    cache = init_cache(cfg, 1, max_seq)
    logits, cache = _prefill_jit(params, cfg, jnp.asarray(prompt)[None],
                                 cache, frontend)
    return logits[0], cache


def chunkable(cfg: ModelConfig, frontend=None) -> bool:
    """Whether chunked prefill applies: no encoder (the whisper decoder
    prefill needs the whole encoder pass anyway) and no frontend prefix
    tokens (patch embeddings are not chunkable token streams)."""
    return frontend is None and not cfg.frontend and not cfg.is_encoder_decoder


def prefill_chunked(params, cfg: ModelConfig, prompt: np.ndarray,
                    max_seq: int, chunk: int):
    """One-request chunked prefill of a batch-1 contiguous cache: the
    engine-loop twin of the scheduler's PREFILLING state. Returns
    (last-position logits (V,), cache) — bitwise equal to
    :func:`_prefill_one`'s on every layer pattern: sliding-window ring
    histories are re-gathered into ascending logical order before
    attention, so the chunk arrangement cannot perturb reduction order
    (DESIGN.md §6)."""
    if chunk < 1:
        raise ValueError(f"prefill chunk must be >= 1, got {chunk}")
    if not chunkable(cfg):
        raise ValueError("model is not chunkable (frontend / enc-dec)")
    cache = init_cache(cfg, 1, max_seq)
    logits = None
    for s in range(0, len(prompt), chunk):
        piece = prompt[s:s + chunk]
        logits, cache, _ = _prefill_chunk_contig(
            params, cfg, jnp.asarray(piece)[None],
            jnp.full((1,), s, jnp.int32), s, cache)
    return logits[0], cache


@functools.partial(jax.jit, static_argnums=(1,), donate_argnums=(4,))
def _model_step(params, cfg: ModelConfig, token, pos, cache):
    # cache donation: the updated cache aliases the input buffer (measured
    # −37 % peak memory per chip on granite-3-8b decode — §Perf B it.3)
    return decode_step(params, cfg, token, pos, cache)


def _n_prefix(cfg: ModelConfig) -> int:
    return cfg.frontend_tokens if (cfg.frontend and not cfg.is_encoder_decoder) else 0


# ------------------------------------------------------------ shared loop

def _decode_loop(params, cfg: ModelConfig, kcfg: KappaConfig,
                 prompt: np.ndarray, rng,
                 strategy: strategies.DecodeStrategy, *, eos_id: int,
                 bos_id: int = 0, max_seq: Optional[int] = None,
                 frontend=None,
                 prefill_chunk: Optional[int] = None) -> GenResult:
    """Drive one request to completion with a dedicated branch cache.
    ``prefill_chunk`` switches the prompt phase to the chunked path the
    scheduler uses — the loop-parity knob for DESIGN.md §6."""
    n_prefix = _n_prefix(cfg)
    max_seq = max_seq or (len(prompt) + kcfg.max_new_tokens + n_prefix)

    if prefill_chunk is not None and chunkable(cfg, frontend):
        pf_logits, cache = prefill_chunked(params, cfg, prompt, max_seq,
                                           prefill_chunk)
    else:
        pf_logits, cache = _prefill_one(params, cfg, prompt, max_seq,
                                        frontend)
    rs = strategies.RequestState(
        strategy, params, cfg, kcfg, len(prompt), rng, eos_id=eos_id,
        bos_id=bos_id, max_seq=max_seq, n_prefix=n_prefix, frontend=frontend)
    if rs.n > 1:
        cache = cache_lib.broadcast_batch(cache, rs.n)
    rs.first_tokens(pf_logits)

    while not rs.finished:
        logits, cache = _model_step(params, cfg, jnp.asarray(rs.cur),
                                    jnp.int32(rs.pos), cache)
        dec = rs.sample_and_advance(logits)
        if dec.keep is not None:
            cache = cache_lib.gather_batch(cache, jnp.asarray(dec.keep))
    return rs.result()


# --------------------------------------------------------- public methods

def generate_kappa(params, cfg: ModelConfig, kcfg: KappaConfig,
                   prompt: np.ndarray, rng, *, eos_id: int, bos_id: int = 0,
                   max_seq: Optional[int] = None, frontend=None) -> GenResult:
    return _decode_loop(params, cfg, kcfg, prompt, rng,
                        strategies.KappaStrategy(), eos_id=eos_id,
                        bos_id=bos_id, max_seq=max_seq, frontend=frontend)


def generate_greedy(params, cfg: ModelConfig, kcfg: KappaConfig,
                    prompt: np.ndarray, rng, *, eos_id: int, bos_id: int = 0,
                    max_seq: Optional[int] = None, frontend=None) -> GenResult:
    return _decode_loop(params, cfg, kcfg, prompt, rng,
                        strategies.GreedyStrategy(), eos_id=eos_id,
                        bos_id=bos_id, max_seq=max_seq, frontend=frontend)


def generate_bon(params, cfg: ModelConfig, kcfg: KappaConfig,
                 prompt: np.ndarray, rng, *, eos_id: int, bos_id: int = 0,
                 max_seq: Optional[int] = None, frontend=None) -> GenResult:
    return _decode_loop(params, cfg, kcfg, prompt, rng,
                        strategies.BoNStrategy(), eos_id=eos_id,
                        bos_id=bos_id, max_seq=max_seq, frontend=frontend)


def generate_stbon(params, cfg: ModelConfig, kcfg: KappaConfig,
                   prompt: np.ndarray, rng, *, eos_id: int, bos_id: int = 0,
                   buffer_window: int = 16, max_seq: Optional[int] = None,
                   frontend=None) -> GenResult:
    return _decode_loop(params, cfg, kcfg, prompt, rng,
                        strategies.STBoNStrategy(buffer_window=buffer_window),
                        eos_id=eos_id, bos_id=bos_id, max_seq=max_seq,
                        frontend=frontend)


# ------------------------------------------------------- dry-run target

def serve_step(params, cfg: ModelConfig, kcfg: KappaConfig,
               token, pos, cache, state: kappa_lib.KappaState, log_q, rng):
    """One fused serving step — the decode-shape dry-run lowering target:
    model decode + sampling + KAPPA scoring/gating. The controller
    consumes the tokens sampled THIS step (its contract), so sampling
    chains into scoring device-side."""
    logits, cache = decode_step(params, cfg, token, pos, cache)
    nxt = sampler.sample(rng, logits, temperature=kcfg.temperature,
                         top_k=kcfg.top_k, top_p=kcfg.top_p)
    state = kappa_lib.kappa_step(state, logits, nxt, log_q, kcfg)
    return nxt, cache, state
