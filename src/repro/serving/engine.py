"""Serving engine: batched branch decoding for Greedy / BoN / ST-BoN /
KAPPA with bucketed cache compaction.

The decode loop is a host-side Python loop over a **jitted step** (the
same architecture as production serving stacks: device step + host
scheduler). Branch lifecycle:

  prefill(prompt, B=1) ─ broadcast cache to N ─▶ step* ─▶ compaction at
  power-of-two buckets as KAPPA prunes ─▶ survivor decodes to EOS

Two token accountings are kept (see DESIGN.md §2):
  * logical — tokens sampled on live branches (the paper's accounting;
    an eager-freeing implementation generates exactly these)
  * compute — tokens actually processed on TPU rows (bucketed shapes
    decode dead rows until the next compaction)

``serve_step`` at the bottom is the dry-run lowering target for the
decode input shapes: one model step + fused KAPPA scoring.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import KappaConfig, ModelConfig
from repro.core import kappa as kappa_lib
from repro.core.signals import reference_log_q
from repro.models import decode_step, init_cache, prefill, train_logits
from repro.serving import cache as cache_lib
from repro.serving import sampler


@dataclass
class GenResult:
    tokens: List[int]                 # generated tokens of the chosen branch
    chosen_branch: int                # original branch index
    all_tokens: np.ndarray            # (N, T) all branch tokens (-1 pad)
    lengths: np.ndarray               # (N,) live lengths
    logical_tokens: int               # paper-style token count
    compute_tokens: int               # TPU rows actually decoded
    peak_cache_bytes: int             # branch-scaling memory peak
    steps: int
    compactions: List[int] = field(default_factory=list)
    extra: Dict = field(default_factory=dict)


# ------------------------------------------------------------ shared bits

@functools.partial(jax.jit, static_argnums=(1,))
def _bos_log_q(params, cfg: ModelConfig, bos_token, frontend=None):
    """Unconditional reference logits q from the BOS-only context
    (Alg. 2 line 9)."""
    logits, _ = train_logits(params, cfg, bos_token[None, None], frontend)
    return reference_log_q(logits[0, -1])


def _prefill_one(params, cfg: ModelConfig, prompt: np.ndarray, max_seq: int,
                 frontend=None):
    cache = init_cache(cfg, 1, max_seq)
    fn = jax.jit(prefill, static_argnums=(1,))
    logits, cache = fn(params, cfg, jnp.asarray(prompt)[None], cache, frontend)
    return logits[0], cache


@functools.partial(jax.jit, static_argnums=(1,), donate_argnums=(4,))
def _model_step(params, cfg: ModelConfig, token, pos, cache):
    # cache donation: the updated cache aliases the input buffer (measured
    # −37 % peak memory per chip on granite-3-8b decode — §Perf B it.3)
    return decode_step(params, cfg, token, pos, cache)


def _sample_step(rng, logits, kcfg: KappaConfig, greedy: bool = False):
    if greedy:
        return sampler.greedy(logits)
    return sampler.sample(rng, logits, temperature=kcfg.temperature,
                          top_k=kcfg.top_k, top_p=kcfg.top_p)


class _TokenLog:
    """Host-side per-branch token buffers surviving compaction."""

    def __init__(self, n: int, max_new: int):
        self.buf = np.full((n, max_new), -1, np.int32)
        self.len = np.zeros((n,), np.int64)

    def append(self, branch_ids: np.ndarray, tokens: np.ndarray,
               active: np.ndarray):
        for row, b in enumerate(branch_ids):
            if active[row]:
                self.buf[b, self.len[b]] = tokens[row]
                self.len[b] += 1


# ------------------------------------------------------------------ KAPPA

def generate_kappa(params, cfg: ModelConfig, kcfg: KappaConfig,
                   prompt: np.ndarray, rng, *, eos_id: int, bos_id: int = 0,
                   max_seq: Optional[int] = None, frontend=None) -> GenResult:
    n = kcfg.num_branches
    max_seq = max_seq or (len(prompt) + kcfg.max_new_tokens
                          + (cfg.frontend_tokens if cfg.frontend and not cfg.is_encoder_decoder else 0))
    n_prefix = cfg.frontend_tokens if (cfg.frontend and not cfg.is_encoder_decoder) else 0

    log_q = _bos_log_q(params, cfg, jnp.int32(bos_id),
                       frontend[:1] if frontend is not None else None)
    pf_logits, cache1 = _prefill_one(params, cfg, prompt, max_seq, frontend)
    cache = cache_lib.broadcast_batch(cache1, n)
    state = kappa_lib.init_state(kcfg)

    rng, k0 = jax.random.split(rng)
    cur = _sample_step(k0, jnp.broadcast_to(pf_logits, (n, pf_logits.shape[-1])), kcfg)

    log = _TokenLog(n, kcfg.max_new_tokens + 1)
    branch_ids = np.arange(n)
    done = np.zeros((n,), bool)
    alive_rows = n
    logical = compute = 0
    peak = cache_lib.used_cache_bytes(cfg, n, len(prompt) + n_prefix, max_seq)
    chain = cache_lib.bucket_chain(n)
    compactions: List[int] = []

    cur_np = np.asarray(cur)
    log.append(branch_ids, cur_np, ~done)
    logical += int(np.sum(~done))
    compute += alive_rows

    pos = len(prompt) + n_prefix
    step = 0
    controller_step = jax.jit(kappa_lib.kappa_step, static_argnums=(4,))

    while step < kcfg.max_new_tokens - 1:
        logits, cache = _model_step(params, cfg, jnp.asarray(cur), jnp.int32(pos), cache)
        state = controller_step(state, logits, jnp.asarray(cur), log_q, kcfg)

        rng, kk = jax.random.split(rng)
        nxt = _sample_step(kk, logits, kcfg)
        nxt_np = np.asarray(nxt)
        nxt_np = np.where(done[branch_ids], eos_id, nxt_np)
        done[branch_ids] |= (nxt_np == eos_id)

        alive_mask = np.asarray(state.alive)
        active = alive_mask & ~done[branch_ids]
        log.append(branch_ids, nxt_np, active)
        logical += int(np.sum(active))
        compute += len(branch_ids)

        pos += 1
        step += 1
        cur = jnp.asarray(nxt_np)

        # --- bucketed compaction
        n_alive = int(np.sum(alive_mask))
        if kcfg.compaction:
            bucket = cache_lib.next_bucket(chain, max(n_alive, 1), len(branch_ids))
            if bucket < len(branch_ids):
                traj = np.asarray(state.traj)
                order = np.argsort(~alive_mask * 1_000_000 - traj)  # alive best first
                keep = np.sort(order[:bucket])
                cache = cache_lib.gather_batch(cache, jnp.asarray(keep))
                state = kappa_lib.compact_state(state, jnp.asarray(keep))
                branch_ids = branch_ids[keep]
                cur = cur[jnp.asarray(keep)]
                compactions.append(bucket)
        peak = max(peak, cache_lib.used_cache_bytes(cfg, len(branch_ids), pos, max_seq))

        # --- termination: sole survivor finished, or everyone done
        alive_mask = np.asarray(state.alive)
        live_branches = branch_ids[alive_mask]
        if len(live_branches) == 1 and done[live_branches[0]]:
            break
        if np.all(done[branch_ids] | ~alive_mask):
            break

    traj = np.asarray(state.traj)
    alive_mask = np.asarray(state.alive)
    masked = np.where(alive_mask, traj, -np.inf)
    winner_row = int(np.argmax(masked))
    chosen = int(branch_ids[winner_row])
    toks = log.buf[chosen, :log.len[chosen]]
    toks = toks[toks != -1].tolist()
    return GenResult(
        tokens=toks, chosen_branch=chosen, all_tokens=log.buf,
        lengths=log.len.copy(), logical_tokens=logical,
        compute_tokens=compute, peak_cache_bytes=peak, steps=step,
        compactions=compactions,
        extra={"cutoff": int(np.asarray(state.cutoff)),
               "traj": traj.tolist()})


def done_rows(done: np.ndarray, branch_ids: np.ndarray) -> np.ndarray:
    return done[branch_ids]


# ------------------------------------------------------------------ greedy

def generate_greedy(params, cfg: ModelConfig, kcfg: KappaConfig,
                    prompt: np.ndarray, rng, *, eos_id: int, bos_id: int = 0,
                    max_seq: Optional[int] = None, frontend=None) -> GenResult:
    max_seq = max_seq or (len(prompt) + kcfg.max_new_tokens
                          + (cfg.frontend_tokens if cfg.frontend and not cfg.is_encoder_decoder else 0))
    n_prefix = cfg.frontend_tokens if (cfg.frontend and not cfg.is_encoder_decoder) else 0
    pf_logits, cache = _prefill_one(params, cfg, prompt, max_seq, frontend)
    cur = sampler.greedy(pf_logits[None])
    toks = [int(cur[0])]
    pos = len(prompt) + n_prefix
    peak = cache_lib.used_cache_bytes(cfg, 1, pos, max_seq)
    step = 0
    while toks[-1] != eos_id and step < kcfg.max_new_tokens - 1:
        logits, cache = _model_step(params, cfg, cur, jnp.int32(pos), cache)
        cur = sampler.greedy(logits)
        toks.append(int(cur[0]))
        pos += 1
        step += 1
        peak = max(peak, cache_lib.used_cache_bytes(cfg, 1, pos, max_seq))
    if toks and toks[-1] == eos_id:
        toks = toks[:-1] + [eos_id]
    buf = np.full((1, kcfg.max_new_tokens + 1), -1, np.int32)
    buf[0, :len(toks)] = toks
    return GenResult(tokens=toks, chosen_branch=0, all_tokens=buf,
                     lengths=np.array([len(toks)]), logical_tokens=len(toks),
                     compute_tokens=len(toks), peak_cache_bytes=peak,
                     steps=step)


# --------------------------------------------------------------------- BoN

def generate_bon(params, cfg: ModelConfig, kcfg: KappaConfig,
                 prompt: np.ndarray, rng, *, eos_id: int, bos_id: int = 0,
                 max_seq: Optional[int] = None, frontend=None) -> GenResult:
    """Full Best-of-N with negative-perplexity selection (Kang et al. 2025)."""
    n = kcfg.num_branches
    max_seq = max_seq or (len(prompt) + kcfg.max_new_tokens
                          + (cfg.frontend_tokens if cfg.frontend and not cfg.is_encoder_decoder else 0))
    n_prefix = cfg.frontend_tokens if (cfg.frontend and not cfg.is_encoder_decoder) else 0
    pf_logits, cache1 = _prefill_one(params, cfg, prompt, max_seq, frontend)
    cache = cache_lib.broadcast_batch(cache1, n)

    rng, k0 = jax.random.split(rng)
    logits = jnp.broadcast_to(pf_logits, (n, pf_logits.shape[-1]))
    cur = _sample_step(k0, logits, kcfg)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    sum_lp = np.asarray(jnp.take_along_axis(lp, cur[:, None], axis=-1)[:, 0], np.float64)
    count = np.ones((n,), np.int64)

    log = _TokenLog(n, kcfg.max_new_tokens + 1)
    branch_ids = np.arange(n)
    done = np.zeros((n,), bool)
    cur_np = np.asarray(cur)
    log.append(branch_ids, cur_np, ~done)
    logical = int(np.sum(~done))
    compute = n
    peak = cache_lib.used_cache_bytes(cfg, n, len(prompt) + n_prefix, max_seq)

    pos = len(prompt) + n_prefix
    step = 0
    while step < kcfg.max_new_tokens - 1 and not np.all(done):
        logits, cache = _model_step(params, cfg, jnp.asarray(cur_np), jnp.int32(pos), cache)
        rng, kk = jax.random.split(rng)
        nxt = _sample_step(kk, logits, kcfg)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        step_lp = np.asarray(jnp.take_along_axis(lp, nxt[:, None], axis=-1)[:, 0], np.float64)
        nxt_np = np.asarray(nxt)
        nxt_np = np.where(done, eos_id, nxt_np)
        newly = ~done
        sum_lp += np.where(newly, step_lp, 0.0)
        count += newly
        done |= (nxt_np == eos_id)
        log.append(branch_ids, nxt_np, newly)
        logical += int(np.sum(newly))
        compute += n
        cur_np = nxt_np
        pos += 1
        step += 1
        peak = max(peak, cache_lib.used_cache_bytes(cfg, n, pos, max_seq))

    neg_ppl = sum_lp / np.maximum(count, 1)  # mean log-prob = −log(perplexity)
    chosen = int(np.argmax(neg_ppl))
    toks = log.buf[chosen, :log.len[chosen]]
    toks = toks[toks != -1].tolist()
    return GenResult(tokens=toks, chosen_branch=chosen, all_tokens=log.buf,
                     lengths=log.len.copy(), logical_tokens=logical,
                     compute_tokens=compute, peak_cache_bytes=peak, steps=step,
                     extra={"neg_ppl": neg_ppl.tolist()})


# ------------------------------------------------------------------ ST-BoN

def generate_stbon(params, cfg: ModelConfig, kcfg: KappaConfig,
                   prompt: np.ndarray, rng, *, eos_id: int, bos_id: int = 0,
                   buffer_window: int = 16, max_seq: Optional[int] = None,
                   frontend=None) -> GenResult:
    """Self-Truncation BoN (Wang et al. 2025): decode until the earliest
    point of pairwise difference + a fixed buffer window, then keep the
    branch most consistent with the others and truncate the rest.

    Consistency here = mean pairwise cosine similarity of the branches'
    buffer-window-averaged next-token distributions (the paper uses
    latent-embedding consistency; distribution-space consistency is the
    closest signal our engine already materializes — noted in DESIGN.md).
    """
    n = kcfg.num_branches
    max_seq = max_seq or (len(prompt) + kcfg.max_new_tokens
                          + (cfg.frontend_tokens if cfg.frontend and not cfg.is_encoder_decoder else 0))
    n_prefix = cfg.frontend_tokens if (cfg.frontend and not cfg.is_encoder_decoder) else 0
    pf_logits, cache1 = _prefill_one(params, cfg, prompt, max_seq, frontend)
    cache = cache_lib.broadcast_batch(cache1, n)

    rng, k0 = jax.random.split(rng)
    cur = _sample_step(k0, jnp.broadcast_to(pf_logits, (n, pf_logits.shape[-1])), kcfg)
    cur_np = np.asarray(cur)

    log = _TokenLog(n, kcfg.max_new_tokens + 1)
    branch_ids = np.arange(n)
    done = np.zeros((n,), bool)
    log.append(branch_ids, cur_np, ~done)
    logical = int(np.sum(~done))
    compute = n
    peak = cache_lib.used_cache_bytes(cfg, n, len(prompt) + n_prefix, max_seq)

    diverged = np.eye(n, dtype=bool)
    cutoff_hit_step = None
    prob_acc = np.zeros((n, cfg.vocab_size), np.float64)
    prob_cnt = 0
    truncated = False
    chosen = 0
    compactions: List[int] = []

    pos = len(prompt) + n_prefix
    step = 0
    while step < kcfg.max_new_tokens - 1:
        logits, cache = _model_step(params, cfg, jnp.asarray(cur_np), jnp.int32(pos), cache)
        rng, kk = jax.random.split(rng)
        nxt = _sample_step(kk, logits, kcfg)
        nxt_np = np.asarray(nxt)
        nxt_np = np.where(done[branch_ids], eos_id, nxt_np)
        done[branch_ids] |= (nxt_np == eos_id)
        active = ~done[branch_ids] if truncated else ~done[branch_ids]
        log.append(branch_ids, nxt_np, active)
        logical += int(np.sum(active))
        compute += len(branch_ids)
        pos += 1
        step += 1
        cur_np = nxt_np

        if not truncated:
            diverged |= cur_np[:, None] != cur_np[None, :]
            if cutoff_hit_step is None and (np.all(diverged) or step >= kcfg.max_cutoff):
                cutoff_hit_step = step
            if cutoff_hit_step is not None:
                probs = np.asarray(jax.nn.softmax(logits.astype(jnp.float32), axis=-1),
                                   np.float64)
                prob_acc += probs
                prob_cnt += 1
                if step >= cutoff_hit_step + buffer_window:
                    mean_p = prob_acc / max(prob_cnt, 1)
                    norm = np.linalg.norm(mean_p, axis=-1, keepdims=True)
                    unit = mean_p / np.maximum(norm, 1e-12)
                    sim = unit @ unit.T
                    consistency = (sim.sum(-1) - 1.0) / max(n - 1, 1)
                    chosen_row = int(np.argmax(consistency))
                    chosen = int(branch_ids[chosen_row])
                    keep = jnp.asarray([chosen_row])
                    cache = cache_lib.gather_batch(cache, keep)
                    branch_ids = branch_ids[[chosen_row]]
                    cur_np = cur_np[[chosen_row]]
                    truncated = True
                    compactions.append(1)
        peak = max(peak, cache_lib.used_cache_bytes(cfg, len(branch_ids), pos, max_seq))
        if truncated and done[branch_ids[0]]:
            break
        if np.all(done[branch_ids]):
            break

    if not truncated:
        chosen = int(branch_ids[0])
    toks = log.buf[chosen, :log.len[chosen]]
    toks = toks[toks != -1].tolist()
    return GenResult(tokens=toks, chosen_branch=chosen, all_tokens=log.buf,
                     lengths=log.len.copy(), logical_tokens=logical,
                     compute_tokens=compute, peak_cache_bytes=peak, steps=step,
                     compactions=compactions,
                     extra={"cutoff": cutoff_hit_step})


# ------------------------------------------------------- dry-run target

def serve_step(params, cfg: ModelConfig, kcfg: KappaConfig,
               token, pos, cache, state: kappa_lib.KappaState, log_q, rng):
    """One fused serving step — the decode-shape dry-run lowering target:
    model decode + KAPPA scoring/gating + sampling."""
    logits, cache = decode_step(params, cfg, token, pos, cache)
    state = kappa_lib.kappa_step(state, logits, token, log_q, kcfg)
    nxt = sampler.sample(rng, logits, temperature=kcfg.temperature,
                         top_k=kcfg.top_k, top_p=kcfg.top_p)
    return nxt, cache, state
