"""Decode strategies: each method's per-step controller state and
selection rule behind one uniform interface (DESIGN.md §3).

A ``DecodeStrategy`` owns everything method-specific — KAPPA's jitted
controller state, BoN's running log-probabilities, ST-BoN's divergence
tracking — while ``RequestState`` holds the method-agnostic host state of
one in-flight request (token log, done mask, RNG stream, byte/token
accounting). The same two classes drive both execution modes:

  * the single-request loop in ``repro.serving.engine`` (one model step
    per request per iteration, cache gathered on compaction), and
  * the continuous-batching scheduler in ``repro.serving.scheduler``
    (one fused model step over a fixed row pool, rows freed on prune).

Because every host-side decision (sampling keys, masking, compaction
order, termination) lives here and is shared verbatim, the scheduler is
token-for-token equivalent to sequential serving given the same
per-request RNG keys and ``max_seq``.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import KappaConfig, ModelConfig
from repro.core import kappa as kappa_lib
from repro.core.signals import reference_log_q
from repro.models import train_logits
from repro.serving import cache as cache_lib
from repro.serving import sampler


@dataclass
class GenResult:
    tokens: List[int]                 # generated tokens of the chosen branch
    chosen_branch: int                # original branch index
    all_tokens: np.ndarray            # (N, T) all branch tokens (-1 pad)
    lengths: np.ndarray               # (N,) live lengths
    logical_tokens: int               # paper-style token count
    compute_tokens: int               # TPU rows actually decoded
    peak_cache_bytes: int             # branch-scaling memory peak
    steps: int
    compactions: List[int] = field(default_factory=list)
    extra: Dict = field(default_factory=dict)
    status: str = "OK"                # terminal status: OK | CANCELLED |
                                      #   TIMEOUT | FAILED | SHED
    n_retries: int = 0                # fault-triggered replays before finish


@dataclass
class StepDecision:
    """What a strategy decided after observing one decode step."""
    counted: np.ndarray               # (rows,) bool — log + logical accounting
    keep: Optional[np.ndarray] = None  # sorted row indices to compact to
    stop: bool = False                # request finished


class TokenLog:
    """Host-side per-branch token buffers surviving compaction."""

    def __init__(self, n: int, max_new: int):
        self.buf = np.full((n, max_new), -1, np.int32)
        self.len = np.zeros((n,), np.int64)

    def append(self, branch_ids: np.ndarray, tokens: np.ndarray,
               active: np.ndarray):
        for row, b in enumerate(branch_ids):
            if active[row]:
                self.buf[b, self.len[b]] = tokens[row]
                self.len[b] += 1


@functools.partial(jax.jit, static_argnums=(1,))
def _bos_log_q(params, cfg: ModelConfig, bos_token, frontend=None):
    """Unconditional reference logits q from the BOS-only context
    (Alg. 2 line 9)."""
    logits, _ = train_logits(params, cfg, bos_token[None, None], frontend)
    return reference_log_q(logits[0, -1])


_kappa_controller = jax.jit(kappa_lib.kappa_step, static_argnums=(4,))


def controller_key(kcfg: KappaConfig) -> KappaConfig:
    """The subset of a KappaConfig the controller math depends on.
    ``max_new_tokens`` is a host-side stopping knob only, so requests
    that differ in nothing else can share one pooled controller (and one
    jit specialization)."""
    return dataclasses.replace(kcfg, max_new_tokens=0)


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def _pooled_kappa_tick(kcfg: KappaConfig, state, logits, toks, gather_idx,
                       done_prev, reset, slot_active, row_n, log_q, eos_id):
    """ONE device program advancing every pooled kappa controller:

      * re-initialize slots acquired since the last tick (``reset``) with
        their own live-row count (padding rows masked dead);
      * gather each slot's branch logits/tokens from the scheduler's row
        pool (``gather_idx`` maps controller rows to pool rows — dropped
        rows point at row 0 and are dead in the state, so their garbage
        never propagates);
      * force already-done rows' tokens to EOS exactly as
        ``RequestState.advance`` does on host;
      * one vmapped kappa_step over all slots; inactive slots keep their
        (reset) state untouched.

    Returns the new state plus the (alive, traj, cutoff) views the host
    needs — transferred by the caller in the same blocking device_get as
    the sampled tokens, so the controller costs one dispatch and zero
    extra syncs per tick."""
    def sel(mask, a, b):
        return jnp.where(mask.reshape(mask.shape + (1,) * (a.ndim - 1)), a, b)

    fresh = kappa_lib.init_pool_rows(kcfg, row_n)
    state = jax.tree.map(lambda f, s: sel(reset, f, s), fresh, state)
    step_logits = logits[gather_idx]                      # (S, N, V)
    step_toks = jnp.where(done_prev, eos_id, toks[gather_idx])
    new = kappa_lib.pooled_step(state, step_logits, step_toks, log_q, kcfg)
    new = jax.tree.map(lambda a, b: sel(slot_active, a, b), new, state)
    return new, (new.alive, new.traj, new.cutoff)


class PooledKappaController:
    """Device-resident stacked KappaState shared by every kappa request
    in a scheduler pool (DESIGN.md §4).

    The scheduler acquires a slot per admitted kappa request, builds one
    (slots, fan_out) gather map per tick, and calls :meth:`dispatch`
    once — regardless of how many requests are active. ``publish``
    stores the host copies (fetched by the scheduler inside its existing
    per-tick device_get) that :class:`KappaStrategy` then reads its
    slice of, replacing the per-request ``np.asarray(state.alive)``
    sync that previously dominated scheduler ticks."""

    def __init__(self, params, cfg: ModelConfig, kcfg: KappaConfig, *,
                 slots: int, bos_id: int, frontend=None):
        self.kcfg = kcfg
        self.slots = slots
        self.nmax = kcfg.num_branches
        self.log_q = _bos_log_q(params, cfg, jnp.int32(bos_id),
                                frontend[:1] if frontend is not None else None)
        self.state = kappa_lib.init_pool(kcfg, slots)
        self.free = list(range(slots))
        self.row_n = np.full((slots,), self.nmax, np.int32)
        self.pending_reset = np.zeros((slots,), bool)
        self.slot_active = np.zeros((slots,), bool)
        # mirror defaults come from init_state itself so the values served
        # before a slot's first dispatch can never drift from the device
        init_cut = int(kappa_lib.init_state(kcfg).cutoff)
        self._init_cut = init_cut
        # host mirrors of the per-tick controller outputs
        self.alive = np.zeros((slots, self.nmax), bool)
        self.traj = np.zeros((slots, self.nmax), np.float32)
        self.cutoff = np.full((slots,), init_cut, np.int32)
        self.dispatches = 0

    def acquire(self, n_rows: int) -> int:
        slot = self.free.pop(0)
        self.pending_reset[slot] = True
        self.slot_active[slot] = True
        self.row_n[slot] = n_rows
        self.alive[slot] = np.arange(self.nmax) < n_rows
        self.traj[slot] = 0.0
        self.cutoff[slot] = self._init_cut
        return slot

    def release(self, slot: int) -> None:
        self.slot_active[slot] = False
        self.free.append(slot)
        self.free.sort()

    def dispatch(self, pool_logits, pool_toks, gather_idx: np.ndarray,
                 done_prev: np.ndarray, eos_id: int):
        """One jitted controller step for all active slots; returns the
        DEVICE (alive, traj, cutoff) tuple so the caller can fold it into
        its single blocking transfer for the tick."""
        self.state, out = _pooled_kappa_tick(
            self.kcfg, self.state, pool_logits, pool_toks,
            jnp.asarray(gather_idx), jnp.asarray(done_prev),
            jnp.asarray(self.pending_reset), jnp.asarray(self.slot_active),
            jnp.asarray(self.row_n), self.log_q, jnp.int32(eos_id))
        self.pending_reset[:] = False
        self.dispatches += 1
        return out

    def publish(self, out_host) -> None:
        """Store the host copies of this tick's controller outputs.
        Copied: device_get hands back read-only buffers, and acquire()
        re-initializes a slot's mirror rows in place."""
        alive, traj, cutoff = out_host
        self.alive = np.array(alive)
        self.traj = np.array(traj)
        self.cutoff = np.array(cutoff)


# device-side picked-token log-prob: only the (N,) vector crosses to
# host, not the full (N, V) softmax (the BoN per-step round-trip fix).
# One definition shared with the fused sampler dispatch so the BoN
# single-request path and the scheduler's fused path can never diverge.
_picked_logprob = sampler.picked_logprob


# ------------------------------------------------------------- strategies

class DecodeStrategy:
    """Per-method controller. Subclasses hold all method-specific state;
    the driving loop only sees rows/begin/step/choose."""

    name = "base"
    greedy = False  # argmax sampling instead of temperature sampling
    # strategy consumes the picked-token log-prob each step; the
    # scheduler then computes it for ALL rows in one fused per-tick
    # dispatch and hands each request its slice (see RequestState.advance)
    wants_picked_lp = False
    # strategy reads the raw per-step logits in step() — False lets the
    # scheduler skip the per-request device gather entirely
    needs_step_logits = True

    def rows(self, kcfg: KappaConfig) -> int:
        return kcfg.num_branches

    def begin(self, params, cfg: ModelConfig, kcfg: KappaConfig, *,
              bos_id: int, frontend=None) -> None:
        self.kcfg = kcfg

    def init_done(self, tokens0: np.ndarray, eos_id: int) -> np.ndarray:
        return np.zeros(tokens0.shape, bool)

    def observe_prefill(self, logits0, tokens0: np.ndarray) -> None:
        pass

    def step(self, logits, in_tokens: np.ndarray, out_tokens: np.ndarray,
             branch_ids: np.ndarray, done: np.ndarray,
             done_prev: np.ndarray, step_idx: int,
             picked_lp: Optional[np.ndarray] = None) -> StepDecision:
        raise NotImplementedError

    def choose(self, branch_ids: np.ndarray, done: np.ndarray) -> int:
        return int(branch_ids[0])

    def decided_branch(self, branch_ids: np.ndarray,
                       done: np.ndarray) -> Optional[int]:
        """Branch id whose logged tokens are *committed* — certain to be
        the final ``choose()`` pick however decoding continues — or None
        while selection is still open. The streaming scheduler emits a
        request's tokens only from this branch, which keeps every
        streamed prefix a prefix of the final ``GenResult.tokens``.
        Conservative default: undecided until the terminal flush."""
        return None

    def release_pool(self) -> None:
        """Return any shared pooled-controller slot (no-op by default)."""

    def extra(self) -> Dict:
        return {}


class GreedyStrategy(DecodeStrategy):
    """Single deterministic branch decoded to EOS."""

    name = "greedy"
    greedy = True
    needs_step_logits = False

    def rows(self, kcfg: KappaConfig) -> int:
        return 1

    def init_done(self, tokens0, eos_id):
        return tokens0 == eos_id

    def step(self, logits, in_tokens, out_tokens, branch_ids, done,
             done_prev, step_idx, picked_lp=None):
        # the EOS token itself is logged/counted (emitted before done)
        return StepDecision(counted=~done_prev,
                            stop=bool(done[branch_ids[0]]))

    def decided_branch(self, branch_ids, done):
        return int(branch_ids[0])   # one branch; every token is final


class BoNStrategy(DecodeStrategy):
    """Full Best-of-N with negative-perplexity selection (Kang et al.
    2025): every branch decodes to EOS, keep the most likely one."""

    name = "bon"
    wants_picked_lp = True

    def begin(self, params, cfg, kcfg, *, bos_id, frontend=None):
        super().begin(params, cfg, kcfg, bos_id=bos_id, frontend=frontend)
        n = kcfg.num_branches
        self.sum_lp = np.zeros((n,), np.float64)
        self.count = np.zeros((n,), np.int64)

    def observe_prefill(self, logits0, tokens0):
        picked = _picked_logprob(logits0, jnp.asarray(tokens0))
        self.sum_lp += np.asarray(picked, np.float64)
        self.count += 1

    def step(self, logits, in_tokens, out_tokens, branch_ids, done,
             done_prev, step_idx, picked_lp=None):
        if picked_lp is None:  # single-request path: own (N,) extraction
            picked_lp = np.asarray(
                _picked_logprob(logits, jnp.asarray(out_tokens)))
        step_lp = np.asarray(picked_lp, np.float64)
        newly = ~done_prev  # a branch's own EOS step still counts toward ppl
        # index by branch id: after eager release the step arrays cover
        # only surviving rows, while sum_lp/count stay full fan-out
        self.sum_lp[branch_ids] += np.where(newly, step_lp, 0.0)
        self.count[branch_ids] += newly
        # release EOS'd branches eagerly: a done branch contributes
        # nothing further to its perplexity, so its rows (and KV pages)
        # go back to the pool instead of decoding dead tokens to the end
        alive = ~done[branch_ids]
        keep = np.where(alive)[0] if alive.any() and not alive.all() else None
        return StepDecision(counted=newly, keep=keep, stop=bool(np.all(done)))

    def choose(self, branch_ids, done):
        return int(np.argmax(self._neg_ppl()))

    def decided_branch(self, branch_ids, done):
        # perplexity ranks over the FULL fan-out (eagerly-released EOS
        # branches included), so the winner can change until the last
        # branch finishes — undecided unless the fan-out is one
        return int(branch_ids[0]) if len(self.sum_lp) == 1 else None

    def _neg_ppl(self):
        return self.sum_lp / np.maximum(self.count, 1)

    def extra(self):
        return {"neg_ppl": self._neg_ppl().tolist()}


class STBoNStrategy(DecodeStrategy):
    """Self-Truncation BoN (Wang et al. 2025): decode until the earliest
    point of pairwise difference + a fixed buffer window, then keep the
    branch most consistent with the others and truncate the rest.

    Consistency here = mean pairwise cosine similarity of the branches'
    buffer-window-averaged next-token distributions (the paper uses
    latent-embedding consistency; distribution-space consistency is the
    closest signal our engine already materializes — noted in DESIGN.md).
    """

    name = "stbon"

    def __init__(self, buffer_window: int = 16):
        self.buffer_window = buffer_window

    def begin(self, params, cfg, kcfg, *, bos_id, frontend=None):
        super().begin(params, cfg, kcfg, bos_id=bos_id, frontend=frontend)
        n = kcfg.num_branches
        self.diverged = np.eye(n, dtype=bool)
        self.cutoff_hit: Optional[int] = None
        self.prob_acc = np.zeros((n, cfg.vocab_size), np.float64)
        self.prob_cnt = 0
        self.truncated = False

    def step(self, logits, in_tokens, out_tokens, branch_ids, done,
             done_prev, step_idx, picked_lp=None):
        kcfg = self.kcfg
        keep = None
        if not self.truncated:
            self.diverged |= out_tokens[:, None] != out_tokens[None, :]
            if self.cutoff_hit is None and (np.all(self.diverged)
                                            or step_idx >= kcfg.max_cutoff):
                self.cutoff_hit = step_idx
            if self.cutoff_hit is not None:
                probs = np.asarray(
                    jax.nn.softmax(logits.astype(jnp.float32), axis=-1),
                    np.float64)
                self.prob_acc += probs
                self.prob_cnt += 1
                if step_idx >= self.cutoff_hit + self.buffer_window:
                    keep = np.array([int(np.argmax(self._consistency()))])
                    self.truncated = True
        bids = branch_ids if keep is None else branch_ids[keep]
        stop = (self.truncated and bool(done[bids[0]])) or bool(np.all(done[bids]))
        # EOS-emitting steps count (~done_prev), matching greedy/BoN —
        # a branch's own EOS token is part of its generated sequence
        return StepDecision(counted=~done_prev, keep=keep, stop=stop)

    def _consistency(self):
        mean_p = self.prob_acc / max(self.prob_cnt, 1)
        norm = np.linalg.norm(mean_p, axis=-1, keepdims=True)
        unit = mean_p / np.maximum(norm, 1e-12)
        sim = unit @ unit.T
        n = self.prob_acc.shape[0]
        return (sim.sum(-1) - 1.0) / max(n - 1, 1)

    def choose(self, branch_ids, done):
        """If every branch hit EOS before ``cutoff + buffer_window``
        forced a truncation, select by the consistency accumulated so
        far instead of silently falling back to branch 0. Before any
        divergence (no cutoff, no signal accumulated) all branches are
        prefix-identical, so branch 0 is the deliberate tie-break."""
        if self.truncated:
            return int(branch_ids[0])
        if self.prob_cnt > 0:
            return int(branch_ids[int(np.argmax(self._consistency()))])
        return int(branch_ids[0])

    def decided_branch(self, branch_ids, done):
        # after self-truncation only the consistency winner survives and
        # choose() is pinned to it; before that the pick can still move
        return int(branch_ids[0]) if self.truncated else None

    def extra(self):
        return {"cutoff": self.cutoff_hit}


class KappaStrategy(DecodeStrategy):
    """The paper's KAPPA controller: latent-informativeness scoring with
    scheduled pruning and bucketed cache compaction (DESIGN.md §2).

    Two controller backends behind the same host-side decisions:

      * **local** (single-request engine loop, or ``fused_sampling=False``
        schedulers): this strategy owns a jitted per-request
        ``kappa_step`` — one dispatch and one blocking ``np.asarray``
        sync per step.
      * **pooled** (the batched scheduler path): the scheduler attaches a
        :class:`PooledKappaController` slot; the controller math runs in
        the scheduler's single fused tick dispatch and this strategy only
        reads its slice of the published host mirrors — zero device work
        and zero syncs here. ``ctrl_rows`` maps the request's current
        (compaction-survivor) row order onto its slot's controller rows;
        compaction just shrinks the map, the pooled state is never
        gathered (dropped rows are dead and masked — see core.kappa).
    """

    name = "kappa"

    def begin(self, params, cfg, kcfg, *, bos_id, frontend=None):
        super().begin(params, cfg, kcfg, bos_id=bos_id, frontend=frontend)
        self._begin_args = (params, cfg, jnp.int32(bos_id),
                            frontend[:1] if frontend is not None else None)
        self.state = None            # local backend, created on first use
        self.log_q = None
        self.chain = cache_lib.bucket_chain(kcfg.num_branches)
        self.pool: Optional[PooledKappaController] = None
        self.slot: Optional[int] = None
        self.ctrl_rows: Optional[np.ndarray] = None

    # ------------------------------------------------- controller backends

    def attach_pool(self, pool: PooledKappaController, slot: int,
                    n_rows: int) -> None:
        self.pool, self.slot = pool, slot
        self.ctrl_rows = np.arange(n_rows)
        # the pooled tick computes signals from the pool logits directly;
        # the scheduler can skip this request's per-tick logits gather
        self.needs_step_logits = False

    def release_pool(self) -> None:
        if self.pool is not None:
            self.pool.release(self.slot)
            self.pool = self.slot = self.ctrl_rows = None
            self._pool_released = True

    def _local_state(self):
        if getattr(self, "_pool_released", False):
            # result() must run BEFORE release_pool(); lazily building a
            # fresh local state here would silently report branch 0 /
            # zero trajectories instead of the pooled outcome
            raise RuntimeError(
                "KappaStrategy read after its pooled-controller slot was "
                "released — call result() before release_pool()")
        if self.state is None:
            params, cfg, bos, fe = self._begin_args
            self.log_q = _bos_log_q(params, cfg, bos, fe)
            self.state = kappa_lib.init_state(self.kcfg)
        return self.state

    # ---------------------------------------------------------------- step

    def step(self, logits, in_tokens, out_tokens, branch_ids, done,
             done_prev, step_idx, picked_lp=None):
        kcfg = self.kcfg
        if self.pool is not None:
            # controller already stepped in the scheduler's fused tick
            # dispatch; read this request's slice of the host mirrors
            alive = self.pool.alive[self.slot][self.ctrl_rows]
            traj = self.pool.traj[self.slot][self.ctrl_rows]
        else:
            # controller contract: ``tokens`` are the tokens JUST sampled
            # (out_tokens) — feeding last step's tokens delays the
            # adaptive cutoff one step past true all-pairwise divergence
            self.state = _kappa_controller(self._local_state(), logits,
                                           jnp.asarray(out_tokens),
                                           self.log_q, kcfg)
            # ONE fused blocking transfer for both controller outputs —
            # the local-path twin of the pooled tick's single device_get
            # repro-lint: disable-next-line=sync-discipline
            alive, traj = jax.device_get((self.state.alive,
                                          self.state.traj))
        # ~done_prev: a branch's own EOS-emitting step is logged/counted,
        # the same accounting greedy and BoN use
        counted = alive & ~done_prev

        keep = None
        rows = len(branch_ids)
        if kcfg.compaction:
            n_alive = int(np.sum(alive))
            bucket = cache_lib.next_bucket(self.chain, max(n_alive, 1), rows)
            if bucket < rows:
                order = np.argsort(~alive * 1_000_000 - traj)  # alive best first
                keep = np.sort(order[:bucket])
                if self.pool is not None:
                    self.ctrl_rows = self.ctrl_rows[keep]
                else:
                    self.state = kappa_lib.compact_state(self.state,
                                                         jnp.asarray(keep))
                alive = alive[keep]

        # termination on the post-compaction view
        bids = branch_ids if keep is None else branch_ids[keep]
        live = bids[alive]
        stop = (len(live) == 1 and bool(done[live[0]])) \
            or bool(np.all(done[bids] | ~alive))
        return StepDecision(counted=counted, keep=keep, stop=stop)

    # ------------------------------------------------------------ selection

    def _alive_traj(self):
        if self.pool is not None:
            return (self.pool.alive[self.slot][self.ctrl_rows],
                    self.pool.traj[self.slot][self.ctrl_rows])
        st = self._local_state()
        # one fused transfer instead of two sequential blocking reads
        # repro-lint: disable-next-line=sync-discipline
        return jax.device_get((st.alive, st.traj))

    def choose(self, branch_ids, done):
        alive, traj = self._alive_traj()
        masked = np.where(alive, traj, -np.inf)
        return int(branch_ids[int(np.argmax(masked))])

    def decided_branch(self, branch_ids, done):
        # pruning is monotone (a pruned branch never revives), so once a
        # single survivor remains it IS the final choose() pick
        alive, traj = self._alive_traj()
        if int(np.sum(alive)) != 1:
            return None
        masked = np.where(alive, traj, -np.inf)
        return int(branch_ids[int(np.argmax(masked))])

    def extra(self):
        if self.pool is not None:
            cutoff = int(self.pool.cutoff[self.slot])
            traj = self.pool.traj[self.slot][self.ctrl_rows]
        else:
            st = self._local_state()
            # repro-lint: disable-next-line=sync-discipline
            cut_np, traj = jax.device_get((st.cutoff, st.traj))
            cutoff = int(cut_np)
        return {"cutoff": cutoff, "traj": traj.tolist()}


_STRATEGIES = {
    "greedy": GreedyStrategy,
    "bon": BoNStrategy,
    "stbon": STBoNStrategy,
    "kappa": KappaStrategy,
}


def make_strategy(name: str, **kw) -> DecodeStrategy:
    return _STRATEGIES[name](**kw)


# ----------------------------------------------------------- request state

class RequestState:
    """Method-agnostic host state of one in-flight request.

    Owns the RNG stream, the done mask, the token log, and the
    logical/compute/byte accounting. The driver (engine loop or
    scheduler) owns the device cache; it applies ``StepDecision.keep``
    to its own row storage (gather for a dedicated cache, slot freeing
    for the shared pool)."""

    def __init__(self, strategy: DecodeStrategy, params, cfg: ModelConfig,
                 kcfg: KappaConfig, prompt_len: int, rng, *, eos_id: int,
                 bos_id: int, max_seq: int, n_prefix: int, frontend=None):
        self.strategy = strategy
        self.cfg = cfg
        self.kcfg = kcfg
        self.eos_id = eos_id
        self.max_seq = max_seq
        self.rng = rng
        strategy.begin(params, cfg, kcfg, bos_id=bos_id, frontend=frontend)
        self.n = strategy.rows(kcfg)
        self.log = TokenLog(self.n, kcfg.max_new_tokens + 1)
        self.branch_ids = np.arange(self.n)
        self.pos = prompt_len + n_prefix
        self.step = 0
        self.logical = 0
        self.compute = 0
        self.compactions: List[int] = []
        self.peak = cache_lib.used_cache_bytes(cfg, self.n, self.pos, max_seq)
        self.done: Optional[np.ndarray] = None
        self.cur: Optional[np.ndarray] = None
        self.finished = False

    def first_tokens(self, pf_logits) -> np.ndarray:
        """Sample the fan-out tokens from the prefill logits."""
        keys0 = self.step_keys()
        logits0 = jnp.broadcast_to(pf_logits, (self.n, pf_logits.shape[-1]))
        cur = sampler.sample_rows(keys0, logits0, self._greedy_mask(self.n),
                                  self.kcfg)
        self.cur = np.asarray(cur)
        self.done = self.strategy.init_done(self.cur, self.eos_id)
        self.strategy.observe_prefill(logits0, self.cur)
        self.log.append(self.branch_ids, self.cur, np.ones(self.n, bool))
        self.logical += self.n
        self.compute += self.n
        if np.all(self.done) or self.kcfg.max_new_tokens <= 1:
            self.finished = True
        return self.cur

    def step_keys(self):
        """Advance this request's RNG stream and derive one sampling key
        per live row. The scheduler gathers these across requests into a
        single fused :func:`repro.serving.sampler.sample_rows` dispatch;
        the engine loop uses them via :meth:`sample_and_advance`. Both
        consume the stream identically, so tokens match across modes.

        Returned keys are always raw (n, 2) uint32 key data — new-style
        *threefry* typed keys (``jax.random.key``'s default impl) are
        unwrapped so the scheduler's pooled key buffer works for either
        flavor the caller submitted. Wider key impls (e.g. rbg's 4-word
        data) are rejected up front rather than silently misread."""
        self.rng, kk = jax.random.split(self.rng)
        keys = jax.random.split(kk, len(self.branch_ids))
        if jnp.issubdtype(keys.dtype, jax.dtypes.prng_key):
            keys = jax.random.key_data(keys)
        if keys.shape[-1] != 2:
            raise ValueError(
                f"request RNG uses a {keys.shape[-1]}-word key impl; the "
                "serving stack supports 2-word (threefry) keys only")
        return keys

    def _greedy_mask(self, n: int):
        return jnp.full((n,), self.strategy.greedy)

    def sample_and_advance(self, logits) -> StepDecision:
        """Single-request path: one ``sample_rows`` dispatch for this
        request's rows, then the shared host-side bookkeeping."""
        keys = self.step_keys()
        toks = sampler.sample_rows(keys, logits,
                                   self._greedy_mask(len(self.branch_ids)),
                                   self.kcfg)
        return self.advance(logits, np.asarray(toks))

    def advance(self, logits, tokens: np.ndarray,
                picked_lp: Optional[np.ndarray] = None) -> StepDecision:
        """Host-side work for one decode step given this request's
        per-branch logits and pre-sampled next tokens (sampled with this
        request's :meth:`step_keys`). ``picked_lp`` optionally carries the
        picked-token log-probs when the scheduler already extracted them
        for the whole pool in one dispatch (rows where ``done`` was
        already set are never consumed, so the raw-token values are
        fine). The caller must apply ``decision.keep`` to its cache
        rows."""
        nxt_np = np.asarray(tokens)
        done_prev = self.done[self.branch_ids].copy()
        nxt_np = np.where(done_prev, self.eos_id, nxt_np)
        self.done[self.branch_ids] |= (nxt_np == self.eos_id)
        self.pos += 1
        self.step += 1
        dec = self.strategy.step(logits, self.cur, nxt_np, self.branch_ids,
                                 self.done, done_prev, self.step,
                                 picked_lp=picked_lp)
        self.log.append(self.branch_ids, nxt_np, dec.counted)
        self.logical += int(np.sum(dec.counted))
        self.compute += len(self.branch_ids)
        self.cur = nxt_np
        if dec.keep is not None and len(dec.keep) < len(self.branch_ids):
            # bytes are monotone in pos at fixed row count, so the peak
            # over a constant-rows stretch is its last step: sample it
            # right before the rows shrink (and again in result()) —
            # this keeps the per-step host path free of byte accounting
            self._observe_peak()
        if dec.keep is not None:
            self.branch_ids = self.branch_ids[dec.keep]
            self.cur = self.cur[dec.keep]
            self.compactions.append(len(dec.keep))
        if dec.stop or self.step >= self.kcfg.max_new_tokens - 1:
            self.finished = True
        return dec

    def _observe_peak(self) -> None:
        self.peak = max(self.peak, cache_lib.used_cache_bytes(
            self.cfg, len(self.branch_ids), self.pos, self.max_seq))

    def result(self) -> GenResult:
        self._observe_peak()
        chosen = self.strategy.choose(self.branch_ids, self.done)
        toks = self.log.buf[chosen, :self.log.len[chosen]]
        toks = toks[toks != -1].tolist()
        return GenResult(
            tokens=toks, chosen_branch=chosen, all_tokens=self.log.buf,
            lengths=self.log.len.copy(), logical_tokens=self.logical,
            compute_tokens=self.compute, peak_cache_bytes=self.peak,
            steps=self.step, compactions=self.compactions,
            extra=self.strategy.extra())
