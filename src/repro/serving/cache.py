"""Cache utilities: batch-axis bookkeeping, compaction gathers, byte
accounting.

Cache pytrees from repro.models.init_cache have two leaf families:
  "stack" / "xkv_stack" leaves: (K, B, ...) — batch is axis 1
  "rem"   / "xkv_rem"   leaves: (B, ...)    — batch is axis 0

Bucketed compaction (the TPU-native replacement for PyTorch's eager
per-branch KV freeing, DESIGN.md §2): when the number of live branches
falls to the next power-of-two bucket, gather live rows into a smaller
cache. Each bucket size is a distinct compiled shape; the bucket chain
N → 2^⌈log2 N⌉-1 → … → 1 bounds recompilation.
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp


def _map_batched(cache: Dict[str, Any], fn_stack, fn_rem):
    out = {}
    for key, val in cache.items():
        if key.endswith("stack"):
            out[key] = jax.tree.map(fn_stack, val)
        else:
            out[key] = jax.tree.map(fn_rem, val)
    return out


def _map_batched2(cache: Dict[str, Any], other: Dict[str, Any],
                  fn_stack, fn_rem):
    out = {}
    for key, val in cache.items():
        if key.endswith("stack"):
            out[key] = jax.tree.map(fn_stack, val, other[key])
        else:
            out[key] = jax.tree.map(fn_rem, val, other[key])
    return out


def gather_batch(cache, idx):
    """Select branch rows ``idx`` from every cache leaf."""
    return _map_batched(cache, lambda a: a[:, idx], lambda a: a[idx])


def scatter_batch(pool, idx, sub):
    """Write ``sub``'s branch rows into pool rows ``idx`` — the inverse
    of :func:`gather_batch`, used by the continuous-batching scheduler to
    install a freshly prefilled request into free slots of its fixed
    (rows, max_seq) device pool (DESIGN.md §4)."""
    return _map_batched2(pool, sub,
                         lambda a, b: a.at[:, idx].set(b),
                         lambda a, b: a.at[idx].set(b))


def broadcast_batch(cache, n: int):
    """Replicate a batch-1 cache to n branches (post-prefill fan-out)."""
    def rep(a, axis):
        reps = [1] * a.ndim
        reps[axis] = n
        return jnp.tile(a, reps)
    return _map_batched(cache, lambda a: rep(a, 1), lambda a: rep(a, 0))


def cache_bytes(cache) -> int:
    """Total bytes held by the cache pytree (the branch-scaling part of
    peak memory — our static-shape analogue of the paper's M_peak)."""
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(cache))


def used_cache_bytes(cfg, rows: int, pos: int, max_seq: int) -> int:
    """Paged-allocator view of cache memory: bytes actually *referenced*
    with ``rows`` live branch rows after ``pos`` positions.

    The paper's peak-memory numbers come from PyTorch's dynamically grown
    KV tensors; a TPU serving stack gets the same effect with a paged KV
    allocator (pages freed on branch prune / never allocated past pos).
    This analytic accounting is the static-shape analogue used for the
    M_cost metric."""
    it = jnp.dtype(cfg.dtype).itemsize
    if cfg.kv_cache_dtype == "int8":
        it_kv = 1.0 + 4.0 / cfg.resolved_head_dim  # int8 + amortized scale
    else:
        it_kv = it
    hd = cfg.resolved_head_dim
    total = 0
    for bt in cfg.block_types():
        if bt == "global":
            total += rows * min(pos, max_seq) * cfg.num_kv_heads * hd * 2 * it_kv
        elif bt == "local":
            w = min(cfg.window_size, max_seq)
            total += rows * min(pos, w) * cfg.num_kv_heads * hd * 2 * it_kv
        elif bt == "recurrent":
            total += rows * (cfg.d_model * 4 + cfg.d_model * 3 * it)  # h fp32 + conv
        elif bt == "rwkv6":
            total += rows * (cfg.num_heads * hd * hd * 4 + 2 * cfg.d_model * it)
    if cfg.is_encoder_decoder:
        total += cfg.num_layers * rows * cfg.encoder_seq_len \
            * cfg.num_kv_heads * hd * 2 * it
    return int(total)


def per_request_bytes(cfg, rows_pos: Dict[Any, tuple], max_seq: int
                      ) -> Dict[Any, int]:
    """Per-request paged-view byte accounting over a shared row pool:
    ``rows_pos`` maps request id -> (occupied rows, current pos). Each
    request is charged only for the slots it owns, referenced up to its
    own position — the scheduler's analogue of the single-request
    ``used_cache_bytes`` accounting."""
    return {rid: used_cache_bytes(cfg, r, p, max_seq)
            for rid, (r, p) in rows_pos.items()}


def bucket_chain(n: int) -> List[int]:
    """Descending bucket sizes: n, then powers of two below n, down to 1."""
    out = [n]
    b = 1
    while b < n:
        b <<= 1
    b >>= 1
    while b >= 1:
        if b < n:
            out.append(b)
        b >>= 1
    return out


def next_bucket(chain: List[int], alive: int, current: int) -> int:
    """Smallest bucket in the chain that still fits ``alive`` branches and
    is smaller than ``current`` (or ``current`` if no shrink possible)."""
    best = current
    for b in chain:
        if b < best and b >= alive:
            best = b
    return best
