"""Cache utilities: batch-axis bookkeeping, compaction gathers, byte
accounting.

Cache pytrees from repro.models.init_cache have two leaf families:
  "stack" / "xkv_stack" leaves: (K, B, ...) — batch is axis 1
  "rem"   / "xkv_rem"   leaves: (B, ...)    — batch is axis 0

Bucketed compaction (the TPU-native replacement for PyTorch's eager
per-branch KV freeing, DESIGN.md §2): when the number of live branches
falls to the next power-of-two bucket, gather live rows into a smaller
cache. Each bucket size is a distinct compiled shape; the bucket chain
N → 2^⌈log2 N⌉-1 → … → 1 bounds recompilation.
"""
from __future__ import annotations

import heapq
from typing import Any, Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _map_batched(cache: Dict[str, Any], fn_stack, fn_rem):
    out = {}
    for key, val in cache.items():
        if key.endswith("stack"):
            out[key] = jax.tree.map(fn_stack, val)
        else:
            out[key] = jax.tree.map(fn_rem, val)
    return out


def _map_batched2(cache: Dict[str, Any], other: Dict[str, Any],
                  fn_stack, fn_rem):
    out = {}
    for key, val in cache.items():
        if key.endswith("stack"):
            out[key] = jax.tree.map(fn_stack, val, other[key])
        else:
            out[key] = jax.tree.map(fn_rem, val, other[key])
    return out


def gather_batch(cache, idx):
    """Select branch rows ``idx`` from every cache leaf."""
    return _map_batched(cache, lambda a: a[:, idx], lambda a: a[idx])


def scatter_batch(pool, idx, sub):
    """Write ``sub``'s branch rows into pool rows ``idx`` — the inverse
    of :func:`gather_batch`, used by the continuous-batching scheduler to
    install a freshly prefilled request into free slots of its fixed
    (rows, max_seq) device pool (DESIGN.md §4)."""
    return _map_batched2(pool, sub,
                         lambda a, b: a.at[:, idx].set(b),
                         lambda a, b: a.at[idx].set(b))


def scatter_batch_prefix(pool, idx, sub):
    """Like :func:`scatter_batch`, but ``sub``'s leaves may be *shorter*
    than the pool's on their non-batch axes (a prompt-sized prefill
    cache installed into max_seq-sized pool rows): each leaf writes only
    its own extent, leaving the rows' tails untouched. Stale data beyond
    a request's written positions is never read — decode writes position
    p before attending with ``kv_pos <= p``, and ring-slot validity
    masks unwritten slots. ``sub`` may be batch-1 (broadcast into all
    ``idx`` rows) or match ``len(idx)``."""
    def st(a, b):
        sl = (slice(None), idx) + tuple(slice(0, s) for s in b.shape[2:])
        return a.at[sl].set(b)

    def rm(a, b):
        sl = (idx,) + tuple(slice(0, s) for s in b.shape[1:])
        return a.at[sl].set(b)

    return _map_batched2(pool, sub, st, rm)


def broadcast_batch(cache, n: int):
    """Replicate a batch-1 cache to n branches (post-prefill fan-out)."""
    def rep(a, axis):
        reps = [1] * a.ndim
        reps[axis] = n
        return jnp.tile(a, reps)
    return _map_batched(cache, lambda a: rep(a, 1), lambda a: rep(a, 0))


def cache_bytes(cache) -> int:
    """Total bytes held by the cache pytree (the branch-scaling part of
    peak memory — our static-shape analogue of the paper's M_peak)."""
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(cache))


def _kv_token_bytes(cfg) -> int:
    """Exact bytes one (token, kv-head) K *or* V entry costs: hd values
    in the cache dtype, plus one fp32 absmax scale per token-head when
    quantized. Integer math — the old amortized-per-element float
    (``1 + 4/hd``) drifted under ``int()`` truncation whenever the head
    dim wasn't a power of two, so accounting disagreed with the
    allocator's ``leaf.nbytes`` truth."""
    hd = cfg.resolved_head_dim
    if cfg.kv_cache_dtype == "int8":
        return hd * 1 + 4                      # int8 values + fp32 scale
    return hd * jnp.dtype(cfg.dtype).itemsize


def _kv_itemsize(cfg) -> float:
    """Per-element KV byte cost (quantization-aware), kept for display /
    ratio math; byte *accounting* uses the exact :func:`_kv_token_bytes`."""
    return _kv_token_bytes(cfg) / cfg.resolved_head_dim


def used_cache_bytes(cfg, rows: int, pos: int, max_seq: int, *,
                     skip_global: bool = False) -> int:
    """Paged-allocator view of cache memory: bytes actually *referenced*
    with ``rows`` live branch rows after ``pos`` positions.

    The paper's peak-memory numbers come from PyTorch's dynamically grown
    KV tensors; a TPU serving stack gets the same effect with a paged KV
    allocator (pages freed on branch prune / never allocated past pos).
    This analytic accounting is the static-shape analogue used for the
    M_cost metric. ``skip_global=True`` drops the global-attention term —
    the paged scheduler charges that part from allocator truth instead
    (owned pages × :func:`page_bytes`, shared pages once)."""
    it = jnp.dtype(cfg.dtype).itemsize
    tb_kv = _kv_token_bytes(cfg)
    hd = cfg.resolved_head_dim
    total = 0
    for bt in cfg.block_types():
        if bt == "global":
            if skip_global:
                continue
            total += rows * min(pos, max_seq) * cfg.num_kv_heads * 2 * tb_kv
        elif bt == "local":
            w = min(cfg.window_size, max_seq)
            total += rows * min(pos, w) * cfg.num_kv_heads * 2 * tb_kv
        elif bt == "recurrent":
            total += rows * (cfg.d_model * 4 + cfg.d_model * 3 * it)  # h fp32 + conv
        elif bt == "rwkv6":
            total += rows * (cfg.num_heads * hd * hd * 4 + 2 * cfg.d_model * it)
    if cfg.is_encoder_decoder:
        total += cfg.num_layers * rows * cfg.encoder_seq_len \
            * cfg.num_kv_heads * hd * 2 * it
    return int(total)


def per_request_bytes(cfg, rows_pos: Dict[Any, tuple], max_seq: int
                      ) -> Dict[Any, int]:
    """Per-request paged-view byte accounting over a shared row pool:
    ``rows_pos`` maps request id -> (occupied rows, current pos). Each
    request is charged only for the slots it owns, referenced up to its
    own position — the scheduler's analogue of the single-request
    ``used_cache_bytes`` accounting."""
    return {rid: used_cache_bytes(cfg, r, p, max_seq)
            for rid, (r, p) in rows_pos.items()}


# ----------------------------------------------------------- paged pool
#
# DESIGN.md §5: the paged scheduler replaces the contiguous (rows,
# max_seq) reservation with fixed-size pages handed out from a free
# list. Freeing a pruned branch returns its pages immediately — no
# gather/compaction on the scheduler path — and admission is counted in
# pages, so rows of different lengths share the pool.


class PageAllocator:
    """Host-side page bookkeeping for the shared device page pool,
    with per-page reference counts for copy-on-write prefix sharing.

    ``num_pages`` allocatable physical pages of ``page_size`` token slots
    each; physical index ``num_pages`` is the shared *trash* page (the
    device pool is allocated with one extra page). Block tables are
    (rows, max_pages) int32 in *device form*: owned logical pages map to
    real physical pages, everything else aliases the trash page, so
    attention validity stays purely positional (kv_pos <= pos).

    ``ref`` counts how many block tables reference each physical page.
    Fan-out branches alias the fully-written prompt pages (``ref`` = N)
    and privately own everything they write (``ref`` = 1 — the COW
    invariant :meth:`write_page` enforces); :meth:`free_row` returns a
    page to the free list only when its last reference drops.

    The free list is a min-heap: freeing is O(log F) per page (not a
    full sort on the hot pruning path) and allocation always hands out
    the smallest free physical id, so page placement is a deterministic
    function of the alloc/free history."""

    def __init__(self, num_pages: int, page_size: int, rows: int,
                 max_pages: int, fault_plan=None):
        if num_pages < 1:
            raise ValueError("need at least one allocatable page")
        self.num_pages = num_pages
        self.page_size = page_size
        self.trash = num_pages
        self.rows = rows
        self.max_pages = max_pages
        self.free_pages: List[int] = list(range(num_pages))  # min-heap
        self.block = np.full((rows, max_pages), self.trash, np.int32)
        self.owned = np.zeros((rows,), np.int32)   # block-table entries/row
        self.ref = np.zeros((num_pages,), np.int32)
        # pin references held by the radix prefix cache (a pin is an
        # ordinary ``ref`` plus this attribution mark, so the invariant
        # checkers can split refcounts into table refs + pins)
        self.pinned = np.zeros((num_pages,), np.int32)
        # fault injection: ``holdback`` free pages are embargoed for the
        # current tick — ``can_alloc`` (and hence admission/eviction
        # decisions) see a smaller heap, but the raw ``free_count``
        # accounting is untouched so leak checks stay exact.
        self.fault_plan = fault_plan
        self.holdback = 0

    def begin_tick(self, tick: int) -> int:
        """Consult the fault plan for this tick's allocator-exhaustion
        embargo; returns the holdback so callers can count injections."""
        self.holdback = (self.fault_plan.page_holdback(tick)
                         if self.fault_plan is not None else 0)
        return self.holdback

    # ------------------------------------------------------------ queries

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` positions of one row."""
        return -(-int(n_tokens) // self.page_size)

    @property
    def free_count(self) -> int:
        return len(self.free_pages)

    @property
    def used_count(self) -> int:
        return self.num_pages - len(self.free_pages)

    @property
    def avail_count(self) -> int:
        """Pages actually allocatable this tick (free minus embargo)."""
        return max(0, len(self.free_pages) - self.holdback)

    def can_alloc(self, n_pages: int) -> bool:
        return self.avail_count >= n_pages

    def row_pages(self, row: int) -> np.ndarray:
        """Physical pages referenced by ``row``'s block table."""
        return self.block[row, :int(self.owned[row])]

    # ---------------------------------------------------------- lifecycle

    def alloc_pages(self, n_pages: int) -> List[int]:
        """Pop ``n_pages`` free pages (smallest physical ids first). The
        pages are unreferenced until installed into a block table via
        :meth:`set_row_pages`."""
        if not self.can_alloc(n_pages):
            raise ValueError(f"out of pages: need {n_pages}, "
                             f"free {len(self.free_pages)}"
                             + (f" (holdback {self.holdback})"
                                if self.holdback else ""))
        return [heapq.heappop(self.free_pages) for _ in range(n_pages)]

    def set_row_pages(self, row: int, pages: Sequence[int]) -> None:
        """Install ``pages`` as ``row``'s block table (shared prefix pages
        may appear in several rows' tables; each installation takes one
        reference)."""
        if self.owned[row]:
            raise ValueError(f"row {row} already owns {self.owned[row]} pages")
        if len(pages) > self.max_pages:
            raise ValueError(f"{len(pages)} pages > max_pages={self.max_pages}")
        n = len(pages)
        self.block[row, :n] = pages
        self.block[row, n:] = self.trash
        self.owned[row] = n
        for p in pages:
            self.ref[int(p)] += 1

    def alloc_row(self, row: int, n_pages: int) -> np.ndarray:
        """Hand ``n_pages`` fresh private pages to ``row``."""
        if self.owned[row]:
            raise ValueError(f"row {row} already owns {self.owned[row]} pages")
        if n_pages > self.max_pages:
            raise ValueError(f"{n_pages} pages > max_pages={self.max_pages}")
        pages = np.array(self.alloc_pages(n_pages), np.int32)
        self.set_row_pages(row, pages)
        return pages

    def append_page(self, row: int) -> int:
        """Lazy growth: hand ``row`` one more private page (the next
        decode page, acquired when its position crosses a page
        boundary)."""
        n = int(self.owned[row])
        if n >= self.max_pages:
            raise ValueError(f"row {row} already at max_pages={self.max_pages}")
        p = self.alloc_pages(1)[0]
        self.block[row, n] = p
        self.owned[row] = n + 1
        self.ref[p] = 1
        return p

    def free_row(self, row: int) -> None:
        """Drop every reference ``row`` holds; pages whose last reference
        this was go back on the free heap (O(log F) each)."""
        for p in self.block[row, :int(self.owned[row])]:
            p = int(p)
            self.ref[p] -= 1
            if self.ref[p] == 0:
                heapq.heappush(self.free_pages, p)
        self.block[row] = self.trash
        self.owned[row] = 0

    # -------------------------------------------------------- pinned pages

    def pin_page(self, page: int) -> None:
        """Take a pin reference on ``page`` (the radix prefix cache's
        claim): the page survives every block table dropping it and
        returns to the free heap only after :meth:`unpin_page`. Pinning
        requires the page to be live (referenced) — a pin adopts an
        existing page, it never resurrects a freed one."""
        page = int(page)
        if not (0 <= page < self.num_pages):
            raise ValueError(f"cannot pin page {page}")
        if self.ref[page] == 0:
            raise ValueError(f"cannot pin unreferenced page {page}")
        self.ref[page] += 1
        self.pinned[page] += 1

    def unpin_page(self, page: int) -> None:
        """Drop a pin reference; the page goes back on the free heap
        when that was its last reference (O(log F), like
        :meth:`free_row`)."""
        page = int(page)
        if self.pinned[page] < 1:
            raise ValueError(f"page {page} is not pinned")
        self.pinned[page] -= 1
        self.ref[page] -= 1
        if self.ref[page] == 0:
            heapq.heappush(self.free_pages, page)

    # --------------------------------------------------------- COW guard

    def write_page(self, rows: np.ndarray, pos: np.ndarray) -> np.ndarray:
        """Physical page each of ``rows`` writes its token at ``pos`` into,
        with the COW invariant enforced: the write page must be inside the
        row's owned table AND referenced by that row alone (refcount 1) —
        a decode write can never land on a page shared with a sibling
        branch."""
        rows = np.asarray(rows)
        lp = np.asarray(pos) // self.page_size
        if np.any(lp >= self.owned[rows]):
            bad = rows[lp >= self.owned[rows]]
            raise AssertionError(
                f"rows {bad.tolist()} write past their allocated pages "
                "(lazy growth missed a page-boundary crossing)")
        phys = self.block[rows, lp]
        shared = self.ref[phys] != 1
        if np.any(shared):
            raise AssertionError(
                f"COW violation: rows {rows[shared].tolist()} would write "
                f"to shared pages {phys[shared].tolist()} "
                f"(refcounts {self.ref[phys][shared].tolist()})")
        return phys.astype(np.int32)


# ---------------------------------------------------- radix prefix cache
#
# DESIGN.md §7: cross-request prefix sharing. Completed (or preempted)
# requests publish their fully-written prompt pages — and, on the
# KAPPA/ST-BoN winner path, the surviving generated prefix — into a
# radix tree keyed on token ids at page granularity. Admission walks the
# tree and aliases every matched page into the new request's block table
# (one table ref per sharer, the tree keeps its pin), so chunked prefill
# starts at the first uncached token. When the free heap runs dry, the
# least-recently-hit pin-only leaves are released BEFORE any request is
# preempted.


class _RadixNode:
    """One cached page. The edge key is the page's ``page_size``-token id
    tuple relative to the parent chain's prefix; ``page`` is the pinned
    physical page holding that prefix extent's K/V."""

    __slots__ = ("key", "page", "parent", "children", "last_hit")

    def __init__(self, key, page, parent):
        self.key = key
        self.page = page
        self.parent = parent
        self.children: Dict[tuple, "_RadixNode"] = {}
        self.last_hit = 0


class RadixPrefixCache:
    """Cross-request radix tree over token-id prefixes, page-granular.

    Nodes pin refcounted pages in a :class:`PageAllocator` (one pin per
    node, taken at publish time before the publisher's block table drops
    its reference — the page never transits the free heap). Keying on
    the token ids from position 0 guarantees a matched page holds K/V
    for exactly the positions a re-prefill would write, so aliasing it
    is bitwise-equivalent to recomputation.

    Only *full* pages are cacheable: a partially-written boundary page
    mixes prefix content with slack a sharer would have to COW-copy
    anyway, and its content is not a pure function of a page-granular
    token key. Eviction (:meth:`evict_one`) releases the
    least-recently-hit leaf whose page the tree is the sole referent of;
    pages still aliased by live block tables are never candidates —
    unpinning them would free nothing and forget reusable content."""

    def __init__(self, alloc: PageAllocator, page_size: int):
        self.alloc = alloc
        self.page_size = page_size
        self.root = _RadixNode((), None, None)
        self._nodes = 0
        self._clock = 0                      # monotonic hit/publish stamp
        self.evictions = 0

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _key(self, tokens: np.ndarray, k: int) -> tuple:
        s = k * self.page_size
        return tuple(int(t) for t in tokens[s:s + self.page_size])

    def _iter_nodes(self):
        stack = list(self.root.children.values())
        while stack:
            node = stack.pop()
            yield node
            stack.extend(node.children.values())

    @property
    def pinned_count(self) -> int:
        """Pages currently pinned by the tree (= node count)."""
        return self._nodes

    @property
    def evictable_count(self) -> int:
        """Pages the tree could hand back under pressure: pin-only
        pages (no live block-table references). Rows alias contiguous
        prefixes, so a pin-only node's whole subtree is pin-only too and
        reachable leaf-by-leaf — this count is achievable, not just an
        upper bound."""
        return sum(1 for n in self._iter_nodes()
                   if int(self.alloc.ref[n.page])
                   == int(self.alloc.pinned[n.page]))

    def lookup(self, tokens) -> List[int]:
        """Physical pages of the longest cached page-granular prefix of
        ``tokens`` (empty list on a miss), LRU-stamping every matched
        node. The caller must alias the pages into a block table (taking
        its own references) before anything else can trigger eviction."""
        toks = np.asarray(tokens)
        node, pages = self.root, []
        stamp = self._tick()
        k = 0
        while (k + 1) * self.page_size <= len(toks):
            child = node.children.get(self._key(toks, k))
            if child is None:
                break
            child.last_hit = stamp
            pages.append(child.page)
            node = child
            k += 1
        return pages

    def publish(self, tokens, pages: Sequence[int]) -> int:
        """Pin ``pages`` — the block-table pages backing ``tokens``, one
        per full page, in order — into the tree under their token keys.
        Extents already cached are left alone (the earlier copy wins;
        the content is identical by construction), so republishing a
        shared preamble is idempotent. Returns the number of pages newly
        pinned."""
        toks = np.asarray(tokens)
        node, new = self.root, 0
        stamp = self._tick()
        for k, page in enumerate(pages):
            key = self._key(toks, k)
            if len(key) < self.page_size:
                break
            child = node.children.get(key)
            if child is None:
                child = _RadixNode(key, int(page), node)
                self.alloc.pin_page(int(page))
                node.children[key] = child
                self._nodes += 1
                new += 1
            child.last_hit = stamp
            node = child
        return new

    def evict_one(self) -> "int | None":
        """Release the least-recently-hit evictable leaf; returns the
        physical page handed back to the free heap, or None when nothing
        is evictable (every cached page is still aliased by a live
        table — the caller falls through to preemption)."""
        best = None
        for node in self._iter_nodes():
            if node.children:
                continue
            if int(self.alloc.ref[node.page]) \
                    != int(self.alloc.pinned[node.page]):
                continue
            if best is None or node.last_hit < best.last_hit:
                best = node
        if best is None:
            return None
        del best.parent.children[best.key]
        self.alloc.unpin_page(best.page)
        self._nodes -= 1
        self.evictions += 1
        return best.page

    def drop(self) -> int:
        """Unpin every cached page and empty the tree (teardown); pages
        whose pin was the last reference return to the free heap.
        Returns the number of nodes dropped — after this the allocator
        must account for every page again (the zero-leak check)."""
        n = 0
        for node in list(self._iter_nodes()):
            self.alloc.unpin_page(node.page)
            n += 1
        self.root = _RadixNode((), None, None)
        self._nodes = 0
        return n


def _map_layer_entries(cfg, cache: Dict[str, Any], other: Dict[str, Any],
                       fn) -> Dict[str, Any]:
    """Map ``fn(block_type, is_stack, entry, other_entry)`` over per-layer
    cache entries (cross-attn K/V entries get block_type "xkv")."""
    pattern = cfg.layer_pattern
    P = len(pattern)
    out = {
        "stack": tuple(fn(pattern[j], True, e, o) for j, (e, o)
                       in enumerate(zip(cache["stack"], other["stack"]))),
        "rem": tuple(fn(pattern[j % P], False, e, o) for j, (e, o)
                     in enumerate(zip(cache["rem"], other["rem"]))),
    }
    if "xkv_stack" in cache:
        out["xkv_stack"] = tuple(fn("xkv", True, e, o) for e, o
                                 in zip(cache["xkv_stack"], other["xkv_stack"]))
        out["xkv_rem"] = tuple(fn("xkv", False, e, o) for e, o
                               in zip(cache["xkv_rem"], other["xkv_rem"]))
    return out


def install_paged(cfg, pool, row_idx, phys_flat, sub, page_size: int):
    """Install a freshly prefilled contiguous sub-cache into the paged
    pool — the paged analogue of :func:`scatter_batch`.

    ``row_idx``: (n,) pool row slots receiving the request's branches.
    ``phys_flat``: (n * max_pages,) physical page per (row, logical page),
    trash-aliased for unowned logical pages. Global-attention leaves
    scatter page-wise (the sub-cache's sequence axis is reshaped to
    (max_pages, page_size) and written through the page list; duplicate
    trash writes are garbage-on-garbage). Every per-row leaf family
    (ring, recurrent, rwkv6, cross-KV) scatters into the row slots."""
    def per_entry(bt, is_stack, entry, sub_entry):
        if bt == "global":
            def leaf(a, b):
                if is_stack:           # a: (K, P+1, ps, ...), b: (K, n, S, ...)
                    K, n, S = b.shape[0], b.shape[1], b.shape[2]
                    br = b.reshape((K, n * (S // page_size), page_size)
                                   + b.shape[3:])
                    return a.at[:, phys_flat].set(br.astype(a.dtype))
                n, S = b.shape[0], b.shape[1]
                br = b.reshape((n * (S // page_size), page_size) + b.shape[2:])
                return a.at[phys_flat].set(br.astype(a.dtype))
            return jax.tree.map(leaf, entry, sub_entry)
        def leaf_row(a, b):
            return a.at[:, row_idx].set(b) if is_stack else a.at[row_idx].set(b)
        return jax.tree.map(leaf_row, entry, sub_entry)

    return _map_layer_entries(cfg, pool, sub, per_entry)


def install_paged_shared(cfg, pool, row_idx, src_idx, phys, sub1,
                         page_size: int):
    """Install a batch-1 prefill into the paged pool with prefix sharing —
    no N-way ``broadcast_batch`` tile, no N-way scatter.

    ``row_idx``: (n,) pool row slots receiving the request's branches.
    ``src_idx``: (M,) logical source pages of the single prefilled row.
    ``phys``: (M,) destination physical pages. Fully-written prompt pages
    appear ONCE (all n branch block tables alias them); the partially-
    written boundary page at ``prompt_len % page_size`` appears once per
    branch, so each branch gets a private copy-on-write copy to receive
    its divergent decode writes. Global leaves scatter the reshaped
    (max_pages, page_size) prefill through that (src, phys) map; every
    per-row leaf family (ring, recurrent, rwkv6, cross-KV) broadcasts the
    batch-1 state into the n row slots."""
    def per_entry(bt, is_stack, entry, sub_entry):
        if bt == "global":
            def leaf(a, b):
                if is_stack:           # a: (K, P+1, ps, ...), b: (K, 1, S, ...)
                    K, S = b.shape[0], b.shape[2]
                    br = b[:, 0].reshape((K, S // page_size, page_size)
                                         + b.shape[3:])
                    return a.at[:, phys].set(br[:, src_idx].astype(a.dtype))
                S = b.shape[1]
                br = b[0].reshape((S // page_size, page_size) + b.shape[2:])
                return a.at[phys].set(br[src_idx].astype(a.dtype))
            return jax.tree.map(leaf, entry, sub_entry)

        def leaf_row(a, b):            # b batch-1, broadcast over row_idx
            return a.at[:, row_idx].set(b) if is_stack else a.at[row_idx].set(b)
        return jax.tree.map(leaf_row, entry, sub_entry)

    return _map_layer_entries(cfg, pool, sub1, per_entry)


def copy_pages(cfg, pool, src_pages, dst_pages):
    """Device page copy inside the paged pool's global-attention leaves:
    ``dst_pages[i] <- src_pages[i]``. Used when chunked prefill
    finalizes a fan-out admission — each sibling branch gets a private
    copy-on-write duplicate of the partially-written prompt boundary
    page the prefill wrote (DESIGN.md §6)."""
    def per_entry(bt, is_stack, entry, _):
        if bt != "global":
            return entry

        def leaf(a):
            if is_stack:
                return a.at[:, dst_pages].set(a[:, src_pages])
            return a.at[dst_pages].set(a[src_pages])
        return jax.tree.map(leaf, entry)

    return _map_layer_entries(cfg, pool, pool, per_entry)


def install_rows_aux(cfg, pool, row_idx, aux):
    """Install a batch-1 aux cache's per-row leaf families (ring /
    recurrent / rwkv6 / cross-KV state threaded through chunked prefill)
    into the paged pool's ``row_idx`` slots, broadcasting across the
    fan-out. Global-attention leaves are untouched — their prompt K/V
    already lives in allocator-owned pages (DESIGN.md §6). Aux leaves
    shorter than the pool's (a ring sized to a short prompt) write only
    their own extent, like :func:`scatter_batch_prefix`."""
    def per_entry(bt, is_stack, entry, aux_entry):
        if bt == "global":
            return entry

        def leaf(a, b):
            if is_stack:
                sl = (slice(None), row_idx) + tuple(slice(0, s)
                                                    for s in b.shape[2:])
            else:
                sl = (row_idx,) + tuple(slice(0, s) for s in b.shape[1:])
            return a.at[sl].set(b)
        return jax.tree.map(leaf, entry, aux_entry)

    return _map_layer_entries(cfg, pool, aux, per_entry)


def page_bytes(cfg, page_size: int) -> int:
    """Bytes one physical page holds across every global-attention layer
    (K + V values plus, under int8, the per-token-head fp32 scale
    leaves) — the unit of the paged allocator's own byte accounting.
    Exact integer math: ``page_bytes(cfg, ps) * num_pages`` equals the
    summed ``leaf.nbytes`` of the pool's global-layer leaves (minus the
    trash page)."""
    n_global = sum(1 for bt in cfg.block_types() if bt == "global")
    return (n_global * page_size * cfg.num_kv_heads * 2
            * _kv_token_bytes(cfg))


def bucket_chain(n: int) -> List[int]:
    """Descending bucket sizes: n, then powers of two below n, down to 1."""
    out = [n]
    b = 1
    while b < n:
        b <<= 1
    b >>= 1
    while b >= 1:
        if b < n:
            out.append(b)
        b >>= 1
    return out


def next_bucket(chain: List[int], alive: int, current: int) -> int:
    """Smallest bucket in the chain that still fits ``alive`` branches and
    is smaller than ``current`` (or ``current`` if no shrink possible)."""
    best = current
    for b in chain:
        if b < best and b >= alive:
            best = b
    return best
