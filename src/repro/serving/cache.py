"""Cache utilities: batch-axis bookkeeping, compaction gathers, byte
accounting.

Cache pytrees from repro.models.init_cache have two leaf families:
  "stack" / "xkv_stack" leaves: (K, B, ...) — batch is axis 1
  "rem"   / "xkv_rem"   leaves: (B, ...)    — batch is axis 0

Bucketed compaction (the TPU-native replacement for PyTorch's eager
per-branch KV freeing, DESIGN.md §2): when the number of live branches
falls to the next power-of-two bucket, gather live rows into a smaller
cache. Each bucket size is a distinct compiled shape; the bucket chain
N → 2^⌈log2 N⌉-1 → … → 1 bounds recompilation.
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np


def _map_batched(cache: Dict[str, Any], fn_stack, fn_rem):
    out = {}
    for key, val in cache.items():
        if key.endswith("stack"):
            out[key] = jax.tree.map(fn_stack, val)
        else:
            out[key] = jax.tree.map(fn_rem, val)
    return out


def _map_batched2(cache: Dict[str, Any], other: Dict[str, Any],
                  fn_stack, fn_rem):
    out = {}
    for key, val in cache.items():
        if key.endswith("stack"):
            out[key] = jax.tree.map(fn_stack, val, other[key])
        else:
            out[key] = jax.tree.map(fn_rem, val, other[key])
    return out


def gather_batch(cache, idx):
    """Select branch rows ``idx`` from every cache leaf."""
    return _map_batched(cache, lambda a: a[:, idx], lambda a: a[idx])


def scatter_batch(pool, idx, sub):
    """Write ``sub``'s branch rows into pool rows ``idx`` — the inverse
    of :func:`gather_batch`, used by the continuous-batching scheduler to
    install a freshly prefilled request into free slots of its fixed
    (rows, max_seq) device pool (DESIGN.md §4)."""
    return _map_batched2(pool, sub,
                         lambda a, b: a.at[:, idx].set(b),
                         lambda a, b: a.at[idx].set(b))


def broadcast_batch(cache, n: int):
    """Replicate a batch-1 cache to n branches (post-prefill fan-out)."""
    def rep(a, axis):
        reps = [1] * a.ndim
        reps[axis] = n
        return jnp.tile(a, reps)
    return _map_batched(cache, lambda a: rep(a, 1), lambda a: rep(a, 0))


def cache_bytes(cache) -> int:
    """Total bytes held by the cache pytree (the branch-scaling part of
    peak memory — our static-shape analogue of the paper's M_peak)."""
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(cache))


def used_cache_bytes(cfg, rows: int, pos: int, max_seq: int) -> int:
    """Paged-allocator view of cache memory: bytes actually *referenced*
    with ``rows`` live branch rows after ``pos`` positions.

    The paper's peak-memory numbers come from PyTorch's dynamically grown
    KV tensors; a TPU serving stack gets the same effect with a paged KV
    allocator (pages freed on branch prune / never allocated past pos).
    This analytic accounting is the static-shape analogue used for the
    M_cost metric."""
    it = jnp.dtype(cfg.dtype).itemsize
    if cfg.kv_cache_dtype == "int8":
        it_kv = 1.0 + 4.0 / cfg.resolved_head_dim  # int8 + amortized scale
    else:
        it_kv = it
    hd = cfg.resolved_head_dim
    total = 0
    for bt in cfg.block_types():
        if bt == "global":
            total += rows * min(pos, max_seq) * cfg.num_kv_heads * hd * 2 * it_kv
        elif bt == "local":
            w = min(cfg.window_size, max_seq)
            total += rows * min(pos, w) * cfg.num_kv_heads * hd * 2 * it_kv
        elif bt == "recurrent":
            total += rows * (cfg.d_model * 4 + cfg.d_model * 3 * it)  # h fp32 + conv
        elif bt == "rwkv6":
            total += rows * (cfg.num_heads * hd * hd * 4 + 2 * cfg.d_model * it)
    if cfg.is_encoder_decoder:
        total += cfg.num_layers * rows * cfg.encoder_seq_len \
            * cfg.num_kv_heads * hd * 2 * it
    return int(total)


def per_request_bytes(cfg, rows_pos: Dict[Any, tuple], max_seq: int
                      ) -> Dict[Any, int]:
    """Per-request paged-view byte accounting over a shared row pool:
    ``rows_pos`` maps request id -> (occupied rows, current pos). Each
    request is charged only for the slots it owns, referenced up to its
    own position — the scheduler's analogue of the single-request
    ``used_cache_bytes`` accounting."""
    return {rid: used_cache_bytes(cfg, r, p, max_seq)
            for rid, (r, p) in rows_pos.items()}


# ----------------------------------------------------------- paged pool
#
# DESIGN.md §5: the paged scheduler replaces the contiguous (rows,
# max_seq) reservation with fixed-size pages handed out from a free
# list. Freeing a pruned branch returns its pages immediately — no
# gather/compaction on the scheduler path — and admission is counted in
# pages, so rows of different lengths share the pool.


class PageAllocator:
    """Host-side page bookkeeping for the shared device page pool.

    ``num_pages`` allocatable physical pages of ``page_size`` token slots
    each; physical index ``num_pages`` is the shared *trash* page (the
    device pool is allocated with one extra page). Block tables are
    (rows, max_pages) int32 in *device form*: owned logical pages map to
    real physical pages, everything else aliases the trash page, so
    attention validity stays purely positional (kv_pos <= pos)."""

    def __init__(self, num_pages: int, page_size: int, rows: int,
                 max_pages: int):
        if num_pages < 1:
            raise ValueError("need at least one allocatable page")
        self.num_pages = num_pages
        self.page_size = page_size
        self.trash = num_pages
        self.rows = rows
        self.max_pages = max_pages
        self.free_pages: List[int] = list(range(num_pages))
        self.block = np.full((rows, max_pages), self.trash, np.int32)
        self.owned = np.zeros((rows,), np.int32)

    # ------------------------------------------------------------ queries

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` positions of one row."""
        return -(-int(n_tokens) // self.page_size)

    @property
    def free_count(self) -> int:
        return len(self.free_pages)

    @property
    def used_count(self) -> int:
        return self.num_pages - len(self.free_pages)

    def can_alloc(self, n_pages: int) -> bool:
        return len(self.free_pages) >= n_pages

    # ---------------------------------------------------------- lifecycle

    def alloc_row(self, row: int, n_pages: int) -> np.ndarray:
        """Hand ``n_pages`` pages to ``row``; returns the physical ids."""
        if self.owned[row]:
            raise ValueError(f"row {row} already owns {self.owned[row]} pages")
        if n_pages > self.max_pages:
            raise ValueError(f"{n_pages} pages > max_pages={self.max_pages}")
        if not self.can_alloc(n_pages):
            raise ValueError(f"out of pages: need {n_pages}, "
                             f"free {len(self.free_pages)}")
        pages = np.array(self.free_pages[:n_pages], np.int32)
        del self.free_pages[:n_pages]
        self.block[row, :n_pages] = pages
        self.block[row, n_pages:] = self.trash
        self.owned[row] = n_pages
        return pages

    def free_row(self, row: int) -> None:
        """Return every page ``row`` owns to the free list."""
        n = int(self.owned[row])
        if n:
            self.free_pages.extend(int(p) for p in self.block[row, :n])
            self.free_pages.sort()
        self.block[row] = self.trash
        self.owned[row] = 0


def _map_layer_entries(cfg, cache: Dict[str, Any], other: Dict[str, Any],
                       fn) -> Dict[str, Any]:
    """Map ``fn(block_type, is_stack, entry, other_entry)`` over per-layer
    cache entries (cross-attn K/V entries get block_type "xkv")."""
    pattern = cfg.layer_pattern
    P = len(pattern)
    out = {
        "stack": tuple(fn(pattern[j], True, e, o) for j, (e, o)
                       in enumerate(zip(cache["stack"], other["stack"]))),
        "rem": tuple(fn(pattern[j % P], False, e, o) for j, (e, o)
                     in enumerate(zip(cache["rem"], other["rem"]))),
    }
    if "xkv_stack" in cache:
        out["xkv_stack"] = tuple(fn("xkv", True, e, o) for e, o
                                 in zip(cache["xkv_stack"], other["xkv_stack"]))
        out["xkv_rem"] = tuple(fn("xkv", False, e, o) for e, o
                               in zip(cache["xkv_rem"], other["xkv_rem"]))
    return out


def install_paged(cfg, pool, row_idx, phys_flat, sub, page_size: int):
    """Install a freshly prefilled contiguous sub-cache into the paged
    pool — the paged analogue of :func:`scatter_batch`.

    ``row_idx``: (n,) pool row slots receiving the request's branches.
    ``phys_flat``: (n * max_pages,) physical page per (row, logical page),
    trash-aliased for unowned logical pages. Global-attention leaves
    scatter page-wise (the sub-cache's sequence axis is reshaped to
    (max_pages, page_size) and written through the page list; duplicate
    trash writes are garbage-on-garbage). Every per-row leaf family
    (ring, recurrent, rwkv6, cross-KV) scatters into the row slots."""
    def per_entry(bt, is_stack, entry, sub_entry):
        if bt == "global":
            def leaf(a, b):
                if is_stack:           # a: (K, P+1, ps, ...), b: (K, n, S, ...)
                    K, n, S = b.shape[0], b.shape[1], b.shape[2]
                    br = b.reshape((K, n * (S // page_size), page_size)
                                   + b.shape[3:])
                    return a.at[:, phys_flat].set(br.astype(a.dtype))
                n, S = b.shape[0], b.shape[1]
                br = b.reshape((n * (S // page_size), page_size) + b.shape[2:])
                return a.at[phys_flat].set(br.astype(a.dtype))
            return jax.tree.map(leaf, entry, sub_entry)
        def leaf_row(a, b):
            return a.at[:, row_idx].set(b) if is_stack else a.at[row_idx].set(b)
        return jax.tree.map(leaf_row, entry, sub_entry)

    return _map_layer_entries(cfg, pool, sub, per_entry)


def bucket_chain(n: int) -> List[int]:
    """Descending bucket sizes: n, then powers of two below n, down to 1."""
    out = [n]
    b = 1
    while b < n:
        b <<= 1
    b >>= 1
    while b >= 1:
        if b < n:
            out.append(b)
        b >>= 1
    return out


def next_bucket(chain: List[int], alive: int, current: int) -> int:
    """Smallest bucket in the chain that still fits ``alive`` branches and
    is smaller than ``current`` (or ``current`` if no shrink possible)."""
    best = current
    for b in chain:
        if b < best and b >= alive:
            best = b
    return best
