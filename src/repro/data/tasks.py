"""Synthetic multi-step arithmetic chain-of-thought task.

A problem is a left-associative chain  v0 op1 v1 op2 v2 … opK vK (mod 97).
The reference chain-of-thought emits every intermediate partial result:

  prompt:  BOS P v0 op1 v1 … opK vK = ?
  target:  ARROW r1 ARROW r2 … ARROW rK ANS rK EOS

Answer correctness = the value token after ANS matches the ground truth.
This gives a GSM8K-like shape: multi-step reasoning where sampled
branches genuinely diverge in quality, so BoN/ST-BoN/KAPPA comparisons
are meaningful at toy scale (DESIGN.md §11).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from repro.data import tokenizer as tok


@dataclass(frozen=True)
class Problem:
    prompt: List[int]
    target: List[int]     # CoT + answer + EOS
    answer: int


_OPS = [tok.PLUS, tok.MINUS, tok.TIMES]


def _apply(op: int, a: int, b: int) -> int:
    if op == tok.PLUS:
        return (a + b) % tok.MOD
    if op == tok.MINUS:
        return (a - b) % tok.MOD
    return (a * b) % tok.MOD


def make_problem(rng: np.random.Generator, min_steps: int = 2,
                 max_steps: int = 6, num_ops: int = 3,
                 max_val: int = tok.MOD, max_operand: int = 0) -> Problem:
    """num_ops: 2 → {+,−} only (easier); 3 adds × (mod-97 mult is the
    hard regime). max_val bounds the initial value; max_operand > 0
    bounds the chained operands (small per-step fact table → learnable
    by the toy models while errors still compound over steps)."""
    k = int(rng.integers(min_steps, max_steps + 1))
    v0 = int(rng.integers(0, max_val))
    op_hi = max_operand if max_operand > 0 else max_val
    vals = [v0] + rng.integers(0, op_hi, size=k).tolist()
    ops = [int(_OPS[i]) for i in rng.integers(0, num_ops, size=k)]

    prompt = [tok.BOS, tok.PROB, vals[0]]
    for op, v in zip(ops, vals[1:]):
        prompt += [op, v]
    prompt += [tok.EQ, tok.QM]

    target: List[int] = []
    acc = vals[0]
    for op, v in zip(ops, vals[1:]):
        acc = _apply(op, acc, v)
        target += [tok.ARROW, acc]
    target += [tok.ANS, acc, tok.EOS]
    return Problem(prompt=prompt, target=target, answer=acc)


def make_dataset(seed: int, n: int, **kw) -> List[Problem]:
    rng = np.random.default_rng(seed)
    return [make_problem(rng, **kw) for _ in range(n)]


def pack_batch(problems: List[Problem], max_len: int
               ) -> Tuple[np.ndarray, np.ndarray]:
    """(tokens, loss_mask): next-token LM batch; loss only on target span."""
    B = len(problems)
    toks = np.full((B, max_len), tok.PAD, np.int32)
    mask = np.zeros((B, max_len), np.float32)
    for i, p in enumerate(problems):
        seq = (p.prompt + p.target)[:max_len]
        toks[i, :len(seq)] = seq
        lo = min(len(p.prompt), max_len)
        hi = min(len(seq), max_len)
        # loss predicts positions lo..hi-1 (from inputs lo-1..hi-2)
        mask[i, lo - 1:hi - 1] = 1.0
    return toks, mask


def check_answer(generated: List[int], problem: Problem) -> bool:
    return tok.extract_answer(generated) == problem.answer
