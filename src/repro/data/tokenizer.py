"""Toy deterministic tokenizer for the synthetic arithmetic CoT task.

Vocabulary (size 128):
  0..96   : value tokens (integers mod 97)
  97..99  : operators + - *
  100..107: structural tokens  = ? → ANS BOS EOS PAD P
"""
from __future__ import annotations

from typing import List

MOD = 97

PLUS, MINUS, TIMES = 97, 98, 99
EQ, QM, ARROW, ANS = 100, 101, 102, 103
BOS, EOS, PAD, PROB = 104, 105, 106, 107
VOCAB_SIZE = 128

_OP_CHARS = {PLUS: "+", MINUS: "-", TIMES: "*"}
_SPECIAL = {EQ: "=", QM: "?", ARROW: "→", ANS: "ANS", BOS: "<s>",
            EOS: "</s>", PAD: "<pad>", PROB: "P"}


def decode(ids: List[int]) -> str:
    out = []
    for t in ids:
        if 0 <= t < MOD:
            out.append(str(t))
        elif t in _OP_CHARS:
            out.append(_OP_CHARS[t])
        elif t in _SPECIAL:
            out.append(_SPECIAL[t])
        else:
            out.append(f"<{t}>")
    return " ".join(out)


def extract_answer(ids: List[int]) -> int | None:
    """Final answer = value token right after the last ANS marker."""
    ans_pos = [i for i, t in enumerate(ids) if t == ANS]
    if not ans_pos:
        return None
    i = ans_pos[-1]
    if i + 1 < len(ids) and 0 <= ids[i + 1] < MOD:
        return int(ids[i + 1])
    return None
