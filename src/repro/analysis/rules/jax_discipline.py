"""R3 donation-safety, R4 interpret-default, R5 traced-branch hazard,
R8 jit-key hygiene.

These four rules police the repo's jit/Pallas conventions:

* donation (PR 2/PR 5): tick steps donate the KV pool so chunk k+1
  reuses chunk k's buffers — reading a donated operand after the call
  is use-after-free that XLA only sometimes warns about;
* ``interpret=None`` resolved via ``interpret_mode()`` (PR 2): kernel
  wrappers must never hard-default to the Pallas interpreter, or a real
  TPU silently runs interpreted;
* Python control flow on traced values fails at trace time (or worse,
  silently specializes) — branches must use static values or lax.cond;
* hashable-but-fresh static args (f-strings, dict/tuple literals built
  per call) make every tick a cache miss — the recompile-storm hazard.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.core import FileContext, Finding, Rule, register
from repro.analysis.rules.determinism import _dotted


@dataclasses.dataclass
class JitInfo:
    """One jitted callable discovered in a module."""

    name: str                        # local name it is bound to
    target: Optional[ast.FunctionDef]  # in-module def being wrapped
    static_nums: Tuple[int, ...] = ()
    static_names: Tuple[str, ...] = ()
    donate_nums: Tuple[int, ...] = ()
    node: Optional[ast.AST] = None   # where the wrapping happened


def _int_tuple(node: Optional[ast.AST]) -> Tuple[int, ...]:
    if node is None:
        return ()
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for el in node.elts:
            if isinstance(el, ast.Constant) and isinstance(el.value, int):
                out.append(el.value)
        return tuple(out)
    return ()


def _str_tuple(node: Optional[ast.AST]) -> Tuple[str, ...]:
    if node is None:
        return ()
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(el.value for el in node.elts
                     if isinstance(el, ast.Constant)
                     and isinstance(el.value, str))
    return ()


def _jit_call_kwargs(call: ast.Call) -> Optional[Dict[str, ast.AST]]:
    """If ``call`` is jax.jit(...) / partial(jax.jit, ...), return its
    keyword map (static_argnums / static_argnames / donate_argnums)."""
    dotted = _dotted(call.func)
    inner = None
    if dotted in ("jax.jit", "jit", "pjit", "jax.pjit"):
        inner = call
    elif dotted in ("functools.partial", "partial") and call.args:
        if _dotted(call.args[0]) in ("jax.jit", "jit", "pjit", "jax.pjit"):
            inner = call
    if inner is None:
        return None
    return {kw.arg: kw.value for kw in inner.keywords if kw.arg}


def collect_jitted(ctx: FileContext) -> List[JitInfo]:
    """Find module-level jitted callables: ``name = jax.jit(fn, ...)``
    assignments (fn resolved when defined in this module) and defs
    decorated with ``@jax.jit`` / ``@partial(jax.jit, ...)``."""
    defs: Dict[str, ast.FunctionDef] = {
        n.name: n for n in ast.walk(ctx.tree)
        if isinstance(n, ast.FunctionDef)}
    out: List[JitInfo] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            kwargs = _jit_call_kwargs(node.value)
            if kwargs is None:
                continue
            wrapped = node.value.args[0] if node.value.args else None
            target = None
            if isinstance(wrapped, ast.Name):
                target = defs.get(wrapped.id)
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out.append(JitInfo(
                        t.id, target,
                        _int_tuple(kwargs.get("static_argnums")),
                        _str_tuple(kwargs.get("static_argnames")),
                        _int_tuple(kwargs.get("donate_argnums")),
                        node))
        elif isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                kwargs = None
                if isinstance(dec, ast.Call):
                    kwargs = _jit_call_kwargs(dec)
                elif _dotted(dec) in ("jax.jit", "jit"):
                    kwargs = {}
                if kwargs is not None:
                    out.append(JitInfo(
                        node.name, node,
                        _int_tuple(kwargs.get("static_argnums")),
                        _str_tuple(kwargs.get("static_argnames")),
                        _int_tuple(kwargs.get("donate_argnums")),
                        node))
                    break
    return out


def _name_events(fn: ast.AST) -> List[Tuple[int, int, str, str]]:
    """(line, col, kind, name) for every Name load/store in ``fn``,
    in source order."""
    events = []
    for n in ast.walk(fn):
        if isinstance(n, ast.Name):
            kind = "store" if isinstance(n.ctx, (ast.Store, ast.Del)) \
                else "load"
            events.append((n.lineno, n.col_offset, kind, n.id))
    events.sort()
    return events


@register
class DonationSafety(Rule):
    """R3: a name passed at a donated position must not be read again
    after the call (unless rebound by the call's own assignment)."""

    id = "donation-safety"
    severity = "error"
    contract = ("operands at donate_argnums positions are dead after "
                "the call — the tick reuses their buffers (PR 2/PR 5)")
    rationale = (
        "The fused tick donates the KV pool / aux state so each step "
        "aliases the previous step's buffers instead of allocating "
        "(-37% peak memory on the model-step cache alone). XLA is free "
        "to overwrite a donated buffer the moment the call is issued; "
        "reading the old Python name afterwards returns garbage (on "
        "TPU) or silently correct values (CPU interpreter), which is "
        "exactly the class of bug that passes every CPU test and "
        "corrupts production decodes. The rule tracks module-level "
        "`name = jax.jit(fn, donate_argnums=...)` wrappers and flags "
        "call sites where a donated bare-name operand is loaded again "
        "later in the same function without an intervening rebind.")
    example = ("step = jax.jit(f, donate_argnums=(0,))\n"
               "def tick(cache, tok):\n"
               "    logits, new_cache = step(cache, tok)\n"
               "    return logits, cache   # R3: cache was donated\n")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        donating = {j.name: j for j in collect_jitted(ctx) if j.donate_nums}
        if not donating:
            return
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            events = None
            for call in ast.walk(fn):
                if not (isinstance(call, ast.Call)
                        and isinstance(call.func, ast.Name)
                        and call.func.id in donating):
                    continue
                info = donating[call.func.id]
                # names rebound by the assignment consuming this call
                # (x = f(x) / a, x = f(x)) are live again immediately
                rebound = self._assign_targets(ctx, call)
                for pos in info.donate_nums:
                    if pos >= len(call.args):
                        continue
                    arg = call.args[pos]
                    if not isinstance(arg, ast.Name) or arg.id in rebound:
                        continue
                    if events is None:
                        events = _name_events(fn)
                    hit = self._read_after(events, arg.id, call)
                    if hit is not None:
                        yield Finding(
                            rule=self.id, path=ctx.relpath,
                            line=hit[0], col=hit[1],
                            message=(
                                f"`{arg.id}` is read after being donated "
                                f"to `{call.func.id}` (donate_argnums "
                                f"position {pos}, call at line "
                                f"{call.lineno}) — its buffer may "
                                "already be reused"),
                            severity=self.severity,
                            code=ctx.line_text(hit[0]))

    @staticmethod
    def _assign_targets(ctx: FileContext, call: ast.Call) -> Set[str]:
        names: Set[str] = set()
        for anc in ctx.ancestors(call):
            if isinstance(anc, ast.Assign):
                for t in anc.targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            names.add(n.id)
            elif isinstance(anc, (ast.AugAssign, ast.AnnAssign)):
                for n in ast.walk(anc.target):
                    if isinstance(n, ast.Name):
                        names.add(n.id)
            elif isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                break
        return names

    @staticmethod
    def _read_after(events, name: str,
                    call: ast.Call) -> Optional[Tuple[int, int]]:
        """First load of ``name`` strictly after the call with no store
        in between (lexical order — loop back-edges are out of scope)."""
        call_end = (call.end_lineno or call.lineno,
                    call.end_col_offset or call.col_offset)
        for line, col, kind, nm in events:
            if nm != name or (line, col) <= call_end:
                continue
            if kind == "store":
                return None
            return (line, col)
        return None


@register
class InterpretDefault(Rule):
    """R4: kernel wrappers declare ``interpret=None`` and resolve it via
    ``interpret_mode()``; no hard-coded interpret constants at call
    sites."""

    id = "interpret-default"
    severity = "error"
    contract = ("Pallas wrapper entry points take interpret=None and "
                "resolve via repro.kernels.interpret_mode() (PR 2)")
    rationale = (
        "interpret=True runs the Pallas *interpreter* — orders of "
        "magnitude slower and numerically laxer than the compiled "
        "kernel. The PR 2 convention: public kernel entry points "
        "default interpret=None and resolve it with interpret_mode() "
        "(compiled on a real TPU backend, interpreter elsewhere), so "
        "callers bypassing ops.py can never silently interpret on "
        "hardware. A def with interpret=True/False, an interpret=None "
        "def that never consults interpret_mode(), or a hard-coded "
        "interpret=True/False at a call site all reintroduce the "
        "pre-PR 2 failure mode.")
    example = ("def my_kernel(x, interpret=True):   # R4: not None\n"
               "    return pl.pallas_call(body, ..., interpret=interpret)"
               "(x)\n")

    def applies(self, ctx: FileContext) -> bool:
        # defs are checked in kernels/; hard-coded call-site constants
        # are a hazard everywhere outside tests
        return "tests" not in ctx.parts

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        in_kernels = ctx.in_path("kernels")
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.FunctionDef) and in_kernels \
                    and not node.name.startswith("_"):
                yield from self._check_def(ctx, node)
            elif isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg == "interpret" \
                            and isinstance(kw.value, ast.Constant) \
                            and isinstance(kw.value.value, bool):
                        yield self.finding(
                            ctx, node,
                            f"hard-coded `interpret={kw.value.value}` at "
                            "a call site — pass nothing (wrapper "
                            "resolves via interpret_mode()) or thread a "
                            "caller-provided value")

    def _check_def(self, ctx: FileContext,
                   node: ast.FunctionDef) -> Iterable[Finding]:
        args = node.args
        all_args = args.posonlyargs + args.args + args.kwonlyargs
        if not any(a.arg == "interpret" for a in all_args):
            return
        defaults = dict(
            zip([a.arg for a in args.posonlyargs + args.args]
                [-len(args.defaults):] if args.defaults else [],
                args.defaults))
        defaults.update({a.arg: d for a, d in
                         zip(args.kwonlyargs, args.kw_defaults)
                         if d is not None})
        dflt = defaults.get("interpret")
        if dflt is None:
            # no default: callers must always decide — allowed only for
            # private jit helpers, which the name filter already skips
            yield self.finding(
                ctx, node,
                f"public kernel entry `{node.name}` takes `interpret` "
                "without a default — declare interpret=None and resolve "
                "via interpret_mode()")
            return
        if not (isinstance(dflt, ast.Constant) and dflt.value is None):
            yield self.finding(
                ctx, node,
                f"`{node.name}` defaults interpret="
                f"{getattr(dflt, 'value', '<expr>')} — must default to "
                "None and resolve via interpret_mode() (PR 2 convention)")
            return
        uses_mode = any(isinstance(n, (ast.Name, ast.Attribute))
                        and (getattr(n, "id", None) == "interpret_mode"
                             or getattr(n, "attr", None) == "interpret_mode")
                        for n in ast.walk(node))
        if not uses_mode:
            yield self.finding(
                ctx, node,
                f"`{node.name}` declares interpret=None but never "
                "resolves it via interpret_mode() — None would reach "
                "pl.pallas_call unresolved")


_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size"}


@register
class TracedBranch(Rule):
    """R5: Python if/while/assert on values derived from traced
    arguments inside jitted function bodies."""

    id = "traced-branch"
    severity = "error"
    contract = ("jitted bodies branch only on static values; traced "
                "values use lax.cond/where (jax tracing semantics)")
    rationale = (
        "Inside jax.jit, Python `if`/`while`/`assert` on a traced value "
        "raises TracerBoolConversionError at best; at worst (when the "
        "value is concrete during tracing, e.g. under the Pallas "
        "interpreter on CPU) it silently bakes one branch into the "
        "compiled program — a bug CPU tests cannot see. Branching on "
        "`.shape`/`.ndim`/`.dtype`, `len(...)`, `isinstance(...)`, or "
        "`is None` is static at trace time and exempt; static_argnums/"
        "static_argnames parameters are exempt by name.")
    example = ("@jax.jit\n"
               "def step(state, x):\n"
               "    if x > 0:        # R5: traced value in Python branch\n"
               "        return state + x\n"
               "    return state\n")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for info in collect_jitted(ctx):
            fn = info.target
            if fn is None:
                continue
            params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
            static = set(info.static_names)
            static.update(params[i] for i in info.static_nums
                          if i < len(params))
            traced = {p for p in params if p not in static}
            traced.update(a.arg for a in fn.args.kwonlyargs
                          if a.arg not in static)
            traced.discard("self")
            if not traced:
                continue
            tainted = self._propagate(fn, traced)
            for stmt in ast.walk(fn):
                test = None
                if isinstance(stmt, (ast.If, ast.While)):
                    test = stmt.test
                elif isinstance(stmt, ast.Assert):
                    test = stmt.test
                if test is None:
                    continue
                name = self._tainted_use(test, tainted)
                if name:
                    kind = type(stmt).__name__.lower()
                    yield self.finding(
                        ctx, stmt,
                        f"Python `{kind}` on `{name}`, derived from a "
                        f"traced argument of jitted `{fn.name}` — use "
                        "lax.cond/jnp.where or make it static")

    @staticmethod
    def _propagate(fn: ast.AST, traced: Set[str]) -> Set[str]:
        """Names assigned from expressions mentioning tainted names
        (two passes are enough for straight-line derivations)."""
        tainted = set(traced)
        for _ in range(2):
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign):
                    src_names = {n.id for n in ast.walk(node.value)
                                 if isinstance(n, ast.Name)}
                    if src_names & tainted \
                            and not TracedBranch._is_exempt_expr(node.value):
                        for t in node.targets:
                            for n in ast.walk(t):
                                if isinstance(n, ast.Name):
                                    tainted.add(n.id)
        return tainted

    @staticmethod
    def _is_exempt_expr(node: ast.AST) -> bool:
        """Whole-expression exemption: pure shape/dtype/len derivations
        stay static at trace time."""
        names = [n for n in ast.walk(node) if isinstance(n, ast.Name)]
        if not names:
            return True
        exempt_spans = TracedBranch._exempt_name_spans(node)
        return all(id(n) in exempt_spans for n in names)

    @staticmethod
    def _exempt_name_spans(root: ast.AST) -> Set[int]:
        """ids of Name nodes appearing only inside static accessors:
        x.shape / x.ndim / x.dtype / x.size, len(x), isinstance(x, T),
        `x is None` comparisons."""
        exempt: Set[int] = set()
        for node in ast.walk(root):
            inner = None
            if isinstance(node, ast.Attribute) \
                    and node.attr in _SHAPE_ATTRS:
                inner = node.value
            elif isinstance(node, ast.Call):
                d = _dotted(node.func)
                if d in ("len", "isinstance", "getattr", "hasattr",
                         "type"):
                    inner = node
            elif isinstance(node, ast.Compare) and all(
                    isinstance(op, (ast.Is, ast.IsNot))
                    for op in node.ops):
                inner = node
            if inner is not None:
                for n in ast.walk(inner):
                    if isinstance(n, ast.Name):
                        exempt.add(id(n))
        return exempt

    def _tainted_use(self, test: ast.AST, tainted: Set[str]) -> str:
        exempt = self._exempt_name_spans(test)
        for n in ast.walk(test):
            if isinstance(n, ast.Name) and n.id in tainted \
                    and id(n) not in exempt:
                return n.id
        return ""


@register
class JitKeyHygiene(Rule):
    """R8: per-call-fresh literals (f-strings, dict/list literals,
    non-constant tuples, comprehensions) flowing into jit static args
    in the tick path — every call becomes a cache miss."""

    id = "jit-key-hygiene"
    severity = "error"
    contract = ("static args of tick-path jitted callables are stable "
                "Python values, never per-call-built literals "
                "(recompile-storm hazard)")
    rationale = (
        "A jit cache key includes every static argument by equality. "
        "Passing an f-string, a dict/list, or a tuple rebuilt from "
        "per-request Python values at a tick-path call site makes the "
        "key unique (or unhashable) per call: the scheduler then "
        "retraces EVERY tick, which reads as a 100x throughput collapse "
        "rather than an error. The fused-tick keys are deliberately "
        "coarse (cfg object, chunk-extent multiset); new static args "
        "must be equally stable.")
    example = ("step = jax.jit(f, static_argnums=(1,))\n"
               "def tick(self, x):\n"
               "    # R8: fresh string per tick -> retrace per tick\n"
               "    return step(x, f\"rows={len(self.active)}\")\n")

    FRESH = (ast.JoinedStr, ast.Dict, ast.List, ast.Set, ast.DictComp,
             ast.ListComp, ast.SetComp, ast.GeneratorExp)

    def applies(self, ctx: FileContext) -> bool:
        return (ctx.name in ("engine.py", "scheduler.py", "strategies.py",
                             "sampler.py") and ctx.in_path("serving")) \
            or (ctx.name == "kappa.py" and ctx.in_path("core"))

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        jitted = {j.name: j for j in collect_jitted(ctx)
                  if j.static_nums or j.static_names}
        if not jitted:
            return
        for call in ast.walk(ctx.tree):
            if not (isinstance(call, ast.Call)
                    and isinstance(call.func, ast.Name)
                    and call.func.id in jitted):
                continue
            info = jitted[call.func.id]
            spots = [(f"position {i}", call.args[i])
                     for i in info.static_nums if i < len(call.args)]
            spots += [(f"name `{kw.arg}`", kw.value)
                      for kw in call.keywords
                      if kw.arg in info.static_names]
            for where, arg in spots:
                why = self._fresh(arg)
                if why:
                    yield self.finding(
                        ctx, arg,
                        f"static arg ({where}) of jitted "
                        f"`{call.func.id}` is {why} — a fresh jit key "
                        "every call (recompile storm); hoist a stable "
                        "value instead")

    def _fresh(self, node: ast.AST) -> str:
        if isinstance(node, ast.JoinedStr):
            return "an f-string built per call"
        if isinstance(node, (ast.Dict, ast.DictComp)):
            return "a dict literal (unhashable as a jit key)"
        if isinstance(node, (ast.List, ast.ListComp, ast.Set,
                             ast.SetComp, ast.GeneratorExp)):
            return "an unhashable/per-call literal"
        if isinstance(node, ast.Tuple) and any(
                not isinstance(el, ast.Constant) for el in node.elts):
            return "a tuple rebuilt from per-call values"
        if isinstance(node, ast.Call) and _dotted(node.func) in (
                "str", "repr", "format"):
            return "a string built per call"
        return ""
