"""Rule modules: importing this package registers every rule.

R1 replay-determinism, R2 sync-discipline  -> determinism.py
R3 donation-safety, R4 interpret-default,
R5 traced-branch,   R8 jit-key-hygiene     -> jax_discipline.py
R6 alloc-pairing,   R7 strategy-protocol   -> serving_contracts.py
"""
from repro.analysis.rules import determinism  # noqa: F401
from repro.analysis.rules import jax_discipline  # noqa: F401
from repro.analysis.rules import serving_contracts  # noqa: F401
