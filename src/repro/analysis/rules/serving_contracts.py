"""R6 allocator pairing and R7 strategy conformance.

* The page pool's refcount/pin machinery (PR 4/PR 6) is leak-checked at
  run teardown, but a leak on an *early-return path* only fires when a
  test happens to drive that path. R6 enumerates a function's
  control-flow paths and flags acquire/release pairs that balance on
  some paths and leak on others.
* The streaming scheduler (PR 8) emits only tokens the strategy has
  *committed* via ``decided_branch``; a new strategy that forgets to
  implement it (or ``step``) degrades silently — streams emit nothing
  until the terminal flush. R7 checks strategy subclasses implement the
  protocol.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.core import FileContext, Finding, Rule, register

# acquire-side call name -> release-side call name. Matched on the
# called attribute/function NAME (any receiver), inside one function.
PAIRS = (
    ("pin_page", "unpin_page"),      # radix prefix cache pins (PR 6)
    ("acquire", "release"),          # pooled-controller slots (PR 3)
    ("alloc_row", "free_row"),       # page-pool row block tables (PR 2)
    ("alloc_pages", "free_pages"),   # raw page grants
)

_MAX_PATHS = 64


def _call_name(node: ast.Call) -> str:
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    if isinstance(node.func, ast.Name):
        return node.func.id
    return ""


class _PathWalker:
    """Enumerate simplified control-flow paths of one function body and
    the (acquire - release) balance each pair accumulates along them.
    Loops run 0 or 1 times; ``try`` bodies and handlers are alternate
    paths with ``finally`` appended to all; explicit return/raise ends a
    path. Path count is capped — functions beyond the cap are skipped
    (soundness over noise)."""

    def __init__(self):
        self.overflow = False

    def paths(self, stmts: List[ast.stmt]) -> List[Tuple[Tuple[int, ...],
                                                         bool]]:
        """Returns [(balances, terminated)] per path; ``balances`` is a
        per-pair net count."""
        live = [(tuple(0 for _ in PAIRS), False)]
        for stmt in stmts:
            nxt = []
            for bal, done in live:
                if done:
                    nxt.append((bal, done))
                    continue
                for b2, d2 in self._stmt(stmt):
                    nxt.append((self._add(bal, b2), d2))
            live = self._dedup(nxt)
            if self.overflow:
                return live
        return live

    @staticmethod
    def _add(a, b):
        return tuple(x + y for x, y in zip(a, b))

    def _dedup(self, paths):
        out = list(dict.fromkeys(paths))
        if len(out) > _MAX_PATHS:
            self.overflow = True
            out = out[:_MAX_PATHS]
        return out

    def _events(self, node: ast.AST) -> Tuple[int, ...]:
        """Pair balance from every call in an expression/statement,
        skipping nested function bodies (they run when called, not
        here)."""
        bal = [0] * len(PAIRS)
        for n in self._walk_no_nested(node):
            if isinstance(n, ast.Call):
                name = _call_name(n)
                for i, (acq, rel) in enumerate(PAIRS):
                    if name == acq:
                        bal[i] += 1
                    elif name == rel:
                        bal[i] -= 1
        return tuple(bal)

    @staticmethod
    def _walk_no_nested(node: ast.AST):
        stack = [node]
        while stack:
            n = stack.pop()
            yield n
            for c in ast.iter_child_nodes(n):
                if isinstance(c, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                    continue
                stack.append(c)

    def _stmt(self, stmt: ast.stmt) -> List[Tuple[Tuple[int, ...], bool]]:
        if isinstance(stmt, (ast.Return, ast.Raise)):
            return [(self._events(stmt), True)]
        if isinstance(stmt, (ast.Break, ast.Continue)):
            return [(tuple(0 for _ in PAIRS), True)]
        if isinstance(stmt, ast.If):
            test = self._events(stmt.test)
            out = []
            for branch in (stmt.body, stmt.orelse):
                for bal, done in self.paths(branch) if branch \
                        else [(tuple(0 for _ in PAIRS), False)]:
                    out.append((self._add(test, bal), done))
            return self._dedup(out)
        if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
            head = self._events(stmt.iter if isinstance(
                stmt, (ast.For, ast.AsyncFor)) else stmt.test)
            out = [(head, False)]                      # zero iterations
            for bal, done in self.paths(stmt.body):    # one iteration
                out.append((self._add(head, bal), done))
            for i in range(len(out)):                  # loop else-clause
                bal, done = out[i]
                if not done and stmt.orelse:
                    for bal2, done2 in self.paths(stmt.orelse):
                        out.append((self._add(bal, bal2), done2))
            return self._dedup(out)
        if isinstance(stmt, ast.Try):
            out = []
            alternates = [stmt.body] + [h.body for h in stmt.handlers]
            for block in alternates:
                for bal, done in self.paths(block):
                    out.append((bal, done))
            if stmt.orelse:
                grown = []
                for bal, done in out:
                    if done:
                        grown.append((bal, done))
                    else:
                        for bal2, done2 in self.paths(stmt.orelse):
                            grown.append((self._add(bal, bal2), done2))
                out = grown
            if stmt.finalbody:
                grown = []
                for bal, done in out:
                    for bal2, done2 in self.paths(stmt.finalbody):
                        grown.append((self._add(bal, bal2),
                                      done or done2))
                out = grown
            return self._dedup(out)
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            head = tuple(sum(x) for x in zip(
                *[self._events(item) for item in stmt.items])) \
                if stmt.items else tuple(0 for _ in PAIRS)
            return self._dedup([(self._add(head, bal), done)
                                for bal, done in self.paths(stmt.body)])
        return [(self._events(stmt), False)]


@register
class AllocPairing(Rule):
    """R6: acquire/release pairs that balance on some control-flow paths
    of a function but leak on others."""

    id = "alloc-pairing"
    severity = "error"
    contract = ("pin_page/unpin_page, acquire/release, alloc/free calls "
                "pair on every control-flow path of a function that "
                "uses both sides (PR 4/PR 6 refcount invariants)")
    rationale = (
        "The allocator's invariant — ref == table refs + pins, zero "
        "leaks at quiescence — is asserted at run teardown, so a leak "
        "on an early-return or exception path surfaces only when a test "
        "drives that exact path under pressure. If a function both "
        "acquires and releases a resource, every path through it should "
        "balance; a path that returns between the acquire and the "
        "release (without try/finally) leaks pages that preemption can "
        "never reclaim. Functions that only acquire (ownership handed "
        "to a structure, e.g. radix pins) or only release (teardown "
        "helpers) are exempt — pairing across functions is the "
        "allocator harness's job.")
    example = ("def grow(self, alloc, n):\n"
               "    pages = alloc.alloc_row(row, n)\n"
               "    if not self._fits(pages):\n"
               "        return None        # R6: leaks on this path\n"
               "    ...\n"
               "    alloc.free_row(row)\n")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            names = {_call_name(n) for n in ast.walk(fn)
                     if isinstance(n, ast.Call)}
            active = [i for i, (acq, rel) in enumerate(PAIRS)
                      if acq in names and rel in names]
            if not active:
                continue
            walker = _PathWalker()
            paths = walker.paths(fn.body)
            if walker.overflow:
                continue
            for i in active:
                bals = [bal[i] for bal, _ in paths]
                if any(b == 0 for b in bals) and any(b > 0 for b in bals):
                    acq, rel = PAIRS[i]
                    yield self.finding(
                        ctx, fn,
                        f"`{fn.name}` pairs {acq}/{rel} on some paths "
                        f"but leaks {max(bals)} acquisition(s) on "
                        "another (early return/raise between acquire "
                        "and release?) — balance every path or move the "
                        "release to a finally block")


@register
class StrategyProtocol(Rule):
    """R7: concrete DecodeStrategy subclasses implement the full
    protocol, including the PR 8 streaming contract ``decided_branch``."""

    id = "strategy-protocol"
    severity = "error"
    contract = ("DecodeStrategy subclasses implement step() and "
                "decided_branch() (streaming commit contract, "
                "DESIGN.md §9)")
    rationale = (
        "The scheduler streams a request's tokens only from the branch "
        "its strategy has COMMITTED via decided_branch() — that is what "
        "keeps every streamed prefix a prefix of the final result. The "
        "base class defaults are deliberately conservative: step() "
        "raises, decided_branch() returns None (nothing streams until "
        "the terminal flush). A new strategy that forgets either "
        "doesn't fail any batch test — it just silently never streams, "
        "or dies on first pool use. Subclasses of a concrete in-module "
        "strategy inherit its implementations and are exempt.")
    example = ("class MyStrategy(DecodeStrategy):\n"
               "    name = 'mine'\n"
               "    # R7: neither step() nor decided_branch() defined\n"
               "    def choose(self, branch_ids, done):\n"
               "        return int(branch_ids[0])\n")

    BASE = "DecodeStrategy"
    REQUIRED = ("step", "decided_branch")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        classes: Dict[str, ast.ClassDef] = {
            n.name: n for n in ast.walk(ctx.tree)
            if isinstance(n, ast.ClassDef)}

        def base_names(cls: ast.ClassDef) -> List[str]:
            out = []
            for b in cls.bases:
                if isinstance(b, ast.Name):
                    out.append(b.id)
                elif isinstance(b, ast.Attribute):
                    out.append(b.attr)
            return out

        def chain(cls: ast.ClassDef, seen: Set[str]) -> List[ast.ClassDef]:
            """In-module ancestor chain, excluding the protocol base."""
            out = [cls]
            for b in base_names(cls):
                if b == self.BASE or b in seen or b not in classes:
                    continue
                seen.add(b)
                out.extend(chain(classes[b], seen))
            return out

        for cls in classes.values():
            if cls.name == self.BASE or cls.name.startswith("_"):
                continue
            bases = base_names(cls)
            mro = chain(cls, {cls.name})
            is_strategy = self.BASE in bases or any(
                self.BASE in base_names(c) for c in mro[1:])
            if not is_strategy:
                continue
            # abstract intermediates (no `name` attribute anywhere in
            # the chain) aren't registered; concrete ones must conform
            defined: Set[str] = set()
            has_name = False
            for c in mro:
                for stmt in c.body:
                    if isinstance(stmt, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                        defined.add(stmt.name)
                    elif isinstance(stmt, ast.Assign):
                        for t in stmt.targets:
                            if isinstance(t, ast.Name):
                                defined.add(t.id)
                                has_name |= t.id == "name"
            if not has_name:
                continue
            missing = [m for m in self.REQUIRED if m not in defined]
            if missing:
                yield self.finding(
                    ctx, cls,
                    f"strategy `{cls.name}` does not implement "
                    f"{', '.join(missing)} — the scheduler needs "
                    "step() for decode and decided_branch() for the "
                    "streaming commit contract (DESIGN.md §9)")
