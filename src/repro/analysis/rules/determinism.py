"""R1 replay-determinism and R2 sync-discipline.

Both rules mechanize serving contracts that used to live only in prose:

* DESIGN.md §8 — a preempted / faulted / cancelled-and-retried request
  replays **token-for-token from its original submission RNG**, and all
  request-visible latency flows through the injectable ``clock=``
  (PR 7/PR 8). A stray wall-clock read or ambient-RNG draw in the
  serving/core layers silently breaks that equivalence.
* DESIGN.md §4 — the fused tick performs **at most one blocking
  controller-carrying transfer per tick** (PR 3), with the sampler-key
  fetch as the only other sanctioned transfer. Any new ``.item()`` /
  ``device_get`` / host-coercion in a tick-path module is either a
  regression or a new sanctioned site that must be added to the
  explicit allowlist below (and to the dynamic counter twin in
  tests/conftest.py).
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.core import FileContext, Finding, Rule, register


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of an expression ('np.random.rand',
    'time.monotonic', '' when not a plain name chain)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


_WALLCLOCK = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.sleep",
}
_DATETIME_ATTRS = {"now", "utcnow", "today"}
# module-level stdlib `random` draws share one ambient global state
_RANDOM_FNS = {
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "normalvariate", "betavariate",
    "getrandbits", "randbytes", "triangular", "expovariate",
}
# numpy legacy global-RNG draws (np.random.<fn>)
_NP_GLOBAL_FNS = {
    "rand", "randn", "randint", "random", "random_sample", "choice",
    "shuffle", "permutation", "uniform", "normal", "standard_normal",
    "beta", "binomial", "poisson", "exponential", "bytes",
}
_MISC_ENTROPY = {"uuid.uuid4", "os.urandom", "secrets.token_bytes",
                 "secrets.token_hex", "secrets.randbelow"}


@register
class ReplayDeterminism(Rule):
    """R1: no ambient wall-clock or un-seeded RNG in replay-critical
    modules (``serving/``, ``core/``, ``launch/serve.py``)."""

    id = "replay-determinism"
    severity = "error"
    contract = ("serving/ + core/ + launch/serve.py replay token-for-token "
                "from the submission RNG; wall-clock goes through the "
                "injectable clock= (DESIGN.md §8)")
    rationale = (
        "Preemption, fault retry, and cancellation all REPLAY a request "
        "from its original submission RNG and assert token-for-token "
        "equality; SLO/latency logic reads time only through the "
        "scheduler's injectable clock= so tests can advance a FakeClock. "
        "A time.time()/datetime.now() call or an un-seeded random/"
        "np.random draw in these modules produces values that differ "
        "between the first run and the replay (or between test and "
        "production), breaking replay equivalence with no test failing. "
        "Referencing time.monotonic as the clock= DEFAULT is fine — only "
        "direct calls are flagged. Seeded generators "
        "(np.random.default_rng(seed), jax.random with explicit keys) "
        "are exempt by construction.")
    example = ("def _watchdog(self):\n"
               "    now = time.monotonic()   # R1: bypasses self.clock\n"
               "    ...\n"
               "    jitter = np.random.random()   # R1: ambient RNG\n")

    def applies(self, ctx: FileContext) -> bool:
        return (ctx.in_path("serving") or ctx.in_path("core")
                or ctx.name == "serve.py")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func)
            if dotted in _WALLCLOCK:
                yield self.finding(
                    ctx, node,
                    f"wall-clock call `{dotted}()` outside the injectable "
                    "clock= — route request-visible time through the "
                    "scheduler clock (replay/FakeClock contract)")
            elif dotted in _MISC_ENTROPY:
                yield self.finding(
                    ctx, node,
                    f"`{dotted}()` draws ambient entropy — replay from "
                    "the submission RNG cannot reproduce it")
            elif (dotted.split(".")[-1] in _DATETIME_ATTRS
                  and "datetime" in dotted.split(".")[:-1]
                  or dotted in ("date.today",)):
                yield self.finding(
                    ctx, node,
                    f"`{dotted}()` reads the wall clock — route through "
                    "the injectable clock= or stamp outside serving/core")
            elif (dotted.startswith("random.")
                  and dotted.split(".", 1)[1] in _RANDOM_FNS):
                yield self.finding(
                    ctx, node,
                    f"`{dotted}()` uses the ambient global random state — "
                    "derive from the request's submission RNG instead")
            elif dotted == "random.Random" and not node.args:
                yield self.finding(
                    ctx, node,
                    "`random.Random()` without a seed is entropy-seeded — "
                    "pass an explicit seed derived from the submission RNG")
            elif (dotted.startswith(("np.random.", "numpy.random."))
                  and dotted.split(".")[-1] in _NP_GLOBAL_FNS):
                yield self.finding(
                    ctx, node,
                    f"`{dotted}()` draws from numpy's global RNG — use a "
                    "seeded np.random.default_rng(...) (see "
                    "serving/faults.py for the convention)")
            elif (dotted.split(".")[-1] in ("default_rng", "RandomState")
                  and ".random" in dotted.rsplit(".", 1)[0] + "."
                  and not node.args and not node.keywords):
                yield self.finding(
                    ctx, node,
                    f"`{dotted}()` with no seed is entropy-seeded — pass "
                    "an explicit seed (FaultPlan seeds "
                    "default_rng([seed, site, tick]))")


# The sanctioned blocking-transfer sites: (filename, enclosing function).
# Everything here was audited in the ISSUE 9 sync sweep; the dynamic twin
# (tests/conftest.py `_sync_budget_guard`) asserts the runtime counters
# these sites increment stay within the ≤1-controller-sync-per-tick
# budget, so this list and runtime truth cannot drift apart silently.
ALLOWED_SYNC_SITES = {
    # the fused tick's two sanctioned transfers: the per-row sampler-key
    # fetch and THE blocking transfer carrying tokens + picked log-probs
    # + pooled controller outputs + the finite mask (DESIGN.md §4)
    ("scheduler.py", "tick"),
    # engine-loop twin of the tick sync: the single-request path reads
    # its own sampled tokens back each step by design
    ("strategies.py", "sample_and_advance"),
}


@register
class SyncDiscipline(Rule):
    """R2: host-sync constructs in tick-path modules only at allowlisted
    sites (or baselined with a reason)."""

    id = "sync-discipline"
    severity = "error"
    contract = ("tick-path modules (engine.py, scheduler.py, "
                "strategies.py, core/kappa.py) make ≤1 controller-"
                "carrying blocking transfer per tick (DESIGN.md §4)")
    rationale = (
        "PR 3 collapsed the per-request controller host reads into ONE "
        "pooled dispatch whose outputs ride the tick's single blocking "
        "device_get; the tick's only other transfer is the sampler-key "
        "fetch. Every `.item()`, `jax.device_get`, `block_until_ready`, "
        "`np.asarray`, or float()/int() coercion of a jax value in a "
        "tick-path module is a potential hidden round-trip that "
        "serializes host and device again. New sites must be allowlisted "
        "in rules/determinism.py:ALLOWED_SYNC_SITES (true per-tick "
        "transfers, mirrored by the conftest counter twin) or baselined "
        "with a reason (host-side numpy on host data). np.asarray on "
        "genuinely-host data is flagged too — statically "
        "indistinguishable, and the audit trail is the point.")
    example = ("def step(self, logits, ...):\n"
               "    # R2: per-request blocking read inside the tick\n"
               "    alive = np.asarray(self.state.alive)\n"
               "    if float(jnp.sum(alive)) == 1.0:  # R2: host coercion\n"
               "        ...\n")

    TICK_MODULES = ("engine.py", "scheduler.py", "strategies.py", "kappa.py")

    def applies(self, ctx: FileContext) -> bool:
        return (ctx.name in ("engine.py", "scheduler.py", "strategies.py")
                and ctx.in_path("serving")) \
            or (ctx.name == "kappa.py" and ctx.in_path("core"))

    def _allowed(self, ctx: FileContext, node: ast.AST) -> bool:
        fn = ctx.enclosing_function(node)
        return fn is not None and (ctx.name, fn.name) in ALLOWED_SYNC_SITES

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            msg = self._classify(node)
            if msg and not self._allowed(ctx, node):
                yield self.finding(
                    ctx, node, msg + " — tick-path syncs are allowlisted "
                    "in ALLOWED_SYNC_SITES or baselined with a reason "
                    "(≤1-transfer-per-tick contract, DESIGN.md §4)")

    @staticmethod
    def _mentions_jax(node: ast.AST) -> bool:
        return any(isinstance(n, ast.Name) and n.id in ("jnp", "jax")
                   for n in ast.walk(node))

    def _classify(self, node: ast.Call) -> str:
        func = node.func
        dotted = _dotted(func)
        if isinstance(func, ast.Attribute) and func.attr == "item" \
                and not node.args:
            return "`.item()` is a blocking device->host transfer"
        if isinstance(func, ast.Attribute) \
                and func.attr == "block_until_ready":
            return "`.block_until_ready()` blocks on device completion"
        if dotted in ("jax.device_get", "jax.block_until_ready"):
            return f"`{dotted}(...)` is a blocking transfer"
        if dotted in ("np.asarray", "numpy.asarray"):
            return ("`np.asarray(...)` blocks when handed a device "
                    "array")
        if isinstance(func, ast.Name) and func.id in ("float", "int",
                                                      "bool") \
                and node.args and self._mentions_jax(node.args[0]):
            return (f"`{func.id}(...)` of a jax expression forces a "
                    "blocking scalar transfer")
        return ""
