"""repro-lint core: findings, the rule registry, suppressions, and the
per-file analysis driver.

The repo's reproducibility story rests on a handful of *hard contracts*
(token-for-token replay from the submission RNG, one blocking transfer
per scheduler tick, donation-safe call sites, ``interpret=None`` kernel
entry points, refcount/pin pairing, the streaming strategy protocol).
They live in prose (DESIGN.md) and are policed by whichever test happens
to exercise a violating path — this package checks them statically on
every file instead. Each contract is one :class:`Rule`; rules walk a
shared per-file :class:`FileContext` (source, AST, parent links,
enclosing-function map) and yield :class:`Finding`s.

Escape hatches, in order of preference:

* fix the violation;
* suppress one site inline with ``# repro-lint: disable=<rule>[,<rule>]``
  on the flagged line (or ``disable-next-line=`` on the line above) —
  the comment should say why;
* grandfather it in the checked-in baseline (:mod:`repro.analysis
  .baseline`) with a justifying ``reason``.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Type

SEVERITIES = ("error", "warning")

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*(disable(?:-next-line)?)\s*=\s*"
    r"([A-Za-z0-9_,\-\s]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str                  # rule id, e.g. "sync-discipline"
    path: str                  # repo-relative posix path
    line: int                  # 1-based
    col: int                   # 0-based
    message: str
    severity: str = "error"
    code: str = ""             # stripped source line (baseline fingerprint)

    def key(self):
        """Line-number-independent identity used for baseline matching:
        a baselined finding survives unrelated edits that shift it."""
        return (self.rule, self.path, self.code)


class FileContext:
    """Everything a rule needs about one file: source, AST, parent map,
    and the repo-relative path rules scope themselves on."""

    def __init__(self, relpath: str, source: str,
                 tree: Optional[ast.AST] = None):
        self.relpath = relpath.replace("\\", "/")
        self.parts = tuple(self.relpath.split("/"))
        self.name = self.parts[-1]
        self.source = source
        self.lines = source.splitlines()
        self.tree = tree if tree is not None else ast.parse(source)
        self._parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[child] = parent

    # ------------------------------------------------------------ helpers

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self._parents.get(node)

    def ancestors(self, node: ast.AST) -> Iterator[ast.AST]:
        cur = self._parents.get(node)
        while cur is not None:
            yield cur
            cur = self._parents.get(cur)

    def enclosing_function(self, node: ast.AST) -> Optional[ast.AST]:
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return anc
        return None

    def qualname(self, node: ast.AST) -> str:
        """Dotted class/function path of the scope containing ``node``
        (empty string at module level)."""
        names = [anc.name for anc in self.ancestors(node)
                 if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.ClassDef))]
        return ".".join(reversed(names))

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def in_path(self, *fragments: str) -> bool:
        """True if every fragment appears as a path component (or the
        final filename). Component-based so fixture trees in test tmp
        dirs scope exactly like the real repo layout."""
        return all(f in self.parts for f in fragments)


class Rule:
    """Base class: subclasses set the metadata and implement check()."""

    id: str = ""
    severity: str = "error"
    contract: str = ""         # one-line statement of the invariant
    rationale: str = ""        # --explain body: why the contract exists
    example: str = ""          # --explain body: minimal violating snippet

    def applies(self, ctx: FileContext) -> bool:
        return True

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        raise NotImplementedError

    # ------------------------------------------------------------ helpers

    def finding(self, ctx: FileContext, node: ast.AST,
                message: str) -> Finding:
        return Finding(rule=self.id, path=ctx.relpath,
                       line=node.lineno, col=node.col_offset,
                       message=message, severity=self.severity,
                       code=ctx.line_text(node.lineno))


_REGISTRY: Dict[str, Rule] = {}


def register(cls: Type[Rule]) -> Type[Rule]:
    """Class decorator adding a rule (by id) to the global registry."""
    assert cls.id, f"rule {cls.__name__} has no id"
    assert cls.severity in SEVERITIES, cls.severity
    assert cls.id not in _REGISTRY, f"duplicate rule id {cls.id}"
    _REGISTRY[cls.id] = cls()
    return cls


def all_rules() -> Dict[str, Rule]:
    # importing the rule modules populates the registry
    from repro.analysis import rules  # noqa: F401
    return dict(_REGISTRY)


def suppressed_lines(source: str) -> Dict[int, set]:
    """Map 1-based line number -> set of rule ids suppressed there via
    ``# repro-lint: disable=...`` (same line) or ``disable-next-line=``
    (the line above the flagged one)."""
    out: Dict[int, set] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(line)
        if not m:
            continue
        target = i + 1 if m.group(1) == "disable-next-line" else i
        ids = {p.strip() for p in m.group(2).split(",") if p.strip()}
        out.setdefault(target, set()).update(ids)
    return out


def analyze_source(source: str, relpath: str,
                   rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    """Run rules over one in-memory file. Parse failures come back as a
    single synthetic ``parse-error`` finding instead of raising, so one
    broken file can't hide the rest of a run's findings."""
    try:
        ctx = FileContext(relpath, source)
    except SyntaxError as e:
        return [Finding(rule="parse-error", path=relpath,
                        line=e.lineno or 1, col=e.offset or 0,
                        message=f"could not parse: {e.msg}",
                        code="")]
    if rules is None:
        rules = list(all_rules().values())
    suppressed = suppressed_lines(source)
    findings: List[Finding] = []
    for rule in rules:
        if not rule.applies(ctx):
            continue
        for f in rule.check(ctx):
            if f.rule in suppressed.get(f.line, ()):
                continue
            findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def iter_python_files(paths: Sequence[str], root: Path) -> Iterator[Path]:
    for p in paths:
        path = (root / p) if not Path(p).is_absolute() else Path(p)
        if path.is_file() and path.suffix == ".py":
            yield path
        elif path.is_dir():
            yield from sorted(path.rglob("*.py"))


def analyze_paths(paths: Sequence[str], root: Path,
                  rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    """Analyze every ``*.py`` under ``paths`` (resolved against
    ``root``); finding paths are reported relative to ``root``."""
    findings: List[Finding] = []
    for file in iter_python_files(paths, root):
        try:
            rel = file.resolve().relative_to(root.resolve()).as_posix()
        except ValueError:
            rel = file.as_posix()
        findings.extend(
            analyze_source(file.read_text(encoding="utf-8"), rel, rules))
    return findings
