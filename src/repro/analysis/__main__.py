"""``python -m repro.analysis`` entry point."""
import sys

from repro.analysis.cli import main

sys.exit(main())
