"""Checked-in baseline of grandfathered repro-lint findings.

The baseline is the audited list of *deliberate* contract exceptions
(e.g. the scheduler's sanctioned per-tick blocking transfer, the
``tick_time`` profiling reads). Each entry carries a ``reason`` so
review can judge the exception on its own text, and matches findings by
``(rule, path, stripped source line)`` — line-number independent, so
unrelated edits that shift code don't invalidate it, while *changing*
a baselined line surfaces it again for re-review. ``count`` caps how
many identical occurrences one entry covers (duplicating a baselined
sin on a new line is a new finding).
"""
from __future__ import annotations

import json
from collections import Counter
from pathlib import Path
from typing import Dict, List, Tuple

from repro.analysis.core import Finding

BASELINE_NAME = ".repro-lint-baseline.json"


def default_baseline_path() -> Path:
    """The checked-in baseline at the repo root (three levels above this
    package: src/repro/analysis -> repo)."""
    return Path(__file__).resolve().parents[3] / BASELINE_NAME


def load(path: Path) -> List[Dict]:
    if not path.exists():
        return []
    data = json.loads(path.read_text(encoding="utf-8"))
    entries = data["entries"] if isinstance(data, dict) else data
    for e in entries:
        e.setdefault("count", 1)
        e.setdefault("reason", "")
    return entries


def save(path: Path, entries: List[Dict]) -> None:
    entries = sorted(entries, key=lambda e: (e["path"], e["rule"],
                                             e.get("code", "")))
    payload = {
        "comment": ("grandfathered repro-lint findings; every entry "
                    "needs a justifying `reason` — see "
                    "src/repro/analysis/baseline.py"),
        "entries": entries,
    }
    path.write_text(json.dumps(payload, indent=1) + "\n", encoding="utf-8")


def from_findings(findings: List[Finding],
                  reason: str = "TODO: justify") -> List[Dict]:
    """Collapse findings into baseline entries (one per identity key,
    with a count). Used by ``--write-baseline``."""
    counts: Counter = Counter(f.key() for f in findings)
    return [{"rule": rule, "path": p, "code": code, "count": n,
             "reason": reason}
            for (rule, p, code), n in sorted(counts.items())]


def partition(findings: List[Finding], entries: List[Dict]
              ) -> Tuple[List[Finding], List[Finding], List[Dict]]:
    """Split findings into (new, baselined) and return the stale
    baseline entries that matched nothing (fixed violations whose
    entries should be deleted)."""
    budget: Counter = Counter()
    for e in entries:
        budget[(e["rule"], e["path"], e.get("code", ""))] += e["count"]
    used: Counter = Counter()
    new: List[Finding] = []
    old: List[Finding] = []
    for f in findings:
        if used[f.key()] < budget.get(f.key(), 0):
            used[f.key()] += 1
            old.append(f)
        else:
            new.append(f)
    stale = [e for e in entries
             if used.get((e["rule"], e["path"], e.get("code", "")), 0) == 0]
    return new, old, stale
