"""repro-lint CLI: ``python -m repro.analysis [options] paths...``.

Exit status: 0 when every finding is baselined (or none), 1 when any
new finding exists, 2 on usage errors. ``--format=github`` emits
workflow annotations so the CI lint job pins findings to PR lines.
"""
from __future__ import annotations

import argparse
import json
import sys
import textwrap
from pathlib import Path
from typing import List

from repro.analysis import baseline as baseline_lib
from repro.analysis.core import Finding, all_rules, analyze_paths


def _fmt_text(f: Finding, note: str = "") -> str:
    tag = f" [{note}]" if note else ""
    return (f"{f.path}:{f.line}:{f.col + 1}: {f.severity}: "
            f"{f.rule}: {f.message}{tag}")


def _fmt_github(f: Finding) -> str:
    level = "error" if f.severity == "error" else "warning"
    # '::' and newlines would terminate the annotation command early
    msg = f.message.replace("\n", " ").replace("::", ":")
    return (f"::{level} file={f.path},line={f.line},"
            f"col={f.col + 1},title=repro-lint {f.rule}::{msg}")


def _explain(which: str) -> int:
    rules = all_rules()
    targets = sorted(rules) if which == "all" else [which]
    if which != "all" and which not in rules:
        print(f"unknown rule `{which}`; known: {', '.join(sorted(rules))}",
              file=sys.stderr)
        return 2
    for i, rid in enumerate(targets):
        rule = rules[rid]
        if i:
            print()
        print(f"{rid} ({rule.severity})")
        print(f"  contract: {rule.contract}")
        print("  rationale:")
        print(textwrap.indent(textwrap.fill(rule.rationale, width=72),
                              "    "))
        if rule.example:
            print("  violating example:")
            print(textwrap.indent(rule.example.rstrip(), "    "))
        print("  suppress one site: "
              f"# repro-lint: disable={rid}  (say why)")
    return 0


def main(argv: List[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: contract-aware static analysis "
                    "(DESIGN.md 'Static contracts & repro-lint')")
    parser.add_argument("paths", nargs="*",
                        help="files/directories to analyze "
                             "(default: src benchmarks examples)")
    parser.add_argument("--format", choices=("text", "json", "github"),
                        default="text")
    parser.add_argument("--baseline", type=Path,
                        default=baseline_lib.default_baseline_path(),
                        help="baseline file (default: repo root "
                             f"{baseline_lib.BASELINE_NAME})")
    parser.add_argument("--no-baseline", action="store_true",
                        help="report every finding, baselined or not")
    parser.add_argument("--write-baseline", action="store_true",
                        help="grandfather all current findings into the "
                             "baseline file (entries get a TODO reason "
                             "to fill in) and exit 0")
    parser.add_argument("--explain", metavar="RULE",
                        help="print a rule's contract, rationale and a "
                             "minimal violating example ('all' for the "
                             "whole catalogue)")
    parser.add_argument("--root", type=Path, default=Path.cwd(),
                        help="path findings are reported relative to")
    args = parser.parse_args(argv)

    if args.explain:
        return _explain(args.explain)

    paths = args.paths or ["src", "benchmarks", "examples"]
    findings = analyze_paths(paths, args.root)

    if args.write_baseline:
        entries = baseline_lib.load(args.baseline)
        new, _, _ = baseline_lib.partition(findings, entries)
        entries.extend(baseline_lib.from_findings(new))
        baseline_lib.save(args.baseline, entries)
        print(f"baselined {len(new)} finding(s) -> {args.baseline} "
              "(fill in the TODO reasons)")
        return 0

    if args.no_baseline:
        new, old = findings, []
    else:
        new, old, stale = baseline_lib.partition(
            findings, baseline_lib.load(args.baseline))

    if args.format == "json":
        print(json.dumps({
            "findings": [vars(f) | {"baselined": False} for f in new]
            + [vars(f) | {"baselined": True} for f in old],
            "new": len(new), "baselined": len(old),
        }, indent=1, default=str))
    elif args.format == "github":
        for f in new:
            print(_fmt_github(f))
        if new:
            print(f"repro-lint: {len(new)} new finding(s) "
                  f"({len(old)} baselined)")
    else:
        for f in new:
            print(_fmt_text(f))
        print(f"repro-lint: {len(new)} new finding(s), "
              f"{len(old)} baselined, over {len(paths)} path(s)")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
