"""repro-lint: contract-aware static analysis for this repo
(DESIGN.md "Static contracts & repro-lint").

Run it::

    python -m repro.analysis [--format=text|json|github] paths...
    python -m repro.analysis --explain <rule>
    scripts/lint.sh            # src benchmarks examples, text output

Eight rules mechanize the repo's reproducibility contracts; see
``python -m repro.analysis --explain all`` for the catalogue. Findings
are suppressed inline with ``# repro-lint: disable=<rule>[,<rule>]`` or
grandfathered (with a justifying reason) in ``.repro-lint-baseline.json``
at the repo root.
"""
from repro.analysis.core import (  # noqa: F401
    FileContext, Finding, Rule, all_rules, analyze_paths, analyze_source,
    register, suppressed_lines,
)
from repro.analysis import baseline  # noqa: F401
