"""Pruning-schedule ablation (paper §4.2 discussion): linear (the paper's
schedule) vs cosine (its suggested gentler variant) vs step.

  PYTHONPATH=src python examples/schedule_ablation.py
"""
from repro.launch.serve import serve_eval
from repro.launch.train import train_loop

cfg, params = train_loop("deepseek-r1-distill-qwen-1.5b", steps=800,
                         batch=64, d_model=256, log_every=200)

print(f"\n{'schedule':10s} {'acc':>6s} {'total_toks':>10s} {'peak_MB':>8s}")
for sched in ["linear", "cosine", "step"]:
    r = serve_eval("deepseek-r1-distill-qwen-1.5b", "kappa", n=10,
                   problems=25, params=params, cfg=cfg,
                   kcfg_kw={"schedule": sched}, verbose=False)
    print(f"{sched:10s} {r['accuracy']:6.3f} {r['total_tokens']:10.1f} "
          f"{r['peak_memory_mb']:8.3f}")
