"""End-to-end serving driver: train a small model once, then serve a
batch of reasoning requests under all four decoding strategies and print
the paper's comparison table (accuracy / tokens / peak memory), then the
same pool behind the async streaming front-end (DESIGN.md §9).

  PYTHONPATH=src python examples/serve_batch.py [--steps 1200] [--problems 30]
"""
import argparse

from repro.launch.serve import serve_eval
from repro.launch.train import train_loop

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=1200)
ap.add_argument("--problems", type=int, default=30)
ap.add_argument("--arch", default="deepseek-r1-distill-qwen-1.5b")
args = ap.parse_args()

cfg, params = train_loop(args.arch, steps=args.steps, batch=64, d_model=256)

print(f"\n{'method':8s} {'N':>3s} {'acc':>6s} {'final_toks':>10s} "
      f"{'total_toks':>10s} {'peak_MB':>8s}")
rows = []
for method in ["greedy", "bon", "stbon", "kappa"]:
    for n in ([5, 10] if method != "greedy" else [1]):
        r = serve_eval(args.arch, method, n=n, problems=args.problems,
                       params=params, cfg=cfg, verbose=False)
        rows.append(r)
        print(f"{method:8s} {n:3d} {r['accuracy']:6.3f} "
              f"{r['final_branch_tokens']:10.1f} {r['total_tokens']:10.1f} "
              f"{r['peak_memory_mb']:8.3f}")

bon10 = next(r for r in rows if r["method"] == "bon" and r["n"] == 10)
kap10 = next(r for r in rows if r["method"] == "kappa" and r["n"] == 10)
print(f"\nKAPPA vs BoN (N=10): token reduction "
      f"{1 - kap10['total_tokens']/bon10['total_tokens']:.1%}, "
      f"memory reduction {1 - kap10['peak_memory_mb']/bon10['peak_memory_mb']:.1%}, "
      f"accuracy delta {kap10['accuracy'] - bon10['accuracy']:+.3f}")

# the same prompts through the continuous-batching row pool: identical
# outputs (same per-request keys), but pruned rows are backfilled with
# queued prefills instead of idling
seq5 = next(r for r in rows if r["method"] == "kappa" and r["n"] == 5)
cb5 = serve_eval(args.arch, "kappa", n=5, problems=args.problems,
                 params=params, cfg=cfg, verbose=False, scheduler=True)
print(f"continuous batching (N=5, rows=10): {cb5['tokens_per_s']:.1f} tok/s, "
      f"{cb5['requests_per_s']:.2f} req/s, "
      f"row utilization {cb5['row_utilization']:.2f} "
      f"(sequential wall {seq5['time_s']:.1f}s vs {cb5['time_s']:.1f}s)")

# the paged pool: same tokens again, but KV reservations are per-request
# pages, pruning frees pages instantly, and more rows share the budget
pg5 = serve_eval(args.arch, "kappa", n=5, problems=args.problems,
                 params=params, cfg=cfg, verbose=False, scheduler=True,
                 paged=True, page_size=16, sched_rows=20)
print(f"paged pool        (N=5, rows=20): {pg5['tokens_per_s']:.1f} tok/s, "
      f"{pg5['requests_per_s']:.2f} req/s, "
      f"page utilization {pg5['page_utilization']:.2f} "
      f"(wall {pg5['time_s']:.1f}s)")

# the same paged pool behind the async streaming front-end: every
# request is an AsyncIterator of token events, tokens arrive as the
# scheduler commits them, and the reassembled streams are asserted
# token-for-token equal to the terminal results
fe5 = serve_eval(args.arch, "kappa", n=5, problems=args.problems,
                 params=params, cfg=cfg, verbose=False, scheduler=True,
                 paged=True, page_size=16, sched_rows=20,
                 frontend_serve=True, stream=True)
print(f"streaming frontend(N=5, rows=20): {fe5['tokens_per_s']:.1f} tok/s, "
      f"{fe5['requests_per_s']:.2f} req/s (wall {fe5['time_s']:.1f}s)")

# per-terminal-status summary with goodput (OK tokens per wall second —
# the number the SLO-adaptive admission sweep optimizes): with no
# faults, deadlines, or queue bound every request should land in OK
for name, r in [("continuous", cb5), ("paged", pg5), ("frontend", fe5)]:
    sc = r["status_counts"]
    print(f"{name:10s} statuses: "
          + " ".join(f"{k}={sc.get(k, 0)}"
                     for k in ("OK", "CANCELLED", "TIMEOUT", "FAILED",
                               "SHED"))
          + f" (retries={r['retries']}, "
          + f"goodput={r['goodput_tokens_per_s']:.1f} tok/s)")
