"""Multi-pod dry-run example: lower + compile one (arch × shape) on the
512-chip mesh and print its roofline terms. No device allocation — the
whole thing runs from ShapeDtypeStructs on a laptop.

  PYTHONPATH=src python examples/multipod_dryrun.py [arch] [shape]
"""
import sys

from repro.launch.dryrun import run_one  # noqa: E402  (sets XLA_FLAGS first)

arch = sys.argv[1] if len(sys.argv) > 1 else "rwkv6-3b"
shape = sys.argv[2] if len(sys.argv) > 2 else "decode_32k"

rec = run_one(arch, shape, multi_pod=True)
r = rec["roofline"]
print(f"\nmesh 2x16x16 (512 chips), {arch} × {shape}")
print(f"  compute    {r['compute_s']*1e3:9.3f} ms")
print(f"  memory     {r['memory_s']*1e3:9.3f} ms   (HLO-raw {r['memory_hlo_s']*1e3:.3f} ms)")
print(f"  collective {r['collective_s']*1e3:9.3f} ms")
print(f"  dominant: {r['dominant']}   useful-flops ratio: {r['useful_ratio']:.2f}")
print(f"  memory_analysis: {rec['memory']}")
