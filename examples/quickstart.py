"""Quickstart: train a toy reasoning model for ~3 minutes, then watch
KAPPA prune branches on one problem.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs.base import KappaConfig
from repro.data import tasks
from repro.data import tokenizer as tok
from repro.launch.train import train_loop
from repro.serving import engine

# 1. train a small decoder on synthetic chain-of-thought arithmetic
cfg, params = train_loop("deepseek-r1-distill-qwen-1.5b", steps=400,
                         batch=64, d_model=192, log_every=100)

# 2. run KAPPA on a held-out problem
kcfg = KappaConfig(num_branches=5, max_new_tokens=48, max_cutoff=6,
                   horizon=8, window=8, mom_buckets=4)
prob = tasks.make_dataset(12345, 1, num_ops=2, max_operand=10)[0]
print("\nproblem:", tok.decode(prob.prompt), " expected:", prob.answer)

r = engine.generate_kappa(params, cfg, kcfg, np.array(prob.prompt),
                          jax.random.PRNGKey(0), eos_id=tok.EOS, bos_id=tok.BOS)
print("KAPPA output:", tok.decode(r.tokens))
print(f"chosen branch {r.chosen_branch}, draft cutoff c={r.extra['cutoff']}, "
      f"compactions {r.compactions}")
print(f"answer extracted: {tok.extract_answer(r.tokens)}  "
      f"correct: {tasks.check_answer(r.tokens, prob)}")
print(f"logical tokens {r.logical_tokens}  compute tokens {r.compute_tokens}  "
      f"peak cache {r.peak_cache_bytes/1e6:.3f} MB")
