"""Beyond-paper ablation: fixed pruning horizon τ (paper) vs the
adaptive-τ extension the paper proposes as future work (§5) — τ scaled
by mean branch entropy at the draft cutoff."""
from __future__ import annotations

from benchmarks import common


def run(cfg, params):
    rows = []
    n = common.NS[-1]
    for name, kw in [("fixed", {}),
                     ("adaptive", {"adaptive_horizon": True}),
                     ("adaptive_b05", {"adaptive_horizon": True,
                                       "horizon_beta": 0.5})]:
        r = common.eval_method(cfg, params, "kappa", n, kcfg_kw=kw)
        r["variant"] = name
        rows.append(r)
    return rows


def emit_csv(rows):
    return [f"horizon_ablation/{r['variant']}_N{r['n']},0,"
            f"acc={r['accuracy']:.3f};total_toks={r['total_tokens']:.1f};"
            f"peak_mb={r['peak_memory_mb']:.3f}" for r in rows]
