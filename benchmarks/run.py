# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper table/figure:

  kappa_table       — Appendix A: acc/tokens/memory, all methods × N
  memory_ratio      — Fig. 2: peak-memory reduction KAPPA vs BoN
  token_ratio       — Fig. 3: token reduction KAPPA vs BoN
  schedule_ablation — §4.2: linear vs cosine vs step pruning
  weight_ablation   — §4.1: (w_KL, w_C, w_H) mixes
  kernel_bench      — fused-score traffic arithmetic
  throughput        — sequential vs contiguous vs paged serving tok/s

Usage: PYTHONPATH=src python -m benchmarks.run [table ...]
Env:   BENCH_FULL=1 for paper-scale N∈{5,10,20} + longer training.

Besides the ``name,us_per_call,derived`` CSV on stdout, every table
writes ``BENCH_<name>.json`` ({name, rows, wall_s, config}) to the
working directory so the perf trajectory is machine-trackable across
PRs (see common.write_bench_json).
"""
from __future__ import annotations

import sys
import time

from benchmarks import (
    common,
    horizon_ablation,
    kappa_table,
    kernel_bench,
    memory_ratio,
    schedule_ablation,
    throughput,
    token_ratio,
    weight_ablation,
)

TABLES = {
    "kappa_table": kappa_table,
    "memory_ratio": memory_ratio,
    "token_ratio": token_ratio,
    "schedule_ablation": schedule_ablation,
    "weight_ablation": weight_ablation,
    "horizon_ablation": horizon_ablation,
    "kernel_bench": kernel_bench,
    "throughput": throughput,
}


def main() -> None:
    names = sys.argv[1:] or list(TABLES)
    needs_model = any(n != "kernel_bench" for n in names)
    cfg = params = None
    if needs_model:
        t0 = time.time()
        cfg, params = common.bench_model()
        print(f"# bench model ready ({time.time()-t0:.0f}s, "
              f"steps={common.STEPS}, problems={common.PROBLEMS}, "
              f"N={common.NS})", file=sys.stderr)
    print("name,us_per_call,derived")
    for name in names:
        mod = TABLES[name]
        t0 = time.time()
        rows = mod.run(cfg, params)
        for line in mod.emit_csv(rows):
            print(line)
        wall = time.time() - t0
        path = common.write_bench_json(name, rows, wall)
        print(f"# {name} done in {wall:.0f}s -> {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
