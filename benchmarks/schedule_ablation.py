"""§4.2 ablation: linear (paper) vs cosine (paper's suggested gentler
variant) vs step pruning schedules."""
from __future__ import annotations

from benchmarks import common


def run(cfg, params):
    rows = []
    n = common.NS[-1]
    for sched in ["linear", "cosine", "step"]:
        r = common.eval_method(cfg, params, "kappa", n,
                               kcfg_kw={"schedule": sched})
        r["schedule"] = sched
        rows.append(r)
    return rows


def emit_csv(rows):
    return [f"schedule_ablation/{r['schedule']}_N{r['n']},0,"
            f"acc={r['accuracy']:.3f};total_toks={r['total_tokens']:.1f};"
            f"peak_mb={r['peak_memory_mb']:.3f}" for r in rows]
