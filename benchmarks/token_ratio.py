"""Paper Fig. 3: total-token reduction ratio of KAPPA vs BoN per N."""
from __future__ import annotations

from benchmarks import common


def run(cfg, params):
    rows = []
    for n in common.NS:
        bon = common.eval_method(cfg, params, "bon", n)
        kap = common.eval_method(cfg, params, "kappa", n)
        rows.append({
            "n": n,
            "bon_tokens": bon["total_tokens"],
            "kappa_tokens": kap["total_tokens"],
            "reduction": 1.0 - kap["total_tokens"] / bon["total_tokens"],
        })
    return rows


def emit_csv(rows):
    return [f"token_ratio/N{r['n']},0,"
            f"reduction={r['reduction']:.3f};bon={r['bon_tokens']:.1f};"
            f"kappa={r['kappa_tokens']:.1f}" for r in rows]
