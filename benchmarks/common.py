"""Shared benchmark infrastructure: one trained toy reasoning model,
cached on disk, reused by every table/figure benchmark.

Env knobs:
  BENCH_FULL=1     — paper-scale settings (more training, more problems,
                     N up to 20); default is a fast CI-friendly pass
  BENCH_STEPS=N    — override training steps
  BENCH_PROBLEMS=N — override eval problem count
"""
from __future__ import annotations

import json
import os

import jax
import numpy as _np

from repro.configs import get_config
from repro.data import tokenizer as tok
from repro.launch.train import train_loop
from repro.models import init_params
from repro.training import checkpoint

FULL = os.environ.get("BENCH_FULL", "0") == "1"
STEPS = int(os.environ.get("BENCH_STEPS", "1800" if FULL else "800"))
PROBLEMS = int(os.environ.get("BENCH_PROBLEMS", "60" if FULL else "16"))
NS = [5, 10, 20] if FULL else [5, 10]
ARCH = "deepseek-r1-distill-qwen-1.5b"
D_MODEL = 256
LAYERS = 2
MAX_NEW = 44
# longer chains (8–18 target tokens) so the draft+gating phases end well
# before EOS — the paper's regime (c+τ ≪ sequence length); see §Paper-claims
DATASET_KW = dict(min_steps=4, max_steps=9, num_ops=2, max_operand=10)
KCFG_KW = dict(max_cutoff=3, horizon=5, window=8, mom_buckets=4)

_CKPT = os.path.join(os.path.dirname(__file__), os.pardir, "experiments",
                     f"bench_model_s{STEPS}_d{D_MODEL}.msgpack")


def bench_model():
    """(cfg, params): train once, cache to disk."""
    cfg = get_config(ARCH).reduced(num_layers=LAYERS, d_model=D_MODEL,
                                   vocab_size=tok.VOCAB_SIZE)
    path = os.path.abspath(_CKPT)
    if os.path.exists(path):
        params = init_params(jax.random.PRNGKey(0), cfg)
        return cfg, checkpoint.restore(path, params)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    cfg2, params = train_loop(ARCH, steps=STEPS, batch=64, d_model=D_MODEL,
                              num_layers=LAYERS, out=path, seq_len=44,
                              dataset_kw=DATASET_KW, log_every=300)
    return cfg2, params


def bench_config() -> dict:
    """Shared knobs recorded with every BENCH_<name>.json."""
    return {"full": FULL, "steps": STEPS, "problems": PROBLEMS, "ns": NS,
            "arch": ARCH, "d_model": D_MODEL, "layers": LAYERS,
            "max_new": MAX_NEW}


def _jsonable(x):
    if isinstance(x, _np.integer):
        return int(x)
    if isinstance(x, _np.floating):
        return float(x)
    if isinstance(x, _np.ndarray):
        return x.tolist()
    if isinstance(x, dict):
        return {k: _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    return x


def write_bench_json(name: str, rows, wall_s: float, out_dir: str = ".") -> str:
    """Machine-readable benchmark emission alongside the CSV, so the
    perf trajectory is trackable across PRs.
    Schema: {name, rows: [...], wall_s, config}."""
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    payload = {"name": name, "rows": _jsonable(rows), "wall_s": wall_s,
               "config": bench_config()}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


_MEMO = {}


def eval_method(cfg, params, method: str, n: int, *, problems: int = None,
                kcfg_kw: dict | None = None, seed: int = 999):
    """Memoized: memory_ratio/token_ratio reuse kappa_table's runs."""
    kk = dict(KCFG_KW)
    kk.update(kcfg_kw or {})
    key = (method, n, problems or PROBLEMS, seed, tuple(sorted(kk.items())))
    if key in _MEMO:
        return dict(_MEMO[key])
    from repro.launch.serve import serve_eval
    out = serve_eval(ARCH, method, n=n, problems=problems or PROBLEMS,
                     params=params, cfg=cfg, max_new=MAX_NEW,
                     kcfg_kw=kk, dataset_kw=DATASET_KW, seed=seed,
                     verbose=False)
    _MEMO[key] = dict(out)
    return out
