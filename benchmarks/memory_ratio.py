"""Paper Fig. 2: peak-memory reduction ratio of KAPPA vs BoN per N."""
from __future__ import annotations

from benchmarks import common


def run(cfg, params):
    rows = []
    for n in common.NS:
        bon = common.eval_method(cfg, params, "bon", n)
        kap = common.eval_method(cfg, params, "kappa", n)
        rows.append({
            "n": n,
            "bon_peak_mb": bon["peak_memory_mb"],
            "kappa_peak_mb": kap["peak_memory_mb"],
            "reduction": 1.0 - kap["peak_memory_mb"] / bon["peak_memory_mb"],
        })
    return rows


def emit_csv(rows):
    return [f"memory_ratio/N{r['n']},0,"
            f"reduction={r['reduction']:.3f};bon_mb={r['bon_peak_mb']:.3f};"
            f"kappa_mb={r['kappa_peak_mb']:.3f}" for r in rows]
