"""Paper Appendix A table: Accuracy / Final-Branch Tokens / Total Tokens /
Peak Memory for Greedy, BoN, ST-BoN, KAPPA at N ∈ {5,10,20}."""
from __future__ import annotations

from benchmarks import common


def run(cfg, params):
    rows = []
    rows.append(common.eval_method(cfg, params, "greedy", 1))
    for method in ["bon", "stbon", "kappa"]:
        for n in common.NS:
            rows.append(common.eval_method(cfg, params, method, n))
    return rows


def emit_csv(rows):
    out = []
    for r in rows:
        name = f"kappa_table/{r['method']}_N{r['n']}"
        us = r["time_s"] * 1e6 / max(r["total_tokens"], 1)
        derived = (f"acc={r['accuracy']:.3f};total_toks={r['total_tokens']:.1f};"
                   f"final_toks={r['final_branch_tokens']:.1f};"
                   f"peak_mb={r['peak_memory_mb']:.3f}")
        out.append(f"{name},{us:.1f},{derived}")
    return out
