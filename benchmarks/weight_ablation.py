"""Signal-weight ablation (paper §4.1 hyperparameter discussion):
the paper's (0.7, 0.2, 0.1) vs KL-only, confidence-only, entropy-only
and uniform mixes."""
from __future__ import annotations

from benchmarks import common

MIXES = {
    "paper_0.7_0.2_0.1": (0.7, 0.2, 0.1),
    "kl_only": (1.0, 0.0, 0.0),
    "conf_only": (0.0, 1.0, 0.0),
    "ent_only": (0.0, 0.0, 1.0),
    "uniform": (1 / 3, 1 / 3, 1 / 3),
}


def run(cfg, params):
    rows = []
    n = common.NS[0]
    for name, (wk, wc, wh) in MIXES.items():
        r = common.eval_method(cfg, params, "kappa", n,
                               kcfg_kw={"w_kl": wk, "w_conf": wc, "w_ent": wh})
        r["mix"] = name
        rows.append(r)
    return rows


def emit_csv(rows):
    return [f"weight_ablation/{r['mix']},0,"
            f"acc={r['accuracy']:.3f};total_toks={r['total_tokens']:.1f}"
            for r in rows]
