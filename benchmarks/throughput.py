"""Serving throughput: sequential vs continuous-batched vs paged
decoding across methods and queue depths.

Part 1 (sequential vs contiguous, per method): sequential serving
decodes one request at a time — after KAPPA/ST-BoN prune to one
survivor, the device runs a single branch row for the whole EOS tail.
The continuous-batching scheduler backfills freed rows with queued
prefills, so the same hardware row budget serves several requests per
step.

Part 2 (contiguous vs paged at equal KV memory, mixed-length prompts):
the contiguous pool reserves ``max_seq`` slots per row no matter how
short a request is, so its row count is capped at ``budget / max_seq``.
The paged pool spends the *same KV byte budget* as pages sized to each
request's own ``prompt + max_new`` need — with mixed lengths it packs
more concurrent rows into the same memory, and pruning returns pages
the moment it happens. Three modes are timed on identical tokens:

  * ``pr1``   — contiguous pool, PR 1 dispatch pattern (one sampling
                call + one host sync per request per tick);
  * ``cont``  — contiguous pool + this PR's fused one-dispatch-per-tick
                sampler (isolates the batched-sampling win);
  * ``paged`` — paged pool + fused sampler (adds the admission win).

Acceptance: paged ≥ 1.5× the PR 1 contiguous scheduler's aggregate
tokens/s at queue depth ≥ 8.

Every mode decodes the same prompts with the same per-request RNG keys,
so outputs are token-for-token identical (asserted) — the comparison is
pure wall-clock.

Part 3 (high fan-out COW): N=8 branches over multi-page prompts inside
a page budget the pre-PR broadcast allocator could not admit one
request into — prefix sharing (prompt pages aliased across branches),
lazy decode-page allocation and youngest-admitted preemption serve the
whole queue; shared-page savings, peak pages and preemption counts are
emitted, and zero leaked pages is asserted after every paged run.

Part 5 (PR 6 acceptance): a queue of requests sharing one long preamble
(the shared-system-prompt regime) served with the radix prefix cache on
vs off. Later admissions alias the earlier requests' published prompt
pages and skip that part of prefill entirely; the scenario reports the
hit rate and the fraction of queue-wide prefill tokens saved (>= 50%
target) and asserts the cached run is token-for-token identical.

Part 6 (PR 8 acceptance): open-loop Poisson arrival sweeps at offered
rates expressed as multiples of the pool's measured closed-loop
capacity, static vs SLO-adaptive admission (``repro.serving.slo``).
Decode tick wall time is independent of the active count (fixed-shape
pool dispatch), so overload inflates admitted ITL only through the
prompt chunks fused into each tick — the adaptive controller bounds
exactly that by pausing admission into prefill/decode pulses. The
acceptance: at some offered rate where static admission pushes
admitted ITL p99 past 1.5x the unloaded baseline, adaptive admission
holds it within 1.5x; goodput-under-SLO per rate lands in
BENCH_throughput.json. ``--openloop-smoke`` runs a two-rate reduced
sweep on an untrained toy model (curve produced + zero leaks) for CI.

Each scheduler run also reports a per-tick wall-time breakdown (model
step / sampler dispatch / pooled-controller dispatch / blocking sync /
per-request host work) so controller-overhead regressions are visible:
in the ``pr1`` mode every kappa request pays its own controller dispatch
+ host sync inside the advance loop (it shows up as ``host`` time),
while the fused modes run ONE pooled controller dispatch per tick —
asserted here via the scheduler's dispatch/sync counters.
"""
from __future__ import annotations

import gc
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.configs import get_config
from repro.configs.base import KappaConfig
from repro.data import tasks
from repro.data import tokenizer as tok
from repro.launch.serve import _strategy_factory
from repro.models import init_cache, init_params
from repro.serving import cache as cache_lib
from repro.serving import engine
from repro.serving import sampler
from repro.serving.scheduler import ContinuousBatchingScheduler, PagedScheduler
from repro.serving.slo import SLOConfig, SLOController

DEPTHS = [1, 4, 8] if common.FULL else [1, 4]
PAGED_DEPTHS = [8, 16]          # acceptance criterion lives at depth >= 8
PAGED_METHODS = ["kappa", "bon"]
PAGED_REPS = 3                  # best-of-R wall clock per mode (CPU noise)
BENCH_METHODS = ["kappa", "stbon", "bon"]
PAGE_SIZE = 16
# per-request decode budgets cycled over the queue — the mixed-length
# regime where need-sized page reservations beat max_seq-sized rows
MIXED_MAX_NEW = [common.MAX_NEW, 10, 16, 24]


def _kcfg(n: int = 5) -> KappaConfig:
    return KappaConfig(num_branches=n, max_new_tokens=common.MAX_NEW,
                       **common.KCFG_KW)


def _prompts(depth: int):
    probs = tasks.make_dataset(1234, depth, **common.DATASET_KW)
    return [np.array(p.prompt) for p in probs]


def _mixed_max_new(depth: int):
    return [MIXED_MAX_NEW[i % len(MIXED_MAX_NEW)] for i in range(depth)]


FANOUT_N = 8                    # high-fan-out COW scenario branches
FANOUT_DEPTH = 6

INTERLEAVE_CHUNK = 32           # prompt tokens per tick while decode runs
INTERLEAVE_LONG = 1536          # long-prompt target length (tokens): the
                                # whole-prompt prefill must dominate a
                                # decode tick for the head-of-line stall
                                # to be real (~10+ ticks at toy scale)
INTERLEAVE_REPS = 3             # best-of-R (CPU wall-clock noise; rep 1
                                # also absorbs jit compiles)

BREAKDOWN_KEYS = ("model", "prefill", "sampler", "controller", "sync",
                  "host")


def _tick_breakdown_us(tp):
    """Per-tick µs spent in each scheduler tick phase. ``host`` absorbs
    any UNPOOLED per-request controller dispatch + sync (the pr1 mode),
    which is exactly the regression this breakdown makes visible."""
    ticks = max(tp["ticks"], 1)
    return {k: tp[f"time_{k}_s"] * 1e6 / ticks for k in BREAKDOWN_KEYS}


def _run_sequential(cfg, params, kcfg, method, prompts, max_seq):
    factory = _strategy_factory(method, kcfg)
    t0 = time.time()
    gens = [engine._decode_loop(params, cfg, kcfg, p, jax.random.PRNGKey(i),
                                factory(), eos_id=tok.EOS, bos_id=tok.BOS,
                                max_seq=max_seq)
            for i, p in enumerate(prompts)]
    dt = time.time() - t0
    toks = sum(g.logical_tokens for g in gens)
    return gens, toks, dt


def _run_scheduled(cfg, params, kcfg, method, prompts, max_seq, rows, *,
                   paged=False, max_news=None, **sched_kw):
    factory = _strategy_factory(method, kcfg)
    cls = PagedScheduler if paged else ContinuousBatchingScheduler
    sched = cls(params, cfg, kcfg, rows=rows, max_seq=max_seq, method=method,
                eos_id=tok.EOS, bos_id=tok.BOS, strategy_factory=factory,
                **sched_kw)
    max_news = max_news or [None] * len(prompts)
    rids = [sched.submit(p, jax.random.PRNGKey(i), max_new=mn)
            for i, (p, mn) in enumerate(zip(prompts, max_news))]
    res = sched.run()
    tp = sched.throughput()
    if paged:
        # COW/refcount hygiene: every page reference dropped, none leaked
        # (the radix tree's pins are dropped first — tp already captured
        # the live pinned-page count)
        if getattr(sched, "pcache", None) is not None:
            sched.pcache.drop()
        assert sched.alloc.free_count == sched.num_pages, \
            f"leaked {sched.num_pages - sched.alloc.free_count} pages"
        assert int(sched.alloc.pinned.sum()) == 0
    return [res[r] for r in rids], tp


def _long_prompts(depth: int):
    """Multi-page prompts (3 problems concatenated) so prefix sharing has
    full prompt pages to alias."""
    base = _prompts(3 * depth)
    return [np.concatenate([base[3 * i]]
                           + [b[1:] for b in base[3 * i + 1: 3 * i + 3]])
            for i in range(depth)]


def _fanout_scenario(cfg, params):
    """High-fan-out COW scenario: N=8 branches over long prompts inside a
    page budget the pre-PR broadcast allocator could not even admit ONE
    request into (it reserved N x ceil((prompt+max_new)/page_size) pages
    up front). Prefix sharing + lazy allocation serve the whole queue in
    that budget; preemptions (youngest-admitted eviction on page
    exhaustion) are part of the deal and are reported."""
    kcfg = _kcfg(FANOUT_N)
    prompts = _long_prompts(FANOUT_DEPTH)
    max_seq = max(len(p) for p in prompts) + kcfg.max_new_tokens
    max_seq = -(-max_seq // PAGE_SIZE) * PAGE_SIZE
    need_pages = [-(-(len(p) + kcfg.max_new_tokens) // PAGE_SIZE)
                  for p in prompts]
    full_pages = [len(p) // PAGE_SIZE for p in prompts]
    broadcast_worst = max(FANOUT_N * n for n in need_pages)
    shared_worst = max(f + FANOUT_N * (n - f)
                       for f, n in zip(full_pages, need_pages))
    num_pages = shared_worst + 4
    assert broadcast_worst > num_pages, \
        "budget no longer breaks the broadcast allocator - shrink it"
    sched = PagedScheduler(params, cfg, kcfg, rows=2 * FANOUT_N,
                           max_seq=max_seq, page_size=PAGE_SIZE,
                           num_pages=num_pages, method="kappa",
                           eos_id=tok.EOS, bos_id=tok.BOS)
    rids = [sched.submit(p, jax.random.PRNGKey(i))
            for i, p in enumerate(prompts)]
    res = sched.run()
    assert set(res) == set(rids)
    tp = sched.throughput()
    assert sched.alloc.free_count == num_pages, \
        f"leaked {num_pages - sched.alloc.free_count} pages"
    assert tp["page_peak"] <= num_pages
    return [{
        "kind": "fanout", "method": "kappa", "fan_out": FANOUT_N,
        "depth": FANOUT_DEPTH, "page_size": PAGE_SIZE,
        "num_pages": num_pages,
        "broadcast_worst_pages_per_req": broadcast_worst,
        "shared_worst_pages_per_req": shared_worst,
        "page_peak": tp["page_peak"],
        "shared_page_savings": 1.0 - shared_worst / broadcast_worst,
        "preemptions": tp["preemptions"],
        "tokens_per_s": tp["tokens_per_s"],
        "page_utilization": tp["page_utilization"],
        "ticks": tp["ticks"], "time_s": tp["time_s"],
    }]


INT8_PARITY_PROBLEMS = 12       # answer-parity sweep size (per method)
INT8_DEPTH = 10                 # deeper queue: the int8 pool's peak
                                # concurrency must not be capped by
                                # running out of queued requests


def _int8_capacity_scenario(cfg, params):
    """Part 7 (int8 paged KV acceptance): ONE fixed HBM page budget,
    served twice — model-dtype pages vs int8 pages + scale leaves. The
    int8 pool cuts the same bytes into >= 1.8x the pages (page_bytes
    shrinks from hd*itemsize to hd+4 per token-head), so the N=8 fan-out
    queue reaches >= 1.8x the peak concurrent admitted requests. A
    BoN/KAPPA sweep over the synthetic tasks then checks answer
    accuracy parity against fp serving — quantization must buy capacity,
    not trade away correctness."""
    import dataclasses
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    kcfg = _kcfg(FANOUT_N)
    prompts = _long_prompts(INT8_DEPTH)
    max_seq = max(len(p) for p in prompts) + kcfg.max_new_tokens
    max_seq = -(-max_seq // PAGE_SIZE) * PAGE_SIZE
    need = [-(-(len(p) + kcfg.max_new_tokens) // PAGE_SIZE) for p in prompts]
    full = [len(p) // PAGE_SIZE for p in prompts]
    shared_worst = max(f + FANOUT_N * (n - f) for f, n in zip(full, need))
    # budget sized so the model-dtype pool serves the queue ~serially
    budget = (shared_worst + 4) * cache_lib.page_bytes(cfg, PAGE_SIZE)

    def serve(c):
        sched = PagedScheduler(params, c, kcfg, rows=FANOUT_N * INT8_DEPTH,
                               max_seq=max_seq, page_size=PAGE_SIZE,
                               page_budget_bytes=budget, method="kappa",
                               eos_id=tok.EOS, bos_id=tok.BOS)
        rids = [sched.submit(p, jax.random.PRNGKey(i))
                for i, p in enumerate(prompts)]
        peak, t0 = 0, time.perf_counter()
        while sched.queue or sched.active or sched.prefilling:
            sched.tick()
            peak = max(peak, len(sched.active))
        sched.elapsed = time.perf_counter() - t0   # run() normally sets it
        assert set(sched.results) == set(rids)
        assert sched.alloc.free_count == sched.num_pages, \
            f"leaked {sched.num_pages - sched.alloc.free_count} pages"
        return sched, peak, sched.throughput()

    s_fp, peak_fp, tp_fp = serve(cfg)
    s_i8, peak_i8, tp_i8 = serve(cfg8)
    assert s_i8.num_pages >= int(1.8 * s_fp.num_pages), \
        f"int8 page capacity only {s_i8.num_pages}/{s_fp.num_pages}"
    want_peak = min(INT8_DEPTH, int(np.ceil(1.8 * peak_fp)))
    assert peak_i8 >= want_peak, \
        f"int8 admitted {peak_i8} concurrent vs {peak_fp} fp " \
        f"(>= {want_peak} wanted)"

    # answer parity: same problems, same keys, fp sequential vs int8
    # paged serving, both BoN and KAPPA
    probs = tasks.make_dataset(4321, INT8_PARITY_PROBLEMS,
                               **common.DATASET_KW)
    sp = [np.array(p.prompt) for p in probs]
    kc = _kcfg()
    ms = -(-(max(len(p) for p in sp) + kc.max_new_tokens)
           // PAGE_SIZE) * PAGE_SIZE
    rows_par = 2 * kc.num_branches
    acc = {}
    for method in ("kappa", "bon"):
        fn = getattr(engine, f"generate_{method}")
        gens_fp = [fn(params, cfg, kc, p, jax.random.PRNGKey(i),
                      eos_id=tok.EOS, bos_id=tok.BOS, max_seq=ms)
                   for i, p in enumerate(sp)]
        gens_i8, _ = _run_scheduled(
            cfg8, params, kc, method, sp, ms, rows_par, paged=True,
            page_size=PAGE_SIZE,
            num_pages=rows_par * ms // PAGE_SIZE)
        for label, gens in (("fp", gens_fp), ("int8", gens_i8)):
            acc[f"{method}_{label}"] = float(np.mean(
                [tasks.check_answer(g.tokens, pr)
                 for g, pr in zip(gens, probs)]))
    parity_tol = 2.0 / INT8_PARITY_PROBLEMS
    parity_ok = all(abs(acc[f"{m}_fp"] - acc[f"{m}_int8"]) <= parity_tol
                    for m in ("kappa", "bon"))
    assert parity_ok, f"int8 answer accuracy drifted: {acc}"
    return [{
        "kind": "int8", "fan_out": FANOUT_N, "depth": INT8_DEPTH,
        "page_size": PAGE_SIZE, "page_budget_bytes": budget,
        "num_pages_fp": s_fp.num_pages, "num_pages_int8": s_i8.num_pages,
        "peak_concurrent_fp": peak_fp, "peak_concurrent_int8": peak_i8,
        "admit_ratio": peak_i8 / max(peak_fp, 1),
        "page_ratio": s_i8.num_pages / max(s_fp.num_pages, 1),
        "parity_ok": parity_ok, "parity_problems": INT8_PARITY_PROBLEMS,
        "fp_tokens_per_s": tp_fp["tokens_per_s"],
        "int8_tokens_per_s": tp_i8["tokens_per_s"],
        "int8_preemptions": tp_i8["preemptions"],
        "fp_preemptions": tp_fp["preemptions"],
        "int8_ticks": tp_i8["ticks"], "int8_time_s": tp_i8["time_s"],
        "fp_ticks": tp_fp["ticks"], "fp_time_s": tp_fp["time_s"],
        **{f"acc_{k}": v for k, v in acc.items()},
    }]


PREFIX_DEPTH = 8                # requests sharing the preamble
PREFIX_PREAMBLE = 320           # shared-preamble target length (tokens):
                                # 20 full pages every later request aliases
PREFIX_CHUNK = 32               # chunked prefill (required for resuming
                                # at the cached extent)


def _prefix_scenario(cfg, params):
    """Part 5 (PR 6 acceptance): PREFIX_DEPTH requests share one long
    preamble and differ only in a short tail. With the radix prefix
    cache on, every admission after the first completions aliases the
    published preamble pages and prefills only its tail; with it off,
    every request re-prefills the whole preamble. Both runs must be
    token-for-token identical (the cache is a pure prefill shortcut)."""
    kcfg = _kcfg()
    base = _prompts(PREFIX_DEPTH + 40)
    pieces = [base[PREFIX_DEPTH][:-1]]       # BOS + body, no QM
    total, i = len(pieces[0]), PREFIX_DEPTH + 1
    while total < PREFIX_PREAMBLE:
        pieces.append(base[i][1:-1])         # strip BOS/QM, keep body
        total += len(base[i]) - 2
        i += 1
    preamble = np.concatenate(pieces)
    prompts = [np.concatenate([preamble, base[j][1:]])
               for j in range(PREFIX_DEPTH)]
    max_seq = max(len(p) for p in prompts) + kcfg.max_new_tokens
    max_seq = -(-max_seq // PAGE_SIZE) * PAGE_SIZE
    # one fan-out of rows: requests drain the queue one at a time, so
    # every request after the first finds the preamble already published
    # (concurrent-admission hit/miss races are exercised in the fuzz
    # equivalence suite; this scenario measures steady-state reuse)
    rows = kcfg.num_branches
    num_pages = 2 * rows * max_seq // PAGE_SIZE

    def run_once(pc):
        gens, tp = _run_scheduled(
            cfg, params, kcfg, "kappa", prompts, max_seq, rows,
            paged=True, page_size=PAGE_SIZE, num_pages=num_pages,
            prefill_chunk=PREFIX_CHUNK, prefix_cache=pc)
        return gens, tp

    run_once(True)                           # warm the chunked shapes
    run_once(False)
    gens_off, tp_off = run_once(False)
    gens_on, tp_on = run_once(True)
    assert all(a.tokens == b.tokens for a, b in zip(gens_off, gens_on)), \
        "prefix-cached serving diverged from the uncached run"
    prompt_tokens = sum(len(p) for p in prompts)
    looked = tp_on["prefix_hits"] + tp_on["prefix_misses"]
    return [{
        "kind": "prefix", "method": "kappa", "depth": PREFIX_DEPTH,
        "preamble_len": int(len(preamble)), "page_size": PAGE_SIZE,
        "prefill_chunk": PREFIX_CHUNK, "prompt_tokens": prompt_tokens,
        "prefix_hits": tp_on["prefix_hits"],
        "prefix_hit_rate": tp_on["prefix_hits"] / max(looked, 1),
        "prefix_tokens_saved": tp_on["prefix_tokens_saved"],
        "prefill_tokens_saved_frac": tp_on["prefix_tokens_saved"]
        / max(prompt_tokens, 1),
        "prefix_evictions": tp_on["prefix_evictions"],
        "prefix_pinned_pages": tp_on["prefix_pinned_pages"],
        "cached_tokens_per_s": tp_on["tokens_per_s"],
        "uncached_tokens_per_s": tp_off["tokens_per_s"],
        "cached_vs_uncached": tp_on["tokens_per_s"]
        / max(tp_off["tokens_per_s"], 1e-9),
        "ticks": tp_on["ticks"], "time_s": tp_on["time_s"],
    }]


def _interleave_scenario(cfg, params):
    """Part 4 (PR 5 acceptance): admit one LONG-prompt request while
    >= 2 short requests are decoding. With one-shot admission the whole
    prompt prefill lands inside a single tick — every in-flight request
    stalls for it (a multi-tick-sized ITL spike). With chunked prefill
    the admission advances ``INTERLEAVE_CHUNK`` tokens per tick inside
    the decode tick, so in-flight ITL stays within ~1.2x of a
    no-admission baseline and the long request's TTFT is reported.
    Token streams are asserted identical between the two admission
    modes (the final chunk's logits are bitwise-equal to the one-shot
    prefill)."""
    kcfg = _kcfg()
    shorts = _prompts(3)
    base = _prompts(160)
    pieces, total = [base[0]], len(base[0])
    for p in base[1:]:
        if total >= INTERLEAVE_LONG:
            break
        pieces.append(p[1:])
        total += len(p) - 1
    long_p = np.concatenate(pieces)
    max_seq = -(-(len(long_p) + common.MAX_NEW) // PAGE_SIZE) * PAGE_SIZE
    num_pages = 8 * max_seq // PAGE_SIZE

    def run_once(chunk, admit_long):
        sched = PagedScheduler(params, cfg, kcfg, rows=8, max_seq=max_seq,
                               page_size=PAGE_SIZE, num_pages=num_pages,
                               method="greedy", eos_id=tok.EOS,
                               bos_id=tok.BOS, prefill_chunk=chunk)
        rids = [sched.submit(p, jax.random.PRNGKey(i),
                             max_new=common.MAX_NEW, method="greedy")
                for i, p in enumerate(shorts)]
        for _ in range(200):        # warm: all shorts decoding steadily
            sched.tick()
            if all(r in sched.active and sched.active[r][0].step >= 4
                   for r in rids):
                break
        t_admit = time.perf_counter()
        rl = None
        if admit_long:
            rl = sched.submit(long_p, jax.random.PRNGKey(99), max_new=16,
                              method="greedy")
            # the admission window: ticks while the long prompt's
            # prefill is in flight — where one-shot admission stalls
            # every in-flight request for the whole prompt
            while rl not in sched.active and rl not in sched.results:
                sched.tick()
        else:
            # baseline window: plain decode ticks, sized like the
            # chunked admission window so p99 sees comparable samples
            for _ in range(-(-INTERLEAVE_LONG // INTERLEAVE_CHUNK)):
                sched.tick()
        t_end = time.perf_counter()
        sched.run()
        assert sched.alloc.free_count == sched.num_pages
        itl = np.asarray([t1 - t0 for r in rids
                          for t0, t1 in zip(sched.token_times[r],
                                            sched.token_times[r][1:])
                          if t_admit < t1 <= t_end] or [0.0])
        return {
            "itl_p50_s": float(np.percentile(itl, 50)),
            "itl_p99_s": float(np.percentile(itl, 99)),
            "itl_max_s": float(itl.max()),
            "ttft_long_s": sched.ttft.get(rl),
            "tokens": {r: sched.results[r].tokens for r in rids
                       + ([rl] if rl is not None else [])},
        }

    # interleaved best-of-R (machine speed phases hit every mode; rep 1
    # additionally absorbs the jit compiles of each mode's shapes)
    runs = {"base": [], "oneshot": [], "chunked": []}
    for _ in range(INTERLEAVE_REPS):
        runs["base"].append(run_once(INTERLEAVE_CHUNK, admit_long=False))
        runs["oneshot"].append(run_once(None, admit_long=True))
        runs["chunked"].append(run_once(INTERLEAVE_CHUNK, admit_long=True))
    base = min(runs["base"], key=lambda r: r["itl_p99_s"])
    oneshot = min(runs["oneshot"], key=lambda r: r["itl_p99_s"])
    chunked = min(runs["chunked"], key=lambda r: r["itl_p99_s"])
    assert oneshot["tokens"] == chunked["tokens"], \
        "chunked admission diverged from one-shot serving"
    return [{
        "kind": "interleave", "method": "greedy",
        "in_flight": len(shorts), "long_prompt_len": len(long_p),
        "prefill_chunk": INTERLEAVE_CHUNK, "page_size": PAGE_SIZE,
        "baseline_itl_p99_s": base["itl_p99_s"],
        "oneshot_itl_p99_s": oneshot["itl_p99_s"],
        "chunked_itl_p99_s": chunked["itl_p99_s"],
        "oneshot_itl_max_s": oneshot["itl_max_s"],
        "chunked_itl_max_s": chunked["itl_max_s"],
        "oneshot_ttft_long_s": oneshot["ttft_long_s"],
        "chunked_ttft_long_s": chunked["ttft_long_s"],
        "chunked_vs_baseline_itl_p99": chunked["itl_p99_s"]
        / max(base["itl_p99_s"], 1e-9),
        "oneshot_vs_baseline_itl_p99": oneshot["itl_p99_s"]
        / max(base["itl_p99_s"], 1e-9),
    }]


OVERLOAD_DEPTH = 6              # unloaded load: drains with minimal queuing
OVERLOAD_BURST = 2 * OVERLOAD_DEPTH  # the open-loop 2x burst
OVERLOAD_QUEUE = 8              # bounded admission queue during the burst
OVERLOAD_REPS = 2               # best-of-R for the ITL percentiles


def _itl_p99_s(sched, rids):
    itl = [t1 - t0 for r in rids
           for t0, t1 in zip(sched.token_times.get(r, []),
                             sched.token_times.get(r, [])[1:])]
    return float(np.percentile(np.asarray(itl or [0.0]), 99))


def _overload_scenario(cfg, params):
    """Graceful overload degradation (DESIGN.md §8): an open-loop burst
    at 2x the unloaded depth, served under a bounded admission queue and
    per-request tick budgets (the deterministic twin of wall-clock
    deadlines). The contract: excess load is SHED at the door, requests
    that cannot finish inside their budget TIMEOUT with partial tokens,
    and the requests that ARE admitted keep decoding at unloaded speed —
    admitted-ITL p99 within 1.5x of the unloaded baseline. Reported:
    shed rate, deadline-miss rate, goodput (OK logical tokens/s)."""
    kcfg = _kcfg()
    # one fan-out of rows: the pool is genuinely saturated (requests
    # admit one at a time, pruning backfills), so a 2x burst is real
    # overload rather than slack absorption
    rows = kcfg.num_branches
    prompts = _prompts(OVERLOAD_BURST)
    max_seq = max(len(p) for p in prompts) + kcfg.max_new_tokens

    def run_once(n_req, *, max_queue=None, ticks=None):
        sched = ContinuousBatchingScheduler(
            params, cfg, kcfg, rows=rows, max_seq=max_seq, method="kappa",
            eos_id=tok.EOS, bos_id=tok.BOS,
            strategy_factory=_strategy_factory("kappa", kcfg),
            max_queue=max_queue)
        rids = [sched.submit(prompts[i], jax.random.PRNGKey(i),
                             max_wall_ticks=ticks) for i in range(n_req)]
        res = sched.run()
        return sched, rids, res

    sched_w, _, _ = run_once(OVERLOAD_DEPTH)  # absorb jit compiles
    # all requests are submitted at tick 0, so the tick budget is an
    # absolute completion deadline. Keyed to the measured unloaded drain
    # (not max_new — how long requests actually run depends on how early
    # the model EOSes): the unloaded load fits with 10% slack; the 2x
    # burst admits ~8/6 the work through a saturated pool, so its tail
    # cannot
    budget = int(1.1 * sched_w.ticks)
    base_itl, over = None, None
    for _ in range(OVERLOAD_REPS):           # interleaved best-of-R
        sched_u, rids_u, _ = run_once(OVERLOAD_DEPTH)
        itl_u = _itl_p99_s(sched_u, rids_u)
        base_itl = itl_u if base_itl is None else min(base_itl, itl_u)
        sched_o, rids_o, res = run_once(OVERLOAD_BURST,
                                        max_queue=OVERLOAD_QUEUE,
                                        ticks=budget)
        ok = [r for r in rids_o if res[r].status == "OK"]
        itl_o = _itl_p99_s(sched_o, ok)
        if over is None or itl_o < over["itl"]:
            over = {"sched": sched_o, "rids": rids_o, "res": res,
                    "ok": ok, "itl": itl_o}
    sched_o, rids_o, res, ok = (over["sched"], over["rids"], over["res"],
                                over["ok"])
    statuses = [res[r].status for r in rids_o]
    # the burst must actually exercise all three outcomes — degrade,
    # don't collapse: some served, some shed at the door, some truncated
    assert ok, f"overload starved every request: {statuses}"
    assert "SHED" in statuses, "burst never hit the queue bound"
    assert "TIMEOUT" in statuses, "tick budget never fired — raise burst"
    # timed-out requests keep their partial decode (truncate-and-return)
    assert all(res[r].steps > 0 for r in rids_o
               if res[r].status == "TIMEOUT" and r in sched_o.token_times)
    goodput = sum(res[r].logical_tokens for r in ok) \
        / max(sched_o.elapsed, 1e-9)
    return [{
        "kind": "overload", "method": "kappa", "rows": rows,
        "depth": OVERLOAD_DEPTH, "burst": OVERLOAD_BURST,
        "max_queue": OVERLOAD_QUEUE, "tick_budget": budget,
        "served_ok": len(ok),
        "shed_rate": statuses.count("SHED") / len(rids_o),
        "deadline_miss_rate": statuses.count("TIMEOUT") / len(rids_o),
        "goodput_tokens_per_s": goodput,
        "baseline_itl_p99_s": base_itl,
        "overload_itl_p99_s": over["itl"],
        "overload_vs_baseline_itl_p99": over["itl"] / max(base_itl, 1e-9),
        "ticks": sched_o.ticks, "time_s": sched_o.elapsed,
    }]


OPENLOOP_ROWS = 8               # greedy pool rows (fixed dispatch shape)
OPENLOOP_CHUNK = 256            # prompt tokens fused into a tick per admit:
                                # big enough that chunk COMPUTE (not just
                                # dispatch overhead) is what a concurrent
                                # admission costs the in-flight decoders
OPENLOOP_QUEUE = 12             # bounded admission queue (static's only gate)
OPENLOOP_PROMPT = 256           # uniform prompt length == ONE chunk. The
                                # fused tick dispatch is keyed on each
                                # chunk's block-table extent (grows with
                                # chunk index), so multi-chunk prompts make
                                # the jit key the multiset of in-flight
                                # chunk indices — unwarmable. One chunk per
                                # prompt collapses the key to HOW MANY
                                # admissions ride the tick: rows-1 shapes,
                                # warmed exactly below
OPENLOOP_MAX_NEWS = [10, 10, 10, 28]  # cycled per request: trios of
                                # equal-length requests complete (and
                                # free rows) together, so under backlog
                                # a static gate re-admits ~3 at once —
                                # the burst whose fused chunks inflate
                                # the long-running requests' ITL; the
                                # 28s keep decoders in flight to witness
                                # it
OPENLOOP_REQS = 32              # enough ITL samples (~600 gaps) that a
                                # p99 is a population, not one outlier
OPENLOOP_RATES_X = [0.25, 1.0, 2.5]  # offered rate / measured capacity:
                                # clean unloaded anchor (arrivals rarely
                                # collide), saturation, sustained
                                # overload
OPENLOOP_SMOKE_RATES_X = [0.25, 2.5]
OPENLOOP_SLO_MARGIN = 1.35      # controller target = margin x unloaded
                                # p99. Must clear the cost of ONE paced
                                # admission tick (the unloaded p99 IS
                                # that tick), else every window that
                                # admits anything reads violated and the
                                # controller oscillates into pause
OPENLOOP_SLO_BOUND = 1.5        # acceptance bound (matches overload gate)
OPENLOOP_WINDOW = 8             # controller window (ticks) — reacts well
                                # inside one admission's prefill


def _openloop_prompts(n_req: int):
    """``n_req`` concatenated prompts of exactly OPENLOOP_PROMPT tokens
    each (distinct content, uniform length — see the shape note on
    OPENLOOP_PROMPT)."""
    base = _prompts(32 * n_req)
    prompts, i = [], 0
    for _ in range(n_req):
        pieces, total = [base[i]], len(base[i])
        i += 1
        while total < OPENLOOP_PROMPT:
            assert i < len(base), "ran out of prompt pieces"
            pieces.append(base[i][1:])       # strip BOS, keep body + QM
            total += len(base[i]) - 1
            i += 1
        flat = np.concatenate(pieces)[:OPENLOOP_PROMPT].copy()
        flat[-1] = tok.QM
        prompts.append(flat)
    return prompts


def _poisson_arrivals(rate_rps: float, n: int, seed: int):
    """Cumulative open-loop arrival times. Seeded: every rate reuses the
    same exponential draws, so sweeps differ only by the 1/rate scale."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_rps, size=n))


def _drive_open_loop(sched, prompts, arrivals, max_news, ctl=None):
    """Open-loop serving: submit each request at its wall-clock arrival
    time regardless of pool state (arrivals do not wait for capacity —
    the definition of offered load), tick while anything is in flight,
    let the controller evaluate after every tick. Stamps
    ``sched.elapsed`` like ``run()`` does."""
    rids = [None] * len(prompts)
    # a GC pause inside a measured tick reads as a phantom ITL spike at
    # p99 — collect up front, disable during the run
    gc.collect()
    gc.disable()
    try:
        t0 = sched.clock()
        nxt = 0
        while nxt < len(prompts) or sched.has_work:
            now = sched.clock() - t0
            while nxt < len(prompts) and arrivals[nxt] <= now:
                rids[nxt] = sched.submit(prompts[nxt],
                                         jax.random.PRNGKey(nxt),
                                         max_new=max_news[nxt])
                nxt += 1
            if sched.has_work:
                sched.tick()
                if ctl is not None:
                    ctl.on_tick()
            else:
                time.sleep(min(max(arrivals[nxt] - now, 0.0), 0.002))
        sched.elapsed = sched.clock() - t0
    finally:
        gc.enable()
    return rids


def _openloop_scenario(cfg, params, smoke=False):
    """Part 6: offered-rate sweep, static vs SLO-adaptive admission.

    Capacity is calibrated from a warm closed-loop drain of the same
    prompts (which also absorbs every jit shape the sweep touches); the
    lowest-rate static run defines the unloaded admitted-ITL p99 that
    anchors both the controller's target and the acceptance bound.
    Every run asserts zero leaked pages/pins after drain."""
    kcfg = KappaConfig(num_branches=4,
                       max_new_tokens=max(OPENLOOP_MAX_NEWS),
                       **common.KCFG_KW)
    n_req = 8 if smoke else OPENLOOP_REQS
    rates_x = OPENLOOP_SMOKE_RATES_X if smoke else OPENLOOP_RATES_X
    prompts = _openloop_prompts(n_req)
    max_news = [OPENLOOP_MAX_NEWS[i % len(OPENLOOP_MAX_NEWS)]
                for i in range(n_req)]
    max_seq = OPENLOOP_PROMPT + max(OPENLOOP_MAX_NEWS)
    max_seq = -(-max_seq // PAGE_SIZE) * PAGE_SIZE
    num_pages = OPENLOOP_ROWS * max_seq // PAGE_SIZE

    def mk(max_queue=OPENLOOP_QUEUE):
        return PagedScheduler(params, cfg, kcfg, rows=OPENLOOP_ROWS,
                              max_seq=max_seq, page_size=PAGE_SIZE,
                              num_pages=num_pages, method="greedy",
                              eos_id=tok.EOS, bos_id=tok.BOS,
                              prefill_chunk=OPENLOOP_CHUNK,
                              max_queue=max_queue)

    # deterministic jit warm-up. The fused tick dispatch is keyed on how
    # many prompt chunks ride it, so warm every k the sweep can hit
    # (k prefilling + at least one decoding, bounded by the row pool):
    # admit one request to decode, then admit k more at once so their
    # chunks fuse into its ticks. A compile landing inside a measured
    # run would masquerade as a multi-second ITL spike.
    for k in range(1, OPENLOOP_ROWS):
        sched = mk(max_queue=None)
        sched.submit(prompts[0], jax.random.PRNGKey(0),
                     max_new=max(OPENLOOP_MAX_NEWS))
        for _ in range(OPENLOOP_PROMPT // OPENLOOP_CHUNK + 1):
            sched.tick()                     # request 0 reaches decode
        for j in range(1, k + 1):
            sched.submit(prompts[j % n_req], jax.random.PRNGKey(j),
                         max_new=min(OPENLOOP_MAX_NEWS))
        sched.run()
    # closed-loop drain (unbounded queue, whole batch at tick 0) on the
    # warmed shapes: the capacity estimate offered rates are scaled by
    sched_w = mk(max_queue=None)
    for i, p in enumerate(prompts):
        sched_w.submit(p, jax.random.PRNGKey(i), max_new=max_news[i])
    res_w = sched_w.run()
    assert all(r.status == "OK" for r in res_w.values())
    capacity_rps = len(prompts) / max(sched_w.elapsed, 1e-9)

    def run_rate(rate_rps, *, target_itl=None):
        sched = mk()
        ctl = None
        if target_itl is not None:
            # min_prefill_chunk pins the chunk knob: halving it mid-run
            # would introduce unwarmed fused-dispatch shapes whose
            # compiles dwarf the knob's benefit at toy scale — the
            # admission pacing budget (level 1), pause (level 2) and
            # shed (level 3) are the levers under test. start_level=1:
            # admission begins paced (one chunk of new prompt per tick)
            # and healthy windows relax it — reacting only AFTER a
            # violated window would serve the first burst at full blast
            ctl = SLOController(sched, SLOConfig(
                target_itl_p99_s=target_itl,
                window_ticks=OPENLOOP_WINDOW, min_itl_samples=4,
                min_prefill_chunk=OPENLOOP_CHUNK, start_level=1))
        arrivals = _poisson_arrivals(rate_rps, n_req, seed=4242)
        rids = _drive_open_loop(sched, prompts, arrivals, max_news, ctl)
        res = sched.results
        ok = [r for r in rids if res[r].status == "OK"]
        elapsed = max(sched.elapsed, 1e-9)
        stat = {
            "offered_rps": rate_rps,
            "ok": len(ok),
            "shed": sum(res[r].status == "SHED" for r in rids),
            "attained_ok_rps": len(ok) / elapsed,
            "goodput_tokens_per_s": sum(res[r].logical_tokens
                                        for r in ok) / elapsed,
            "admitted_itl_p99_s": _itl_p99_s(sched, ok),
            "elapsed_s": sched.elapsed,
            "ticks": sched.ticks,
        }
        if ctl is not None:
            stat["controller_max_level"] = max(
                (h["level"] for h in ctl.history), default=0)
            stat["controller_windows"] = len(ctl.history)
        assert sched.alloc.free_count == sched.num_pages, "leaked pages"
        assert int(sched.alloc.pinned.sum()) == 0, "leaked pins"
        return stat

    unloaded = run_rate(rates_x[0] * capacity_rps)
    unloaded_itl = max(unloaded["admitted_itl_p99_s"], 1e-9)
    target_itl = OPENLOOP_SLO_MARGIN * unloaded_itl
    slo_itl = OPENLOOP_SLO_BOUND * unloaded_itl
    out = []
    for rx in rates_x:
        rate = rx * capacity_rps
        static = unloaded if rx == rates_x[0] else run_rate(rate)
        adaptive = run_rate(rate, target_itl=target_itl)
        for stat in (static, adaptive):
            stat["meets_slo"] = stat["admitted_itl_p99_s"] <= slo_itl
            stat["goodput_under_slo_tokens_per_s"] = \
                stat["goodput_tokens_per_s"] if stat["meets_slo"] else 0.0
        out.append({
            "kind": "openloop", "method": "greedy", "rows": OPENLOOP_ROWS,
            "n_requests": n_req, "prompt_len": max(len(p) for p in prompts),
            "prefill_chunk": OPENLOOP_CHUNK, "max_queue": OPENLOOP_QUEUE,
            "page_size": PAGE_SIZE,
            "capacity_rps": capacity_rps, "rate_x_capacity": rx,
            "offered_rps": rate,
            "unloaded_itl_p99_s": unloaded_itl,
            "slo_itl_p99_s": slo_itl,
            "controller_target_itl_p99_s": target_itl,
            "static": static, "adaptive": adaptive,
            "static_itl_vs_unloaded": static["admitted_itl_p99_s"]
            / unloaded_itl,
            "adaptive_itl_vs_unloaded": adaptive["admitted_itl_p99_s"]
            / unloaded_itl,
        })
    return out


def run(cfg, params):
    kcfg = _kcfg()
    fan_out = kcfg.num_branches
    rows_pool = 2 * fan_out
    out = []
    # warm the jit caches so the timed comparison measures steady-state
    # serving, not compiles: prefill is keyed on prompt length (warm every
    # distinct length — the sequential pass runs first and would otherwise
    # absorb those compiles), decode on batch shape (one request walks the
    # whole bucket chain; one scheduler run compiles the pool shapes)
    warm = _prompts(max(DEPTHS + PAGED_DEPTHS))
    max_seq = max(len(p) for p in warm) + kcfg.max_new_tokens
    for p in warm:
        engine._prefill_one(params, cfg, p, max_seq)
        # admission prefills now run through PROMPT-sized transient
        # caches (PR 5 sizing fix), so warm those shapes too — one per
        # distinct prompt length per backend rounding
        engine._prefill_one(params, cfg, p, len(p))
        engine._prefill_one(params, cfg, p,
                            -(-len(p) // PAGE_SIZE) * PAGE_SIZE)

    def warm_decode_shapes(ms):
        # BoN's eager EOS-row release means the sequential engine can hit
        # ANY survivor batch size 1..fan_out; compile every decode + row-
        # sampling shape up front so none lands inside a timed region
        for n in range(1, fan_out + 1):
            cache = init_cache(cfg, n, ms)
            engine._model_step(params, cfg, jnp.zeros((n,), jnp.int32),
                               jnp.int32(4), cache)
            sampler.sample_rows(
                jnp.zeros((n, 2), jnp.uint32),
                jnp.zeros((n, cfg.vocab_size), jnp.float32),
                jnp.zeros((n,), bool), kcfg, want_picked_lp=True)
            sampler.sample_rows(
                jnp.zeros((n, 2), jnp.uint32),
                jnp.zeros((n, cfg.vocab_size), jnp.float32),
                jnp.zeros((n,), bool), kcfg)
            sampler.picked_logprob(
                jnp.zeros((n, cfg.vocab_size), jnp.float32),
                jnp.zeros((n,), jnp.int32))

    warm_decode_shapes(max_seq)
    for method in BENCH_METHODS:
        _run_sequential(cfg, params, kcfg, method, warm[:1], max_seq)
        # full warm list: the install scatter is keyed on the transient
        # cache's (prompt-sized) shape, one specialization per length
        _run_scheduled(cfg, params, kcfg, method, warm, max_seq, rows_pool)

    for method in BENCH_METHODS:
        for depth in DEPTHS:
            prompts = _prompts(depth)
            gens_s, toks_s, dt_s = _run_sequential(
                cfg, params, kcfg, method, prompts, max_seq)
            gens_c, tp = _run_scheduled(
                cfg, params, kcfg, method, prompts, max_seq, rows_pool)
            assert all(a.tokens == b.tokens for a, b in zip(gens_s, gens_c)), \
                f"{method}: scheduler diverged from sequential serving"
            seq_tps = toks_s / max(dt_s, 1e-9)
            out.append({
                "kind": "continuous", "method": method, "depth": depth,
                "rows": rows_pool,
                "seq_tokens_per_s": seq_tps,
                "cb_tokens_per_s": tp["tokens_per_s"],
                "speedup": tp["tokens_per_s"] / max(seq_tps, 1e-9),
                "row_utilization": tp["row_utilization"],
                "ticks": tp["ticks"],
                "seq_time_s": dt_s, "cb_time_s": tp["time_s"],
                "tick_breakdown_us": _tick_breakdown_us(tp),
            })

    # ---- contiguous vs paged at equal KV token budget, mixed lengths.
    # Contiguous: rows_pool rows × max_seq slots each. Paged: the same
    # slot budget cut into pages, spread over more row slots — admission
    # is bounded by pages actually needed, not worst-case rows.
    max_seq_p = -(-max_seq // PAGE_SIZE) * PAGE_SIZE
    num_pages = rows_pool * max_seq_p // PAGE_SIZE
    # 3× fan-out row slots: enough to hold every fan-out the page budget
    # can admit (pages bind first) without paying for a wider model step
    rows_paged = 3 * fan_out
    # warm every shape the comparison touches: prefill at the padded
    # max_seq, each pool's decode shape, and — because the KAPPA
    # controller jit is keyed on the whole kcfg — every mixed max_new
    # variant, in every mode (the PR 1 run goes first and would
    # otherwise absorb those compiles into its timing)
    for p in warm:
        engine._prefill_one(params, cfg, p, max_seq_p)
    warm_decode_shapes(max_seq_p)
    warm_mixed = _mixed_max_new(len(warm))
    for method in PAGED_METHODS:
        _run_scheduled(cfg, params, kcfg, method, warm, max_seq_p,
                       rows_pool, max_news=warm_mixed)
        _run_scheduled(cfg, params, kcfg, method, warm, max_seq_p,
                       rows_pool, max_news=warm_mixed, fused_sampling=False)
        _run_scheduled(cfg, params, kcfg, method, warm, max_seq_p,
                       rows_paged, paged=True, max_news=warm_mixed,
                       page_size=PAGE_SIZE, num_pages=num_pages)
    for method in PAGED_METHODS:
        for depth in PAGED_DEPTHS:
            prompts = _prompts(depth)
            max_news = _mixed_max_new(depth)
            runs = {
                "pr1": lambda: _run_scheduled(
                    cfg, params, kcfg, method, prompts, max_seq_p,
                    rows_pool, max_news=max_news, fused_sampling=False),
                "cont": lambda: _run_scheduled(
                    cfg, params, kcfg, method, prompts, max_seq_p,
                    rows_pool, max_news=max_news),
                "paged": lambda: _run_scheduled(
                    cfg, params, kcfg, method, prompts, max_seq_p,
                    rows_paged, paged=True, max_news=max_news,
                    page_size=PAGE_SIZE, num_pages=num_pages),
            }
            # interleaved best-of-R: each rep times all three modes
            # back-to-back, so multi-second machine speed phases hit
            # every mode instead of whichever block they land on; best
            # wall clock per mode is then comparable (token streams are
            # deterministic — only timing varies between reps)
            gens, tps = {}, {}
            for _ in range(PAGED_REPS):
                for mode, fn in runs.items():
                    g, tp = fn()
                    gens[mode] = g
                    if mode not in tps or tp["tokens_per_s"] \
                            > tps[mode]["tokens_per_s"]:
                        tps[mode] = tp
            gens_1, gens_c, gens_p = gens["pr1"], gens["cont"], gens["paged"]
            tp_1, tp_c, tp_p = tps["pr1"], tps["cont"], tps["paged"]
            assert all(a.tokens == b.tokens == c.tokens
                       for a, b, c in zip(gens_1, gens_c, gens_p)), \
                "paged/fused serving diverged from the PR 1 baseline"
            if method == "kappa":
                # batched-controller contract (the acceptance criterion):
                # the fused modes make at most ONE controller dispatch
                # and ONE controller-carrying blocking transfer per tick,
                # no matter how many kappa requests are in flight
                for mode in ("cont", "paged"):
                    tp = tps[mode]
                    assert tp["controller_dispatches"] <= tp["ticks"], \
                        f"{mode}: {tp['controller_dispatches']} controller " \
                        f"dispatches over {tp['ticks']} ticks"
                    assert tp["controller_syncs"] == \
                        tp["controller_dispatches"]
            out.append({
                "kind": "paged", "method": method, "depth": depth,
                "rows_contiguous": rows_pool, "rows_paged": rows_paged,
                "page_size": PAGE_SIZE, "num_pages": num_pages,
                "kv_slot_budget": rows_pool * max_seq_p,
                "pr1_tokens_per_s": tp_1["tokens_per_s"],
                "contiguous_tokens_per_s": tp_c["tokens_per_s"],
                "paged_tokens_per_s": tp_p["tokens_per_s"],
                "fused_sampling_speedup": tp_c["tokens_per_s"]
                / max(tp_1["tokens_per_s"], 1e-9),
                "paged_vs_contiguous": tp_p["tokens_per_s"]
                / max(tp_c["tokens_per_s"], 1e-9),
                "paged_speedup": tp_p["tokens_per_s"]
                / max(tp_1["tokens_per_s"], 1e-9),
                "contiguous_row_utilization": tp_c["row_utilization"],
                "paged_row_utilization": tp_p["row_utilization"],
                "page_utilization": tp_p["page_utilization"],
                "contiguous_ticks": tp_c["ticks"],
                "paged_ticks": tp_p["ticks"],
                "pr1_time_s": tp_1["time_s"],
                "contiguous_time_s": tp_c["time_s"],
                "paged_time_s": tp_p["time_s"],
                "pr1_tick_breakdown_us": _tick_breakdown_us(tp_1),
                "paged_tick_breakdown_us": _tick_breakdown_us(tp_p),
                "paged_controller_dispatches": tp_p["controller_dispatches"],
                "paged_controller_syncs": tp_p["controller_syncs"],
            })
    out.extend(_fanout_scenario(cfg, params))
    out.extend(_int8_capacity_scenario(cfg, params))
    out.extend(_interleave_scenario(cfg, params))
    out.extend(_prefix_scenario(cfg, params))
    out.extend(_overload_scenario(cfg, params))
    out.extend(_openloop_scenario(cfg, params))
    return out


def emit_csv(rows):
    out = []
    for r in rows:
        if r["kind"] == "continuous":
            name = f"throughput/{r['method']}_depth{r['depth']}"
            us = r["cb_time_s"] * 1e6 / max(r["ticks"], 1)
            derived = (f"seq_tok_s={r['seq_tokens_per_s']:.1f};"
                       f"cb_tok_s={r['cb_tokens_per_s']:.1f};"
                       f"speedup={r['speedup']:.2f};"
                       f"util={r['row_utilization']:.2f}")
        elif r["kind"] == "interleave":
            name = f"throughput/interleave_chunk{r['prefill_chunk']}"
            us = r["chunked_itl_p99_s"] * 1e6
            derived = (f"base_itl_p99_us={r['baseline_itl_p99_s'] * 1e6:.0f};"
                       f"oneshot_itl_p99_us={r['oneshot_itl_p99_s'] * 1e6:.0f};"
                       f"chunked_itl_p99_us={r['chunked_itl_p99_s'] * 1e6:.0f};"
                       f"chunked_ratio={r['chunked_vs_baseline_itl_p99']:.2f};"
                       f"ttft_long_s={r['chunked_ttft_long_s']:.3f}")
        elif r["kind"] == "prefix":
            name = f"throughput/prefix_depth{r['depth']}"
            us = r["time_s"] * 1e6 / max(r["ticks"], 1)
            derived = (f"hit_rate={r['prefix_hit_rate']:.2f};"
                       f"saved_frac={r['prefill_tokens_saved_frac']:.2f};"
                       f"saved_toks={r['prefix_tokens_saved']};"
                       f"cached_tok_s={r['cached_tokens_per_s']:.1f};"
                       f"uncached_tok_s={r['uncached_tokens_per_s']:.1f};"
                       f"evictions={r['prefix_evictions']}")
        elif r["kind"] == "overload":
            name = f"throughput/overload_burst{r['burst']}"
            us = r["overload_itl_p99_s"] * 1e6
            derived = (f"base_itl_p99_us={r['baseline_itl_p99_s'] * 1e6:.0f};"
                       f"over_itl_p99_us={r['overload_itl_p99_s'] * 1e6:.0f};"
                       f"ratio={r['overload_vs_baseline_itl_p99']:.2f};"
                       f"shed_rate={r['shed_rate']:.2f};"
                       f"miss_rate={r['deadline_miss_rate']:.2f};"
                       f"goodput_tok_s={r['goodput_tokens_per_s']:.1f}")
        elif r["kind"] == "openloop":
            name = f"throughput/openloop_{r['rate_x_capacity']:g}x"
            us = r["adaptive"]["admitted_itl_p99_s"] * 1e6
            derived = (f"offered_rps={r['offered_rps']:.2f};"
                       f"static_itl_ratio={r['static_itl_vs_unloaded']:.2f};"
                       f"adaptive_itl_ratio="
                       f"{r['adaptive_itl_vs_unloaded']:.2f};"
                       f"static_goodput_tok_s="
                       f"{r['static']['goodput_tokens_per_s']:.1f};"
                       f"adaptive_goodput_tok_s="
                       f"{r['adaptive']['goodput_tokens_per_s']:.1f};"
                       f"static_shed={r['static']['shed']};"
                       f"adaptive_shed={r['adaptive']['shed']}")
        elif r["kind"] == "int8":
            name = f"throughput/int8_fanout{r['fan_out']}"
            us = r["int8_time_s"] * 1e6 / max(r["int8_ticks"], 1)
            derived = (f"budget_kb={r['page_budget_bytes'] // 1024};"
                       f"pages_fp={r['num_pages_fp']};"
                       f"pages_int8={r['num_pages_int8']};"
                       f"peak_req_fp={r['peak_concurrent_fp']};"
                       f"peak_req_int8={r['peak_concurrent_int8']};"
                       f"admit_ratio={r['admit_ratio']:.2f};"
                       f"acc_kappa={r['acc_kappa_int8']:.2f}"
                       f"/{r['acc_kappa_fp']:.2f};"
                       f"acc_bon={r['acc_bon_int8']:.2f}"
                       f"/{r['acc_bon_fp']:.2f}")
        elif r["kind"] == "fanout":
            name = f"throughput/fanout{r['fan_out']}_depth{r['depth']}"
            us = r["time_s"] * 1e6 / max(r["ticks"], 1)
            derived = (f"tok_s={r['tokens_per_s']:.1f};"
                       f"num_pages={r['num_pages']};"
                       f"bcast_worst={r['broadcast_worst_pages_per_req']};"
                       f"page_peak={r['page_peak']};"
                       f"savings={r['shared_page_savings']:.2f};"
                       f"preemptions={r['preemptions']}")
        else:
            name = f"throughput/paged_{r['method']}_depth{r['depth']}"
            us = r["paged_time_s"] * 1e6 / max(r["paged_ticks"], 1)
            bd1, bdp = r["pr1_tick_breakdown_us"], r["paged_tick_breakdown_us"]
            derived = (f"pr1_tok_s={r['pr1_tokens_per_s']:.1f};"
                       f"cont_tok_s={r['contiguous_tokens_per_s']:.1f};"
                       f"paged_tok_s={r['paged_tokens_per_s']:.1f};"
                       f"paged_speedup={r['paged_speedup']:.2f};"
                       f"page_util={r['page_utilization']:.2f};"
                       f"pr1_host_us={bd1['host']:.0f};"
                       f"paged_host_us={bdp['host']:.0f};"
                       f"paged_ctrl_us={bdp['controller']:.0f}")
        out.append(f"{name},{us:.1f},{derived}")
    return out


def openloop_smoke():
    """CI entry (``--openloop-smoke``): two-rate open-loop sweep on an
    untrained toy model — asserts the goodput-under-SLO curve is
    produced for both admission modes and (inside the scenario) that
    every run drains with zero leaked pages/pins."""
    cfg = get_config("deepseek-r1-distill-qwen-1.5b").reduced(
        num_layers=2, d_model=64, vocab_size=tok.VOCAB_SIZE)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rows = _openloop_scenario(cfg, params, smoke=True)
    print("name,us_per_call,derived")
    for line in emit_csv(rows):
        print(line)
    assert len(rows) == len(OPENLOOP_SMOKE_RATES_X)
    for r in rows:
        for mode in ("static", "adaptive"):
            assert r[mode]["goodput_tokens_per_s"] >= 0.0
            assert "goodput_under_slo_tokens_per_s" in r[mode]
            assert r[mode]["ok"] > 0, f"{mode} starved every request"
    print(f"# openloop smoke: {len(rows)} rates x 2 admission modes, "
          f"goodput curve produced, zero leaks after drain -> PASS")


if __name__ == "__main__":
    import sys
    if "--openloop-smoke" in sys.argv:
        openloop_smoke()
        sys.exit(0)
    cfg, params = common.bench_model()
    t0 = time.time()
    rows = run(cfg, params)
    print("name,us_per_call,derived")
    for line in emit_csv(rows):
        print(line)
    common.write_bench_json("throughput", rows, time.time() - t0)
    kap = {r["depth"]: r for r in rows
           if r["kind"] == "continuous" and r["method"] == "kappa"}
    for depth, r in sorted(kap.items()):
        if depth >= 4:
            verdict = "PASS" if r["speedup"] > 1.0 else "FAIL"
            print(f"# depth={depth}: continuous batching speedup "
                  f"{r['speedup']:.2f}x -> {verdict}")
    for r in rows:
        if r["kind"] == "paged" and r["method"] == "kappa":
            bd1, bdp = r["pr1_tick_breakdown_us"], r["paged_tick_breakdown_us"]
            print(f"# kappa depth={r['depth']}: per-tick controller cost "
                  f"{bd1['host']:.0f}us host (pr1: one dispatch+sync per "
                  f"request) -> {bdp['controller']:.0f}us pooled dispatch + "
                  f"{bdp['host']:.0f}us host "
                  f"({r['paged_controller_dispatches']} dispatches / "
                  f"{r['paged_ticks']} ticks)")
    paged_rows = [r for r in rows if r["kind"] == "paged" and r["depth"] >= 8]
    for r in paged_rows:
        print(f"# {r['method']} depth={r['depth']}: paged+fused vs PR1 "
              f"contiguous {r['paged_speedup']:.2f}x "
              f"(fused sampling alone {r['fused_sampling_speedup']:.2f}x,"
              f" paging alone {r['paged_vs_contiguous']:.2f}x)")
    if paged_rows:
        best = max(paged_rows, key=lambda r: r["paged_speedup"])
        verdict = "PASS" if best["paged_speedup"] >= 1.5 else "FAIL"
        print(f"# acceptance: paged+batched-sampling vs PR1 contiguous at "
              f"queue depth >= 8: {best['paged_speedup']:.2f}x "
              f"({best['method']}, depth {best['depth']}; >=1.5 target) "
              f"-> {verdict}")
    for r in rows:
        if r["kind"] == "interleave":
            ratio = r["chunked_vs_baseline_itl_p99"]
            # "~1.2x": p99 over ~150 window samples rides 1-2 noise
            # spikes on the CPU container (±20% run-to-run), so the
            # hard gate sits at 1.35 and requires the one-shot stall to
            # actually reproduce (>=2x) for the comparison to mean much
            verdict = "PASS" if (ratio <= 1.35 and
                                 r["oneshot_vs_baseline_itl_p99"] >= 2.0) \
                else "FAIL"
            print(f"# interleave: long-prompt ({r['long_prompt_len']} tok) "
                  f"admission over {r['in_flight']} in-flight requests — "
                  f"in-flight ITL p99 {r['baseline_itl_p99_s'] * 1e3:.1f}ms "
                  f"baseline / {r['oneshot_itl_p99_s'] * 1e3:.1f}ms one-shot "
                  f"/ {r['chunked_itl_p99_s'] * 1e3:.1f}ms chunked "
                  f"({ratio:.2f}x baseline, <=1.2 target; one-shot "
                  f"{r['oneshot_vs_baseline_itl_p99']:.2f}x); long TTFT "
                  f"{r['chunked_ttft_long_s']:.3f}s chunked vs "
                  f"{r['oneshot_ttft_long_s']:.3f}s one-shot -> {verdict}")
    for r in rows:
        if r["kind"] == "prefix":
            verdict = "PASS" if (r["prefill_tokens_saved_frac"] >= 0.5
                                 and r["prefix_hit_rate"] > 0) else "FAIL"
            print(f"# prefix: {r['depth']} requests sharing a "
                  f"{r['preamble_len']}-token preamble — hit rate "
                  f"{r['prefix_hit_rate']:.2f}, "
                  f"{r['prefix_tokens_saved']}/{r['prompt_tokens']} prefill "
                  f"tokens saved ({r['prefill_tokens_saved_frac']:.0%}, "
                  f">=50% target), cached serving "
                  f"{r['cached_vs_uncached']:.2f}x uncached -> {verdict}")
    for r in rows:
        if r["kind"] == "overload":
            ratio = r["overload_vs_baseline_itl_p99"]
            verdict = "PASS" if ratio <= 1.5 else "FAIL"
            print(f"# overload: {r['burst']}-request burst over a "
                  f"{r['depth']}-deep unloaded pool (queue bound "
                  f"{r['max_queue']}, {r['tick_budget']}-tick budget) — "
                  f"{r['served_ok']} served, shed rate {r['shed_rate']:.0%}, "
                  f"deadline-miss rate {r['deadline_miss_rate']:.0%}, "
                  f"goodput {r['goodput_tokens_per_s']:.1f} tok/s; "
                  f"admitted ITL p99 {ratio:.2f}x unloaded "
                  f"(<=1.5 target) -> {verdict}")
    ol = [r for r in rows if r["kind"] == "openloop"]
    for r in ol:
        a, s = r["adaptive"], r["static"]
        print(f"# openloop {r['rate_x_capacity']:g}x capacity "
              f"({r['offered_rps']:.2f} req/s offered): admitted ITL p99 "
              f"{r['static_itl_vs_unloaded']:.2f}x (static) / "
              f"{r['adaptive_itl_vs_unloaded']:.2f}x (adaptive) unloaded; "
              f"goodput {s['goodput_tokens_per_s']:.1f} vs "
              f"{a['goodput_tokens_per_s']:.1f} tok/s "
              f"(under-SLO {s['goodput_under_slo_tokens_per_s']:.1f} vs "
              f"{a['goodput_under_slo_tokens_per_s']:.1f}); shed "
              f"{s['shed']} vs {a['shed']}")
    if ol:
        sep = [r for r in ol
               if r["static_itl_vs_unloaded"] > OPENLOOP_SLO_BOUND
               and r["adaptive_itl_vs_unloaded"] <= OPENLOOP_SLO_BOUND]
        verdict = "PASS" if sep else "FAIL"
        at = (f" at {sep[0]['rate_x_capacity']:g}x capacity"
              if sep else "")
        print(f"# acceptance: adaptive admission holds admitted ITL p99 "
              f"<= {OPENLOOP_SLO_BOUND}x unloaded at an offered rate "
              f"where static admission exceeds it{at} -> {verdict}")
    for r in rows:
        if r["kind"] == "int8":
            verdict = "PASS" if (r["admit_ratio"] >= 1.8
                                 and r["parity_ok"]) else "FAIL"
            print(f"# int8 KV: equal {r['page_budget_bytes'] // 1024}KiB "
                  f"budget holds {r['num_pages_int8']} int8 pages vs "
                  f"{r['num_pages_fp']} fp — peak "
                  f"{r['peak_concurrent_int8']} concurrent fan-out "
                  f"requests vs {r['peak_concurrent_fp']} "
                  f"({r['admit_ratio']:.1f}x, >=1.8 target); answer "
                  f"accuracy kappa {r['acc_kappa_int8']:.2f} vs "
                  f"{r['acc_kappa_fp']:.2f} fp, bon "
                  f"{r['acc_bon_int8']:.2f} vs {r['acc_bon_fp']:.2f} fp "
                  f"-> {verdict}")
    for r in rows:
        if r["kind"] == "fanout":
            print(f"# fanout N={r['fan_out']} depth={r['depth']}: served in "
                  f"{r['num_pages']} pages (broadcast needed "
                  f"{r['broadcast_worst_pages_per_req']}/request — would "
                  f"raise at submit), peak {r['page_peak']} pages, "
                  f"{r['shared_page_savings']:.0%} shared-page savings, "
                  f"{r['preemptions']} preemptions -> PASS")
