"""Serving throughput: sequential vs continuous-batched decoding across
methods and queue depths.

Sequential serving decodes one request at a time — after KAPPA/ST-BoN
prune to one survivor, the device runs a single branch row for the whole
EOS tail. The continuous-batching scheduler backfills freed rows with
queued prefills, so the same hardware row budget serves several requests
per step. Expectation (acceptance criterion): continuous-batched KAPPA
achieves higher aggregate tokens/s than sequential serving at queue
depth >= 4 on the toy bench model.

Both modes decode the same prompts with the same per-request RNG keys and
the same max_seq, so their outputs are token-for-token identical — the
comparison is pure wall-clock.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common
from repro.configs.base import KappaConfig
from repro.data import tasks
from repro.data import tokenizer as tok
from repro.launch.serve import _strategy_factory
from repro.serving import engine
from repro.serving.scheduler import ContinuousBatchingScheduler

DEPTHS = [1, 4, 8] if common.FULL else [1, 4]
BENCH_METHODS = ["kappa", "stbon", "bon"]


def _kcfg(n: int = 5) -> KappaConfig:
    return KappaConfig(num_branches=n, max_new_tokens=common.MAX_NEW,
                       **common.KCFG_KW)


def _prompts(depth: int):
    probs = tasks.make_dataset(1234, depth, **common.DATASET_KW)
    return [np.array(p.prompt) for p in probs]


def _run_sequential(cfg, params, kcfg, method, prompts, max_seq):
    factory = _strategy_factory(method, kcfg)
    t0 = time.time()
    gens = [engine._decode_loop(params, cfg, kcfg, p, jax.random.PRNGKey(i),
                                factory(), eos_id=tok.EOS, bos_id=tok.BOS,
                                max_seq=max_seq)
            for i, p in enumerate(prompts)]
    dt = time.time() - t0
    toks = sum(g.logical_tokens for g in gens)
    return gens, toks, dt


def _run_scheduled(cfg, params, kcfg, method, prompts, max_seq, rows):
    factory = _strategy_factory(method, kcfg)
    sched = ContinuousBatchingScheduler(
        params, cfg, kcfg, rows=rows, max_seq=max_seq, method=method,
        eos_id=tok.EOS, bos_id=tok.BOS, strategy_factory=factory)
    rids = [sched.submit(p, jax.random.PRNGKey(i))
            for i, p in enumerate(prompts)]
    res = sched.run()
    tp = sched.throughput()
    return [res[r] for r in rids], tp


def run(cfg, params):
    kcfg = _kcfg()
    rows_pool = 2 * kcfg.num_branches
    out = []
    # warm the jit caches so the timed comparison measures steady-state
    # serving, not compiles: prefill is keyed on prompt length (warm every
    # distinct length — the sequential pass runs first and would otherwise
    # absorb those compiles), decode on batch shape (one request walks the
    # whole bucket chain; one scheduler run compiles the pool shapes)
    warm = _prompts(max(DEPTHS))
    max_seq = max(len(p) for p in warm) + kcfg.max_new_tokens
    for p in warm:
        engine._prefill_one(params, cfg, p, max_seq)
    for method in BENCH_METHODS:
        _run_sequential(cfg, params, kcfg, method, warm[:1], max_seq)
        _run_scheduled(cfg, params, kcfg, method, warm[:1], max_seq, rows_pool)

    for method in BENCH_METHODS:
        for depth in DEPTHS:
            prompts = _prompts(depth)
            gens_s, toks_s, dt_s = _run_sequential(
                cfg, params, kcfg, method, prompts, max_seq)
            gens_c, tp = _run_scheduled(
                cfg, params, kcfg, method, prompts, max_seq, rows_pool)
            assert all(a.tokens == b.tokens for a, b in zip(gens_s, gens_c)), \
                f"{method}: scheduler diverged from sequential serving"
            seq_tps = toks_s / max(dt_s, 1e-9)
            out.append({
                "method": method, "depth": depth, "rows": rows_pool,
                "seq_tokens_per_s": seq_tps,
                "cb_tokens_per_s": tp["tokens_per_s"],
                "speedup": tp["tokens_per_s"] / max(seq_tps, 1e-9),
                "row_utilization": tp["row_utilization"],
                "ticks": tp["ticks"],
                "seq_time_s": dt_s, "cb_time_s": tp["time_s"],
            })
    return out


def emit_csv(rows):
    out = []
    for r in rows:
        name = f"throughput/{r['method']}_depth{r['depth']}"
        us = r["cb_time_s"] * 1e6 / max(r["ticks"], 1)
        derived = (f"seq_tok_s={r['seq_tokens_per_s']:.1f};"
                   f"cb_tok_s={r['cb_tokens_per_s']:.1f};"
                   f"speedup={r['speedup']:.2f};"
                   f"util={r['row_utilization']:.2f}")
        out.append(f"{name},{us:.1f},{derived}")
    return out


if __name__ == "__main__":
    cfg, params = common.bench_model()
    rows = run(cfg, params)
    print("name,us_per_call,derived")
    for line in emit_csv(rows):
        print(line)
    kap = {r["depth"]: r for r in rows if r["method"] == "kappa"}
    for depth, r in sorted(kap.items()):
        if depth >= 4:
            verdict = "PASS" if r["speedup"] > 1.0 else "FAIL"
            print(f"# depth={depth}: continuous batching speedup "
                  f"{r['speedup']:.2f}x -> {verdict}")
