"""Kernel microbenchmarks: wall-time of the jnp oracle path on CPU (the
Pallas kernels themselves run in interpret mode here — TPU wall-time is
the dry-run/roofline's job) + derived per-call traffic, proving the
fusion arithmetic: fused_score reads the logits row once vs 4×.

Also *executes* every Pallas kernel wrapper end to end (fused_score,
decode_attn contiguous + paged, rwkv6_scan) at small shapes — the CI
smoke step runs this module so a broken pallas_call surfaces on push,
not only in the unit-test sweeps."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.signals import compute_signals, log_softmax, reference_log_q
from repro.kernels.decode_attn.ops import (decode_attn, paged_decode_attn,
                                           paged_prefill_attn)
from repro.kernels.fused_score.ops import fused_score
from repro.kernels.rwkv6_scan.ops import rwkv6_scan


def _time(fn, *args, iters=20):
    fn(*args)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(cfg=None, params=None):
    rows = []
    for B, V in [(5, 50_000), (20, 150_000)]:
        k1, k2 = jax.random.split(jax.random.PRNGKey(B))
        logits = jax.random.normal(k1, (B, V))
        log_q = reference_log_q(jax.random.normal(k2, (V,)))

        fused = jax.jit(lambda l, q: compute_signals(l, q))
        us_fused = _time(fused, logits, log_q)

        def separate(l, q):
            lp = log_softmax(l)
            p = jnp.exp(lp)
            kl = jnp.sum(p * (lp - q), -1)
            conf = jnp.max(p, -1)
            ent = -jnp.sum(p * jnp.log(p + 1e-9), -1)
            return kl, conf, ent

        us_sep = _time(jax.jit(separate), logits, log_q)
        bytes_once = B * V * 4
        rows.append({"name": f"signals_B{B}_V{V}", "us_fused": us_fused,
                     "us_separate": us_sep, "row_bytes": bytes_once})
    rows.extend(_wrapper_smoke())
    return rows


def _wrapper_smoke():
    """Execute each Pallas kernel wrapper once and record its wall time
    (interpret mode off-TPU, so this is a does-it-run check, not a perf
    number — contiguous vs paged decode ride through the same shapes)."""
    out = []
    ks = jax.random.split(jax.random.PRNGKey(0), 8)

    logits = jax.random.normal(ks[0], (4, 4096))
    log_q = reference_log_q(jax.random.normal(ks[1], (4096,)))
    out.append({"name": "wrapper_fused_score",
                "us_fused": _time(lambda l, q: fused_score(l, q),
                                  logits, log_q, iters=3)})

    B, H, KV, hd, S = 2, 4, 2, 64, 128
    q = jax.random.normal(ks[2], (B, H, hd))
    k = jax.random.normal(ks[3], (B, S, KV, hd))
    v = jax.random.normal(ks[4], (B, S, KV, hd))
    out.append({"name": "wrapper_decode_attn",
                "us_fused": _time(
                    lambda *a: (decode_attn(*a),), q, k, v, 100, iters=3)})

    ps, MP, P = 32, 4, 9          # same 128 logical slots, paged
    kp = k.reshape(B * 2, ps * 2, KV, hd)[:, :ps]
    kp = jnp.concatenate([kp, jnp.zeros((P - B * 2, ps, KV, hd))], 0)
    vp = jnp.concatenate([v.reshape(B * 2, ps * 2, KV, hd)[:, :ps],
                          jnp.zeros((P - B * 2, ps, KV, hd))], 0)
    bt = jnp.array([[0, 1, 8, 8], [2, 3, 8, 8]], jnp.int32)
    pos = jnp.array([50, 60], jnp.int32)
    out.append({"name": "wrapper_paged_decode_attn",
                "us_fused": _time(
                    lambda *a: (paged_decode_attn(*a),), q, kp, vp, bt, pos,
                    iters=3)})

    # int8 pages: per-(page, slot, head) absmax scales, dequant in-kernel
    def q8(x):
        s = jnp.maximum(jnp.max(jnp.abs(x), -1), 1e-8) / 127.0
        qv = jnp.clip(jnp.round(x / s[..., None]), -127, 127).astype(jnp.int8)
        return qv, s

    kq, ksc = q8(kp)
    vq, vsc = q8(vp)
    out.append({"name": "wrapper_paged_decode_attn_int8",
                "us_fused": _time(
                    lambda *a: (paged_decode_attn(
                        *a, k_scales=ksc, v_scales=vsc),),
                    q, kq, vq, bt, pos, iters=3)})

    # paged chunk prefill: C tokens attend causally through the table
    C = 8
    qc = jax.random.normal(ks[7], (B, C, H, hd))
    pos0 = jnp.array([40, 56], jnp.int32)
    out.append({"name": "wrapper_paged_prefill_attn",
                "us_fused": _time(
                    lambda *a: (paged_prefill_attn(*a),), qc, kp, vp, bt,
                    pos0, iters=3)})
    out.append({"name": "wrapper_paged_prefill_attn_int8",
                "us_fused": _time(
                    lambda *a: (paged_prefill_attn(
                        *a, k_scales=ksc, v_scales=vsc),),
                    qc, kq, vq, bt, pos0, iters=3)})

    # the serving-layer wiring: attn_decode_paged with the paged kernel
    # forced on (the path TPU decode takes), K/V write included
    from repro.models import attention as attn_mod
    d = H * hd
    ap = attn_mod.init_attn(ks[5], d, H, KV, hd, False, jnp.float32)
    cache = attn_mod.init_paged_kv(P, ps, KV, hd, jnp.float32)
    x = jax.random.normal(ks[6], (B, 1, d))
    attn_mod.set_paged_kernel(True)
    try:
        out.append({"name": "wrapper_attn_decode_paged_wired",
                    "us_fused": _time(
                        lambda *a: attn_mod.attn_decode_paged(
                            *a, num_heads=H, num_kv_heads=KV, head_dim=hd,
                            rope_theta=1e4, use_rope=True),
                        ap, x, pos, cache, bt, iters=3)})
        # quantized edition of the same wiring — the regression smoke
        # for the silent int8 fallback (attention must still trace the
        # Pallas kernel when the pool carries scale leaves)
        cache8 = attn_mod.init_paged_kv(P, ps, KV, hd, jnp.float32,
                                        quantized=True)
        attn_mod.reset_paged_backend_counts()
        out.append({"name": "wrapper_attn_decode_paged_wired_int8",
                    "us_fused": _time(
                        lambda *a: attn_mod.attn_decode_paged(
                            *a, num_heads=H, num_kv_heads=KV, head_dim=hd,
                            rope_theta=1e4, use_rope=True),
                        ap, x, pos, cache8, bt, iters=3)})
        counts = attn_mod.paged_backend_counts()
        assert counts["decode_oracle"] == 0, \
            f"int8 paged decode fell back to the gather oracle: {counts}"
    finally:
        attn_mod.set_paged_kernel(None)

    T, Hh, hd2 = 32, 2, 32
    r = jax.random.normal(ks[5], (1, T, Hh, hd2))
    kk = jax.random.normal(ks[6], (1, T, Hh, hd2))
    vv = jax.random.normal(ks[7], (1, T, Hh, hd2))
    w = jax.nn.sigmoid(kk) * 0.9 + 0.05
    u = jnp.zeros((Hh, hd2))
    out.append({"name": "wrapper_rwkv6_scan",
                "us_fused": _time(
                    lambda *a: rwkv6_scan(*a, chunk=16), r, kk, vv, w, u,
                    iters=3)})
    return out


def emit_csv(rows):
    out = []
    for r in rows:
        if "us_separate" in r:
            out.append(f"kernel_bench/{r['name']},{r['us_fused']:.1f},"
                       f"separate_us={r['us_separate']:.1f};"
                       f"row_bytes={r['row_bytes']}")
        else:
            out.append(f"kernel_bench/{r['name']},{r['us_fused']:.1f},"
                       f"wrapper_smoke=1")
    return out
