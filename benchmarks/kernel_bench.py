"""Kernel microbenchmarks: wall-time of the jnp oracle path on CPU (the
Pallas kernels themselves run in interpret mode here — TPU wall-time is
the dry-run/roofline's job) + derived per-call traffic, proving the
fusion arithmetic: fused_score reads the logits row once vs 4×."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.signals import compute_signals, log_softmax, reference_log_q


def _time(fn, *args, iters=20):
    fn(*args)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(cfg=None, params=None):
    rows = []
    for B, V in [(5, 50_000), (20, 150_000)]:
        k1, k2 = jax.random.split(jax.random.PRNGKey(B))
        logits = jax.random.normal(k1, (B, V))
        log_q = reference_log_q(jax.random.normal(k2, (V,)))

        fused = jax.jit(lambda l, q: compute_signals(l, q))
        us_fused = _time(fused, logits, log_q)

        def separate(l, q):
            lp = log_softmax(l)
            p = jnp.exp(lp)
            kl = jnp.sum(p * (lp - q), -1)
            conf = jnp.max(p, -1)
            ent = -jnp.sum(p * jnp.log(p + 1e-9), -1)
            return kl, conf, ent

        us_sep = _time(jax.jit(separate), logits, log_q)
        bytes_once = B * V * 4
        rows.append({"name": f"signals_B{B}_V{V}", "us_fused": us_fused,
                     "us_separate": us_sep, "row_bytes": bytes_once})
    return rows


def emit_csv(rows):
    return [f"kernel_bench/{r['name']},{r['us_fused']:.1f},"
            f"separate_us={r['us_separate']:.1f};row_bytes={r['row_bytes']}"
            for r in rows]
