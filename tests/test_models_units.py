"""Model-substrate unit tests: attention masking, ring caches, MoE
dispatch, RG-LRU/RWKV6 state passing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import rwkv6 as rwkv6_lib


def _attn_params(rng, d, h, kv, hd, bias=False):
    return attn.init_attn(rng, d, h, kv, hd, bias, jnp.float32)


def test_causal_mask_exact():
    """Token t must not see tokens > t: perturbing the future leaves
    logits at t unchanged."""
    d, h, kv, hd, S = 32, 4, 2, 8, 10
    p = _attn_params(jax.random.PRNGKey(0), d, h, kv, hd)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, S, d))
    pos = jnp.arange(S)
    y1 = attn.attn_forward(p, x, pos, num_heads=h, num_kv_heads=kv, head_dim=hd,
                           window=0, rope_theta=1e4, use_rope=True)
    x2 = x.at[:, -1].set(99.0)
    y2 = attn.attn_forward(p, x2, pos, num_heads=h, num_kv_heads=kv, head_dim=hd,
                           window=0, rope_theta=1e4, use_rope=True)
    np.testing.assert_allclose(np.asarray(y1[:, :-1]), np.asarray(y2[:, :-1]),
                               rtol=1e-5, atol=1e-5)


def test_sliding_window_blocks_far_past():
    """With window w, token t must not see tokens < t−w+1."""
    d, h, kv, hd, S, w = 32, 4, 2, 8, 12, 3
    p = _attn_params(jax.random.PRNGKey(0), d, h, kv, hd)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, S, d))
    pos = jnp.arange(S)
    y1 = attn.attn_forward(p, x, pos, num_heads=h, num_kv_heads=kv, head_dim=hd,
                           window=w, rope_theta=1e4, use_rope=True)
    x2 = x.at[:, 0].set(-55.0)  # outside every window for t >= w
    y2 = attn.attn_forward(p, x2, pos, num_heads=h, num_kv_heads=kv, head_dim=hd,
                           window=w, rope_theta=1e4, use_rope=True)
    np.testing.assert_allclose(np.asarray(y1[:, w:]), np.asarray(y2[:, w:]),
                               rtol=1e-5, atol=1e-5)


def test_chunked_prefill_equals_unchunked():
    """Query-chunked attention path ≡ single-block path (incl. a
    non-multiple length that exercises the padding branch)."""
    d, h, kv, hd = 32, 4, 2, 8
    p = _attn_params(jax.random.PRNGKey(0), d, h, kv, hd)
    for S in (attn.Q_CHUNK * 2, attn.Q_CHUNK + 37):
        x = jax.random.normal(jax.random.PRNGKey(1), (1, S, d)) * 0.3
        pos = jnp.arange(S)
        y_chunk = attn.attn_forward(p, x, pos, num_heads=h, num_kv_heads=kv,
                                    head_dim=hd, window=0, rope_theta=1e4,
                                    use_rope=True)
        old = attn.Q_CHUNK
        try:
            attn.Q_CHUNK = S + 1  # force the single-block path
            y_full = attn.attn_forward(p, x, pos, num_heads=h, num_kv_heads=kv,
                                       head_dim=hd, window=0, rope_theta=1e4,
                                       use_rope=True)
        finally:
            attn.Q_CHUNK = old
        np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_full),
                                   rtol=1e-4, atol=1e-4)


def test_ring_cache_decode_matches_full_cache():
    """Sliding-window decode with a ring cache ≡ full cache + window mask."""
    d, h, kv, hd, W = 32, 4, 2, 8, 4
    p = _attn_params(jax.random.PRNGKey(0), d, h, kv, hd)
    T = 10
    xs = jax.random.normal(jax.random.PRNGKey(1), (T, 1, 1, d)) * 0.5

    ring = attn.init_ring_cache(1, W, kv, hd, jnp.float32)
    full = attn.init_full_cache(1, T, kv, hd, jnp.float32)
    for t in range(T):
        yr, ring = attn.attn_decode(p, xs[t], jnp.int32(t), ring, num_heads=h,
                                    num_kv_heads=kv, head_dim=hd, window=W,
                                    rope_theta=1e4, use_rope=True)
        yf, full = attn.attn_decode(p, xs[t], jnp.int32(t), full, num_heads=h,
                                    num_kv_heads=kv, head_dim=hd, window=W,
                                    rope_theta=1e4, use_rope=True)
        np.testing.assert_allclose(np.asarray(yr), np.asarray(yf),
                                   rtol=1e-4, atol=1e-4, err_msg=f"t={t}")


# ----------------------------------------------------------------- MoE

def test_moe_dropless_equals_manual():
    """Dropless top-k routing ≡ per-token dense expert mixture."""
    d, ff, E, K = 16, 32, 4, 2
    p = moe_lib.init_moe(jax.random.PRNGKey(0), d, ff, E, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, d))
    y, aux = moe_lib.moe_ffn(p, x, num_experts=E, experts_per_tok=K,
                             capacity_factor=0.0)

    xt = np.asarray(x).reshape(-1, d)
    logits = xt @ np.asarray(p["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    top = np.argsort(-probs, axis=-1)[:, :K]
    manual = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        wsum = probs[t, top[t]].sum()
        for e in top[t]:
            h = np.maximum(xt[t] @ np.asarray(p["wg"][e]), 0)  # silu approx below
            h = (xt[t] @ np.asarray(p["wg"][e]))
            h = h / (1 + np.exp(-h)) * (xt[t] @ np.asarray(p["wu"][e]))
            manual[t] += (probs[t, e] / wsum) * (h @ np.asarray(p["wd"][e]))
    np.testing.assert_allclose(np.asarray(y).reshape(-1, d), manual,
                               rtol=2e-3, atol=2e-3)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """With capacity_factor≪1 some tokens must be dropped (zero output)."""
    d, ff, E, K = 8, 16, 4, 2
    p = moe_lib.init_moe(jax.random.PRNGKey(0), d, ff, E, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, d))
    y_drop, _ = moe_lib.moe_ffn(p, x, num_experts=E, experts_per_tok=K,
                                capacity_factor=0.1)
    y_full, _ = moe_lib.moe_ffn(p, x, num_experts=E, experts_per_tok=K,
                                capacity_factor=0.0)
    assert not np.allclose(np.asarray(y_drop), np.asarray(y_full))


# ------------------------------------------------------- recurrent blocks

def test_rglru_forward_equals_stepwise():
    d = 16
    p = rglru_lib.init_rglru(jax.random.PRNGKey(0), d, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 7, d)) * 0.5
    y_full, st_full = rglru_lib.rglru_forward(p, x)
    st = rglru_lib.init_rglru_state(2, d, jnp.float32)
    ys = []
    for t in range(7):
        y, st = rglru_lib.rglru_step(p, x[:, t:t + 1], st)
        ys.append(y)
    y_steps = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_steps),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_full["h"]), np.asarray(st["h"]),
                               rtol=2e-4, atol=2e-4)


def test_rglru_state_carries_across_segments():
    """forward(x) ≡ forward(x[:4]) then forward(x[4:], state)."""
    d = 16
    p = rglru_lib.init_rglru(jax.random.PRNGKey(0), d, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, d)) * 0.5
    y_all, _ = rglru_lib.rglru_forward(p, x)
    y1, st = rglru_lib.rglru_forward(p, x[:, :4])
    y2, _ = rglru_lib.rglru_forward(p, x[:, 4:], st)
    np.testing.assert_allclose(np.asarray(y_all),
                               np.asarray(jnp.concatenate([y1, y2], 1)),
                               rtol=2e-4, atol=2e-4)


def test_rwkv6_time_mix_forward_equals_stepwise():
    d, H, hd, ff = 32, 2, 16, 64
    p = rwkv6_lib.init_rwkv6(jax.random.PRNGKey(0), d, ff, H, hd, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, d)) * 0.5
    st0 = rwkv6_lib.init_rwkv6_state(1, d, H, hd, jnp.float32)
    y_full, stf = rwkv6_lib.time_mix(p, x, st0, num_heads=H, head_dim=hd)
    st = st0
    ys = []
    for t in range(6):
        y, st = rwkv6_lib.time_mix_step(p, x[:, t:t + 1], st, num_heads=H,
                                        head_dim=hd)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(stf["S"]), np.asarray(st["S"]),
                               rtol=2e-4, atol=2e-4)
