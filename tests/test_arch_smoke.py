"""Per-architecture smoke tests (deliverable f): instantiate the REDUCED
variant of each assigned family, run one forward/train step on CPU,
assert output shapes + no NaNs, and check prefill+decode ≡ teacher-forced
logits (the serving-correctness invariant)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, PAPER_ARCHS, get_config
from repro.models import decode_step, init_cache, init_params, prefill, train_logits
from repro.models.frontends import stub_frontend
from repro.training.train import init_train_state, train_step

ALL = ASSIGNED_ARCHS + PAPER_ARCHS


@pytest.mark.parametrize("arch", ALL)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch).reduced()
    rng = jax.random.PRNGKey(0)
    params = init_params(rng, cfg)
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    fe = stub_frontend(jax.random.PRNGKey(2), cfg, B)
    logits, aux = train_logits(params, cfg, tokens, fe)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ALL)
def test_prefill_decode_matches_teacher_forcing(arch):
    cfg = get_config(arch).reduced()
    rng = jax.random.PRNGKey(0)
    params = init_params(rng, cfg)
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    fe = stub_frontend(jax.random.PRNGKey(2), cfg, B)
    logits, _ = train_logits(params, cfg, tokens, fe)

    cache = init_cache(cfg, B, max_seq=32)
    pf, cache = prefill(params, cfg, tokens[:, :S - 1], cache, fe)
    np.testing.assert_allclose(np.asarray(pf), np.asarray(logits[:, S - 2]),
                               rtol=2e-4, atol=2e-4)
    n_prefix = cfg.frontend_tokens if (cfg.frontend and not cfg.is_encoder_decoder) else 0
    dec, cache = decode_step(params, cfg, tokens[:, S - 1],
                             jnp.int32(S - 1 + n_prefix), cache)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits[:, S - 1]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_train_step_no_nans(arch):
    cfg = get_config(arch).reduced()
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    mask = jnp.ones((B, S), jnp.float32)
    fe = stub_frontend(jax.random.PRNGKey(2), cfg, B)
    state, metrics = train_step(state, cfg, tokens, mask, jnp.int32(0), fe)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["gnorm"]))
    # params actually moved
    l0 = jax.tree.leaves(state.params)[0]
    assert bool(jnp.all(jnp.isfinite(l0)))
