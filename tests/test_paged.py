"""Paged KV pool: allocator invariants, paged decode correctness, and
the paged scheduler's token-for-token equivalence with both the
sequential engine and the contiguous scheduler (DESIGN.md §5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import KappaConfig
from repro.data import tokenizer as tok
from repro.models import decode_step, init_paged_cache, init_params
from repro.serving import cache as cache_lib
from repro.serving import engine
from repro.serving.cache import PageAllocator
from repro.serving.scheduler import ContinuousBatchingScheduler, PagedScheduler


# ---------------------------------------------------------- allocator

def _check_invariants(alloc: PageAllocator):
    """Free list and per-row ownership partition the physical pages."""
    owned_pages = []
    for r in range(alloc.rows):
        n = int(alloc.owned[r])
        row_pages = alloc.block[r]
        owned_pages.extend(int(p) for p in row_pages[:n])
        # owned prefix holds real pages, tail is all trash
        assert np.all(row_pages[:n] < alloc.num_pages)
        assert np.all(row_pages[n:] == alloc.trash)
    assert len(set(owned_pages)) == len(owned_pages), "double-owned page"
    assert set(owned_pages).isdisjoint(alloc.free_pages)
    assert sorted(owned_pages + list(alloc.free_pages)) == \
        list(range(alloc.num_pages))


def test_allocator_alloc_free_reuse():
    alloc = PageAllocator(8, 4, rows=4, max_pages=3)
    p0 = alloc.alloc_row(0, 3)
    p1 = alloc.alloc_row(1, 2)
    _check_invariants(alloc)
    assert alloc.used_count == 5 and alloc.free_count == 3
    alloc.free_row(0)
    _check_invariants(alloc)
    assert alloc.free_count == 6
    # freed pages are reusable by another row
    p2 = alloc.alloc_row(2, 3)
    _check_invariants(alloc)
    assert set(int(p) for p in p0) & set(int(p) for p in p2)
    assert alloc.pages_for(1) == 1 and alloc.pages_for(4) == 1 \
        and alloc.pages_for(5) == 2


def test_allocator_out_of_pages_and_misuse():
    alloc = PageAllocator(4, 4, rows=3, max_pages=4)
    alloc.alloc_row(0, 3)
    assert not alloc.can_alloc(2)
    with pytest.raises(ValueError):
        alloc.alloc_row(1, 2)           # only 1 page free
    with pytest.raises(ValueError):
        alloc.alloc_row(0, 1)           # row already owns pages
    with pytest.raises(ValueError):
        alloc.alloc_row(1, 5)           # > max_pages
    alloc.free_row(0)
    alloc.free_row(0)                   # double free is a no-op
    _check_invariants(alloc)
    assert alloc.free_count == 4


def test_allocator_churn_integrity():
    """Random prune→backfill churn never corrupts the block tables."""
    rng = np.random.RandomState(0)
    alloc = PageAllocator(32, 8, rows=12, max_pages=4)
    live = set()
    for _ in range(300):
        if live and (rng.rand() < 0.45 or len(live) == alloc.rows):
            r = rng.choice(sorted(live))
            alloc.free_row(r)
            live.discard(r)
        else:
            r = rng.choice([i for i in range(alloc.rows) if i not in live])
            n = rng.randint(1, alloc.max_pages + 1)
            if alloc.can_alloc(n):
                alloc.alloc_row(r, n)
                live.add(r)
        _check_invariants(alloc)
    for r in sorted(live):
        alloc.free_row(r)
    _check_invariants(alloc)
    assert alloc.free_count == alloc.num_pages


# ----------------------------------------------------- paged decode step

@pytest.fixture(scope="module")
def setup():
    cfg = get_config("deepseek-r1-distill-qwen-1.5b").reduced(
        num_layers=2, d_model=64, vocab_size=tok.VOCAB_SIZE)
    params = init_params(jax.random.PRNGKey(0), cfg)
    kcfg = KappaConfig(num_branches=4, max_new_tokens=20, max_cutoff=4,
                       horizon=6, window=8, mom_buckets=4)
    prompts = [
        np.array([tok.BOS, tok.PROB, 3, tok.PLUS, 4, tok.EQ, tok.QM]),
        np.array([tok.BOS, tok.PROB, 7, tok.PLUS, 2, tok.PLUS, 1, tok.EQ, tok.QM]),
        np.array([tok.BOS, tok.PROB, 5, tok.PLUS, 5, tok.EQ, tok.QM]),
    ]
    max_seq = max(len(p) for p in prompts) + kcfg.max_new_tokens
    return cfg, params, kcfg, prompts, max_seq


def test_decode_step_paged_matches_contiguous(setup):
    """A paged pool with a scrambled page layout produces bitwise the
    same logits as the contiguous cache — across two decode steps so the
    paged write path is exercised too."""
    cfg, params, kcfg, prompts, _ = setup
    ps, max_seq = 8, 32
    MP = max_seq // ps
    rows, num_pages = 3, 14
    prompt = prompts[0]
    _, c1 = engine._prefill_one(params, cfg, prompt, max_seq)
    pool_c = cache_lib.broadcast_batch(c1, rows)

    alloc = PageAllocator(num_pages, ps, rows, MP)
    alloc.free_pages = [7, 2, 9, 0, 4, 1, 3, 5, 6, 8, 10, 11, 12, 13]
    for r in range(rows):
        alloc.alloc_row(r, MP)
    pool_p = init_paged_cache(cfg, rows, num_pages, ps, max_seq)
    pool_p = cache_lib.install_paged(
        cfg, pool_p, jnp.arange(rows), jnp.asarray(alloc.block.reshape(-1)),
        cache_lib.broadcast_batch(c1, rows), ps)

    step = jax.jit(decode_step, static_argnums=(1,))
    pos = jnp.array([len(prompt)] * rows, jnp.int32)
    bt = jnp.asarray(alloc.block)
    lc, pool_c = step(params, cfg, jnp.array([5, 9, 7]), pos, pool_c)
    lp, pool_p = step(params, cfg, jnp.array([5, 9, 7]), pos, pool_p, bt)
    assert np.array_equal(np.asarray(lc), np.asarray(lp))
    lc2, _ = step(params, cfg, jnp.array([2, 3, 4]), pos + 1, pool_c)
    lp2, _ = step(params, cfg, jnp.array([2, 3, 4]), pos + 1, pool_p, bt)
    assert np.array_equal(np.asarray(lc2), np.asarray(lp2))


# -------------------------------------------------- scheduler equivalence

def _sequential(setup, method):
    cfg, params, kcfg, prompts, max_seq = setup
    fn = getattr(engine, f"generate_{method}")
    return [fn(params, cfg, kcfg, p, jax.random.PRNGKey(i), eos_id=tok.EOS,
               bos_id=tok.BOS, max_seq=max_seq)
            for i, p in enumerate(prompts)]


def _paged(setup, method, rows, page_size, num_pages):
    cfg, params, kcfg, prompts, max_seq = setup
    sched = PagedScheduler(
        params, cfg, kcfg, rows=rows, max_seq=max_seq, page_size=page_size,
        num_pages=num_pages, method=method, eos_id=tok.EOS, bos_id=tok.BOS)
    rids = [sched.submit(p, jax.random.PRNGKey(i))
            for i, p in enumerate(prompts)]
    res = sched.run()
    return sched, [res[r] for r in rids]


def test_paged_scheduler_matches_sequential(setup):
    """The issue's acceptance property, paged edition: a page-constrained
    pool (requests wait on pages, pruning backfills) reproduces the
    sequential engine token for token with the same per-request keys."""
    seq = _sequential(setup, "kappa")
    sched, conc = _paged(setup, "kappa", rows=6, page_size=8, num_pages=24)
    for s, c in zip(seq, conc):
        assert s.tokens == c.tokens
        assert s.chosen_branch == c.chosen_branch
        assert s.logical_tokens == c.logical_tokens
        assert s.compute_tokens == c.compute_tokens
        assert s.steps == c.steps
        assert s.compactions == c.compactions
    tp = sched.throughput()
    assert 0.0 < tp["page_utilization"] <= 1.0
    # pool fully drained: every page and row slot back on the free lists
    assert sorted(sched.alloc.free_pages) == list(range(24))
    assert sorted(sched.free) == list(range(6))


def test_paged_matches_contiguous_scheduler(setup):
    """Paged and contiguous schedulers are token-for-token identical —
    paging changes where KV bytes live, not what gets decoded."""
    cfg, params, kcfg, prompts, max_seq = setup
    cont = ContinuousBatchingScheduler(
        params, cfg, kcfg, rows=6, max_seq=max_seq, method="kappa",
        eos_id=tok.EOS, bos_id=tok.BOS)
    rids = [cont.submit(p, jax.random.PRNGKey(i))
            for i, p in enumerate(prompts)]
    res_c = cont.run()
    _, res_p = _paged(setup, "kappa", rows=6, page_size=8, num_pages=48)
    for r, p in zip((res_c[i] for i in rids), res_p):
        assert r.tokens == p.tokens
        assert r.chosen_branch == p.chosen_branch
        assert r.logical_tokens == p.logical_tokens


def test_paged_scheduler_mixed_max_new(setup):
    """Per-request max_new overrides: reservation is sized per request
    and results match dedicated sequential runs with the same kcfg."""
    import dataclasses
    cfg, params, kcfg, prompts, max_seq = setup
    max_news = [20, 8, 12]
    seq = []
    for i, (p, mn) in enumerate(zip(prompts, max_news)):
        kc = dataclasses.replace(kcfg, max_new_tokens=mn)
        seq.append(engine.generate_kappa(params, cfg, kc, p,
                                         jax.random.PRNGKey(i), eos_id=tok.EOS,
                                         bos_id=tok.BOS, max_seq=max_seq))
    sched = PagedScheduler(params, cfg, kcfg, rows=8, max_seq=max_seq,
                           page_size=8, num_pages=24, method="kappa",
                           eos_id=tok.EOS, bos_id=tok.BOS)
    rids = [sched.submit(p, jax.random.PRNGKey(i), max_new=mn)
            for i, (p, mn) in enumerate(zip(prompts, max_news))]
    res = sched.run()
    for s, rid in zip(seq, rids):
        assert s.tokens == res[rid].tokens
        assert s.logical_tokens == res[rid].logical_tokens


def test_paged_mixed_pool_batched_controller_contract(setup):
    """Acceptance property: a paged pool serving SEVERAL kappa requests
    (mixed with bon and greedy traffic, per-request max_new) makes at
    most one controller device dispatch and one controller-carrying
    blocking transfer per tick — counted, not assumed — and stays
    token-for-token equivalent to sequential serving."""
    import dataclasses
    cfg, params, kcfg, prompts, max_seq = setup
    specs = [("kappa", 20), ("kappa", 8), ("bon", 12),
             ("greedy", 16), ("kappa", 12)]
    ps = [prompts[i % len(prompts)] for i in range(len(specs))]
    seq = []
    for i, (p, (m, mn)) in enumerate(zip(ps, specs)):
        kc = dataclasses.replace(kcfg, max_new_tokens=mn)
        fn = getattr(engine, f"generate_{m}")
        seq.append(fn(params, cfg, kc, p, jax.random.PRNGKey(i),
                      eos_id=tok.EOS, bos_id=tok.BOS, max_seq=max_seq))
    sched = PagedScheduler(params, cfg, kcfg, rows=12, max_seq=max_seq,
                           page_size=8, num_pages=64, method="kappa",
                           eos_id=tok.EOS, bos_id=tok.BOS)
    rids = [sched.submit(p, jax.random.PRNGKey(i), max_new=mn, method=m)
            for i, (p, (m, mn)) in enumerate(zip(ps, specs))]
    res = sched.run()
    for s, rid, (m, mn) in zip(seq, rids, specs):
        assert s.tokens == res[rid].tokens, f"{m} diverged in the paged pool"
        assert s.logical_tokens == res[rid].logical_tokens
        assert s.steps == res[rid].steps
    # the controller contract, independent of the active kappa count
    assert sched._kappa_pool is not None
    assert sched._kappa_pool.dispatches >= 1
    assert sched.counters["controller_dispatches"] <= sched.ticks
    assert sched.counters["controller_syncs"] == \
        sched.counters["controller_dispatches"]
    # ≤ 2 blocking transfers per tick total (RNG keys + tokens/controller)
    assert sched.counters["host_syncs"] <= 2 * sched.ticks
    # pool fully drained
    assert sorted(sched.free) == list(range(12))
    assert sorted(sched._kappa_pool.free) == list(range(12))


def test_paged_out_of_pages_refusal(setup):
    """A request whose worst case exceeds the whole pool is refused at
    submit; one that merely has to wait is served once pages free up."""
    cfg, params, kcfg, prompts, max_seq = setup
    sched = PagedScheduler(params, cfg, kcfg, rows=8, max_seq=max_seq,
                           page_size=8, num_pages=8, method="kappa",
                           eos_id=tok.EOS, bos_id=tok.BOS)
    with pytest.raises(ValueError):
        # fan-out 4 × ceil(27/8)=4 pages = 16 > 8 total
        sched.submit(prompts[0], jax.random.PRNGKey(0))
    # shrink the requests so each fills the whole pool: they serialize,
    # the second waiting until the first returns its pages
    rids = [sched.submit(p, jax.random.PRNGKey(i), max_new=7)
            for i, p in enumerate(prompts[:2])]
    res = sched.run()
    assert set(res) == set(rids)
    assert sorted(sched.alloc.free_pages) == list(range(8))


def test_paged_sjf_admission_order(setup):
    """Among queued requests that fit, the paged scheduler picks the
    shortest job (fewest reserved pages), FIFO on ties — unlike the
    contiguous scheduler's strict head-of-line FIFO."""
    cfg, params, kcfg, prompts, max_seq = setup
    sched = PagedScheduler(params, cfg, kcfg, rows=8, max_seq=max_seq,
                           page_size=8, num_pages=64, method="kappa",
                           eos_id=tok.EOS, bos_id=tok.BOS)
    sched.submit(prompts[0], jax.random.PRNGKey(0), max_new=20)   # long
    sched.submit(prompts[2], jax.random.PRNGKey(2), max_new=6)    # short
    sched.submit(prompts[1], jax.random.PRNGKey(1), max_new=6)    # short, longer prompt
    picked = sched._select_admit()
    assert sched.queue[picked].rid == 1          # shortest need wins
    # FIFO tie-break: equal-need requests admit in arrival order
    sched.queue[picked].need = sched.queue[2].need
    assert sched.queue[sched._select_admit()].rid == 1
