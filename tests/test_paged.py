"""Paged KV pool: allocator invariants, paged decode correctness, and
the paged scheduler's token-for-token equivalence with both the
sequential engine and the contiguous scheduler (DESIGN.md §5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import KappaConfig
from repro.data import tokenizer as tok
from repro.models import decode_step, init_paged_cache, init_params
from repro.serving import cache as cache_lib
from repro.serving import engine
from repro.serving.cache import PageAllocator
from repro.serving.scheduler import ContinuousBatchingScheduler, PagedScheduler


# ---------------------------------------------------------- allocator

# one source of truth for the allocator's global invariant set —
# shared with the hypothesis op-stream property test (test_property.py)
# and the fuzz-equivalence leak checks
from allocator_harness import check_invariants as _check_invariants  # noqa: E402


def test_allocator_alloc_free_reuse():
    alloc = PageAllocator(8, 4, rows=4, max_pages=3)
    p0 = alloc.alloc_row(0, 3)
    p1 = alloc.alloc_row(1, 2)
    _check_invariants(alloc)
    assert alloc.used_count == 5 and alloc.free_count == 3
    alloc.free_row(0)
    _check_invariants(alloc)
    assert alloc.free_count == 6
    # freed pages are reusable by another row
    p2 = alloc.alloc_row(2, 3)
    _check_invariants(alloc)
    assert set(int(p) for p in p0) & set(int(p) for p in p2)
    assert alloc.pages_for(1) == 1 and alloc.pages_for(4) == 1 \
        and alloc.pages_for(5) == 2


def test_allocator_out_of_pages_and_misuse():
    alloc = PageAllocator(4, 4, rows=3, max_pages=4)
    alloc.alloc_row(0, 3)
    assert not alloc.can_alloc(2)
    with pytest.raises(ValueError):
        alloc.alloc_row(1, 2)           # only 1 page free
    with pytest.raises(ValueError):
        alloc.alloc_row(0, 1)           # row already owns pages
    with pytest.raises(ValueError):
        alloc.alloc_row(1, 5)           # > max_pages
    alloc.free_row(0)
    alloc.free_row(0)                   # double free is a no-op
    _check_invariants(alloc)
    assert alloc.free_count == 4


def test_allocator_churn_integrity():
    """Random prune→backfill churn never corrupts the block tables."""
    rng = np.random.RandomState(0)
    alloc = PageAllocator(32, 8, rows=12, max_pages=4)
    live = set()
    for _ in range(300):
        if live and (rng.rand() < 0.45 or len(live) == alloc.rows):
            r = rng.choice(sorted(live))
            alloc.free_row(r)
            live.discard(r)
        else:
            r = rng.choice([i for i in range(alloc.rows) if i not in live])
            n = rng.randint(1, alloc.max_pages + 1)
            if alloc.can_alloc(n):
                alloc.alloc_row(r, n)
                live.add(r)
        _check_invariants(alloc)
    for r in sorted(live):
        alloc.free_row(r)
    _check_invariants(alloc)
    assert alloc.free_count == alloc.num_pages


# --------------------------------------------------- COW / refcounts

def test_allocator_cow_share_diverge_free():
    """alloc -> share -> diverge -> free lifecycle: shared prompt pages
    carry one refcount per aliasing row, private growth is refcount-1,
    and a page returns to the free heap only on its LAST dereference."""
    alloc = PageAllocator(16, 4, rows=4, max_pages=6)
    shared = alloc.alloc_pages(2)               # prompt pages, shared by all
    for r in range(4):
        priv = alloc.alloc_pages(1)             # boundary COW copy
        alloc.set_row_pages(r, list(shared) + priv)
    _check_invariants(alloc)
    assert all(alloc.ref[p] == 4 for p in shared)
    assert alloc.used_count == 2 + 4            # shared counted once
    # diverge: rows grow private decode pages lazily
    for r in range(4):
        alloc.append_page(r)
    _check_invariants(alloc)
    assert alloc.used_count == 2 + 8
    # write pages are private (refcount 1): position 12 -> logical page 3
    phys = alloc.write_page(np.arange(4), np.full((4,), 12))
    assert len(set(int(p) for p in phys)) == 4
    # writing into the shared prompt pages would violate COW
    with pytest.raises(AssertionError):
        alloc.write_page(np.array([0]), np.array([2]))  # logical page 0: shared
    # writing past the owned table is a missed lazy-growth bug
    with pytest.raises(AssertionError):
        alloc.write_page(np.array([0]), np.array([16]))  # logical page 4
    # free three rows: shared pages stay allocated (ref > 0)
    for r in range(3):
        alloc.free_row(r)
        _check_invariants(alloc)
    assert all(alloc.ref[p] == 1 for p in shared)
    assert alloc.used_count == 2 + 2
    alloc.free_row(3)                           # last reference frees them
    _check_invariants(alloc)
    assert alloc.free_count == alloc.num_pages


def test_allocator_seeded_interleaving_invariants():
    """Seeded alloc / share / COW-diverge / free interleavings through
    the shared op-stream interpreter (allocator_harness) — the tier-1
    twin of the hypothesis property test in test_property.py, which
    needs the optional dependency: invariants hold after every op, zero
    pages leaked at quiescence."""
    from allocator_harness import run_allocator_ops
    rng = np.random.RandomState(42)
    kinds = ["alloc", "share", "diverge", "free", "pin", "unpin"]
    for trial in range(6):
        num_pages = int(rng.randint(6, 24))
        max_pages = int(rng.randint(2, 6))
        ops = [(kinds[int(rng.randint(len(kinds)))],
                int(rng.randint(10 ** 6)),
                int(rng.randint(10 ** 6))) for _ in range(120)]
        run_allocator_ops(num_pages, 4, 8, max_pages, ops)


def test_allocator_alloc_order_deterministic():
    """The free list is a min-heap, not a sorted-on-every-free list:
    allocation always hands out the smallest free ids, so two identical
    alloc/free histories produce identical page placement."""
    def churn(alloc):
        trace = []
        rng = np.random.RandomState(7)
        live = set()
        for _ in range(200):
            if live and (rng.rand() < 0.5 or len(live) == alloc.rows):
                r = int(rng.choice(sorted(live)))
                alloc.free_row(r)
                live.discard(r)
            else:
                r = int(rng.choice([i for i in range(alloc.rows)
                                    if i not in live]))
                n = int(rng.randint(1, alloc.max_pages + 1))
                if alloc.can_alloc(n):
                    trace.append(tuple(int(p) for p in alloc.alloc_row(r, n)))
                    live.add(r)
        return trace

    a, b = (PageAllocator(24, 8, rows=10, max_pages=4) for _ in range(2))
    assert churn(a) == churn(b)
    assert np.array_equal(a.block, b.block)
    assert sorted(a.free_pages) == sorted(b.free_pages)
    # smallest-first: out-of-order frees still allocate lowest ids next
    alloc = PageAllocator(8, 4, rows=4, max_pages=8)
    for r in range(3):
        alloc.alloc_row(r, 2)                   # rows own [0,1],[2,3],[4,5]
    alloc.free_row(1)                           # heap: 2,3,6,7
    alloc.free_row(0)                           # heap: 0,1,2,3,6,7
    assert alloc.alloc_pages(3) == [0, 1, 2]


# ----------------------------------------------------- paged decode step

@pytest.fixture(scope="module")
def setup():
    cfg = get_config("deepseek-r1-distill-qwen-1.5b").reduced(
        num_layers=2, d_model=64, vocab_size=tok.VOCAB_SIZE)
    params = init_params(jax.random.PRNGKey(0), cfg)
    kcfg = KappaConfig(num_branches=4, max_new_tokens=20, max_cutoff=4,
                       horizon=6, window=8, mom_buckets=4)
    prompts = [
        np.array([tok.BOS, tok.PROB, 3, tok.PLUS, 4, tok.EQ, tok.QM]),
        np.array([tok.BOS, tok.PROB, 7, tok.PLUS, 2, tok.PLUS, 1, tok.EQ, tok.QM]),
        np.array([tok.BOS, tok.PROB, 5, tok.PLUS, 5, tok.EQ, tok.QM]),
    ]
    max_seq = max(len(p) for p in prompts) + kcfg.max_new_tokens
    return cfg, params, kcfg, prompts, max_seq


def test_decode_step_paged_matches_contiguous(setup):
    """A paged pool with a scrambled page layout produces bitwise the
    same logits as the contiguous cache — across two decode steps so the
    paged write path is exercised too."""
    cfg, params, kcfg, prompts, _ = setup
    ps, max_seq = 8, 32
    MP = max_seq // ps
    rows, num_pages = 3, 14
    prompt = prompts[0]
    _, c1 = engine._prefill_one(params, cfg, prompt, max_seq)
    pool_c = cache_lib.broadcast_batch(c1, rows)

    alloc = PageAllocator(num_pages, ps, rows, MP)
    alloc.free_pages = [7, 2, 9, 0, 4, 1, 3, 5, 6, 8, 10, 11, 12, 13]
    for r in range(rows):
        alloc.alloc_row(r, MP)
    pool_p = init_paged_cache(cfg, rows, num_pages, ps, max_seq)
    pool_p = cache_lib.install_paged(
        cfg, pool_p, jnp.arange(rows), jnp.asarray(alloc.block.reshape(-1)),
        cache_lib.broadcast_batch(c1, rows), ps)

    step = jax.jit(decode_step, static_argnums=(1,))
    pos = jnp.array([len(prompt)] * rows, jnp.int32)
    bt = jnp.asarray(alloc.block)
    lc, pool_c = step(params, cfg, jnp.array([5, 9, 7]), pos, pool_c)
    lp, pool_p = step(params, cfg, jnp.array([5, 9, 7]), pos, pool_p, bt)
    assert np.array_equal(np.asarray(lc), np.asarray(lp))
    lc2, _ = step(params, cfg, jnp.array([2, 3, 4]), pos + 1, pool_c)
    lp2, _ = step(params, cfg, jnp.array([2, 3, 4]), pos + 1, pool_p, bt)
    assert np.array_equal(np.asarray(lc2), np.asarray(lp2))


# -------------------------------------------------- scheduler equivalence

def _sequential(setup, method):
    cfg, params, kcfg, prompts, max_seq = setup
    fn = getattr(engine, f"generate_{method}")
    return [fn(params, cfg, kcfg, p, jax.random.PRNGKey(i), eos_id=tok.EOS,
               bos_id=tok.BOS, max_seq=max_seq)
            for i, p in enumerate(prompts)]


def _paged(setup, method, rows, page_size, num_pages):
    cfg, params, kcfg, prompts, max_seq = setup
    sched = PagedScheduler(
        params, cfg, kcfg, rows=rows, max_seq=max_seq, page_size=page_size,
        num_pages=num_pages, method=method, eos_id=tok.EOS, bos_id=tok.BOS)
    rids = [sched.submit(p, jax.random.PRNGKey(i))
            for i, p in enumerate(prompts)]
    res = sched.run()
    return sched, [res[r] for r in rids]


def test_paged_scheduler_matches_sequential(setup):
    """The issue's acceptance property, paged edition: a page-constrained
    pool (requests wait on pages, pruning backfills) reproduces the
    sequential engine token for token with the same per-request keys."""
    seq = _sequential(setup, "kappa")
    sched, conc = _paged(setup, "kappa", rows=6, page_size=8, num_pages=24)
    for s, c in zip(seq, conc):
        assert s.tokens == c.tokens
        assert s.chosen_branch == c.chosen_branch
        assert s.logical_tokens == c.logical_tokens
        assert s.compute_tokens == c.compute_tokens
        assert s.steps == c.steps
        assert s.compactions == c.compactions
    tp = sched.throughput()
    assert 0.0 < tp["page_utilization"] <= 1.0
    # pool fully drained: every page and row slot back on the free lists
    assert sorted(sched.alloc.free_pages) == list(range(24))
    assert sorted(sched.free) == list(range(6))


def test_paged_matches_contiguous_scheduler(setup):
    """Paged and contiguous schedulers are token-for-token identical —
    paging changes where KV bytes live, not what gets decoded."""
    cfg, params, kcfg, prompts, max_seq = setup
    cont = ContinuousBatchingScheduler(
        params, cfg, kcfg, rows=6, max_seq=max_seq, method="kappa",
        eos_id=tok.EOS, bos_id=tok.BOS)
    rids = [cont.submit(p, jax.random.PRNGKey(i))
            for i, p in enumerate(prompts)]
    res_c = cont.run()
    _, res_p = _paged(setup, "kappa", rows=6, page_size=8, num_pages=48)
    for r, p in zip((res_c[i] for i in rids), res_p):
        assert r.tokens == p.tokens
        assert r.chosen_branch == p.chosen_branch
        assert r.logical_tokens == p.logical_tokens


def test_paged_scheduler_mixed_max_new(setup):
    """Per-request max_new overrides: reservation is sized per request
    and results match dedicated sequential runs with the same kcfg."""
    import dataclasses
    cfg, params, kcfg, prompts, max_seq = setup
    max_news = [20, 8, 12]
    seq = []
    for i, (p, mn) in enumerate(zip(prompts, max_news)):
        kc = dataclasses.replace(kcfg, max_new_tokens=mn)
        seq.append(engine.generate_kappa(params, cfg, kc, p,
                                         jax.random.PRNGKey(i), eos_id=tok.EOS,
                                         bos_id=tok.BOS, max_seq=max_seq))
    sched = PagedScheduler(params, cfg, kcfg, rows=8, max_seq=max_seq,
                           page_size=8, num_pages=24, method="kappa",
                           eos_id=tok.EOS, bos_id=tok.BOS)
    rids = [sched.submit(p, jax.random.PRNGKey(i), max_new=mn)
            for i, (p, mn) in enumerate(zip(prompts, max_news))]
    res = sched.run()
    for s, rid in zip(seq, rids):
        assert s.tokens == res[rid].tokens
        assert s.logical_tokens == res[rid].logical_tokens


def test_paged_mixed_pool_batched_controller_contract(setup):
    """Acceptance property: a paged pool serving SEVERAL kappa requests
    (mixed with bon and greedy traffic, per-request max_new) makes at
    most one controller device dispatch and one controller-carrying
    blocking transfer per tick — counted, not assumed — and stays
    token-for-token equivalent to sequential serving."""
    import dataclasses
    cfg, params, kcfg, prompts, max_seq = setup
    specs = [("kappa", 20), ("kappa", 8), ("bon", 12),
             ("greedy", 16), ("kappa", 12)]
    ps = [prompts[i % len(prompts)] for i in range(len(specs))]
    seq = []
    for i, (p, (m, mn)) in enumerate(zip(ps, specs)):
        kc = dataclasses.replace(kcfg, max_new_tokens=mn)
        fn = getattr(engine, f"generate_{m}")
        seq.append(fn(params, cfg, kc, p, jax.random.PRNGKey(i),
                      eos_id=tok.EOS, bos_id=tok.BOS, max_seq=max_seq))
    sched = PagedScheduler(params, cfg, kcfg, rows=12, max_seq=max_seq,
                           page_size=8, num_pages=64, method="kappa",
                           eos_id=tok.EOS, bos_id=tok.BOS)
    rids = [sched.submit(p, jax.random.PRNGKey(i), max_new=mn, method=m)
            for i, (p, (m, mn)) in enumerate(zip(ps, specs))]
    res = sched.run()
    for s, rid, (m, mn) in zip(seq, rids, specs):
        assert s.tokens == res[rid].tokens, f"{m} diverged in the paged pool"
        assert s.logical_tokens == res[rid].logical_tokens
        assert s.steps == res[rid].steps
    # the controller contract, independent of the active kappa count
    assert sched._kappa_pool is not None
    assert sched._kappa_pool.dispatches >= 1
    assert sched.counters["controller_dispatches"] <= sched.ticks
    assert sched.counters["controller_syncs"] == \
        sched.counters["controller_dispatches"]
    # ≤ 2 blocking transfers per tick total (RNG keys + tokens/controller)
    assert sched.counters["host_syncs"] <= 2 * sched.ticks
    # pool fully drained
    assert sorted(sched.free) == list(range(12))
    assert sorted(sched._kappa_pool.free) == list(range(12))


def test_paged_out_of_pages_refusal(setup):
    """A request whose worst case exceeds the whole pool is refused at
    submit; one that merely has to wait is served once pages free up."""
    cfg, params, kcfg, prompts, max_seq = setup
    sched = PagedScheduler(params, cfg, kcfg, rows=8, max_seq=max_seq,
                           page_size=8, num_pages=8, method="kappa",
                           eos_id=tok.EOS, bos_id=tok.BOS)
    with pytest.raises(ValueError):
        # fan-out 4 × ceil(27/8)=4 pages = 16 > 8 total
        sched.submit(prompts[0], jax.random.PRNGKey(0))
    # shrink the requests so each fills the whole pool: they serialize,
    # the second waiting until the first returns its pages
    rids = [sched.submit(p, jax.random.PRNGKey(i), max_new=7)
            for i, p in enumerate(prompts[:2])]
    res = sched.run()
    assert set(res) == set(rids)
    assert sorted(sched.alloc.free_pages) == list(range(8))


# ------------------------------------- COW prefix sharing / lazy alloc

def test_shared_admission_page_accounting(setup):
    """The acceptance property: admitting a fan-out-N request allocates
    shared_prompt_pages + N x (boundary copy + 1 decode page) — NOT the
    pre-PR N x ceil((prompt+max_new)/page_size) broadcast worst case —
    and lazy growth never exceeds prompt_pages_shared + N x private
    worst."""
    cfg, params, kcfg, prompts, max_seq = setup
    ps, N = 4, kcfg.num_branches
    sched = PagedScheduler(params, cfg, kcfg, rows=4, max_seq=max_seq,
                           page_size=ps, num_pages=64, method="kappa",
                           eos_id=tok.EOS, bos_id=tok.BOS)
    sched.submit(prompts[0], jax.random.PRNGKey(0))
    item = sched.queue[0]
    pos0 = len(prompts[0])                       # 7: full=1, boundary=1
    full, boundary = pos0 // ps, 1 if pos0 % ps else 0
    assert sched._initial_pages(item) == full + N * (1 + boundary)
    old_worst = N * sched.alloc.pages_for(item.need)
    new_worst = sched._worst_pages(item)
    assert new_worst == full + N * (sched.alloc.pages_for(item.need) - full)
    assert new_worst < old_worst
    assert sched._admit_one()
    # exactly the initial reservation is allocated, prompt pages shared
    assert sched.alloc.used_count == full + N * (1 + boundary)
    shared_pages = [p for p in range(sched.num_pages)
                    if sched.alloc.ref[p] == N]
    assert len(shared_pages) == full
    # every branch's write page is private (refcount 1)
    slots = next(iter(sched.active.values()))[1]
    wp = sched.alloc.write_page(np.asarray(slots), sched.row_pos[slots])
    assert np.all(sched.alloc.ref[wp] == 1)
    sched.run()
    assert sched._page_peak <= new_worst
    assert sched.alloc.free_count == sched.num_pages   # zero leaked pages
    _check_invariants(sched.alloc)


def test_shared_prompt_matches_broadcast_engine(setup):
    """Branches aliasing shared prompt pages decode token-for-token
    equal to the engine's broadcast-N dedicated cache (with forced page
    pressure so lazy growth fires mid-request)."""
    cfg, params, kcfg, prompts, max_seq = setup
    seq = [engine.generate_kappa(params, cfg, kcfg, p, jax.random.PRNGKey(i),
                                 eos_id=tok.EOS, bos_id=tok.BOS,
                                 max_seq=max_seq)
           for i, p in enumerate(prompts)]
    sched = PagedScheduler(params, cfg, kcfg, rows=6, max_seq=max_seq,
                           page_size=4, num_pages=26, method="kappa",
                           eos_id=tok.EOS, bos_id=tok.BOS)
    rids = [sched.submit(p, jax.random.PRNGKey(i))
            for i, p in enumerate(prompts)]
    res = sched.run()
    for s, rid in zip(seq, rids):
        assert s.tokens == res[rid].tokens
        assert s.chosen_branch == res[rid].chosen_branch
        assert s.logical_tokens == res[rid].logical_tokens
    assert sched.alloc.free_count == sched.num_pages
    _check_invariants(sched.alloc)


def test_fanout8_fits_budget_that_breaks_broadcast(setup):
    """N=8 fan-out on a long prompt completes inside a num_pages budget
    the pre-PR broadcast allocator could not even admit one request
    into — and stays token-equal to the sequential engine."""
    import dataclasses
    cfg, params, kcfg, prompts, max_seq = setup
    kcfg8 = dataclasses.replace(kcfg, num_branches=8)
    prompt = np.concatenate([prompts[0], prompts[1][1:], prompts[2][1:]])
    ps = 8
    max_seq8 = len(prompt) + kcfg8.max_new_tokens
    need = max_seq8
    pages_req = -(-need // ps)
    full = len(prompt) // ps
    broadcast_worst = 8 * pages_req
    shared_worst = full + 8 * (pages_req - full)
    num_pages = shared_worst + 2
    assert broadcast_worst > num_pages           # pre-PR submit would raise
    seq = engine.generate_kappa(params, cfg, kcfg8, prompt,
                                jax.random.PRNGKey(0), eos_id=tok.EOS,
                                bos_id=tok.BOS, max_seq=max_seq8)
    sched = PagedScheduler(params, cfg, kcfg8, rows=8, max_seq=max_seq8,
                           page_size=ps, num_pages=num_pages, method="kappa",
                           eos_id=tok.EOS, bos_id=tok.BOS)
    rid = sched.submit(prompt, jax.random.PRNGKey(0))
    res = sched.run()
    assert seq.tokens == res[rid].tokens
    assert seq.chosen_branch == res[rid].chosen_branch
    assert sched._page_peak <= num_pages
    assert sched.alloc.free_count == num_pages
    _check_invariants(sched.alloc)


def test_preemption_requeue_matches_unpreempted(setup):
    """When lazy growth drains the pool, the youngest-admitted request
    is preempted (pages freed, request requeued) and — replayed from its
    original RNG — still produces exactly the tokens of an un-preempted
    run."""
    cfg, params, kcfg, prompts, max_seq = setup
    seq = [engine.generate_bon(params, cfg, kcfg, p, jax.random.PRNGKey(i),
                               eos_id=tok.EOS, bos_id=tok.BOS,
                               max_seq=max_seq)
           for i, p in enumerate(prompts[:2])]
    # worst cases overlap: both admit on their initial pages, lazy
    # growth then outruns the pool and forces a preemption
    sched = PagedScheduler(params, cfg, kcfg, rows=8, max_seq=max_seq,
                           page_size=4, num_pages=26, method="bon",
                           eos_id=tok.EOS, bos_id=tok.BOS)
    rids = [sched.submit(p, jax.random.PRNGKey(i))
            for i, p in enumerate(prompts[:2])]
    res = sched.run()
    assert sched.counters["preemptions"] >= 1
    for s, rid in zip(seq, rids):
        assert s.tokens == res[rid].tokens
        assert s.chosen_branch == res[rid].chosen_branch
        assert s.logical_tokens == res[rid].logical_tokens
    assert sched.alloc.free_count == sched.num_pages
    assert sorted(sched.free) == list(range(8))
    _check_invariants(sched.alloc)


def test_mixed_pool_drains_allocator(setup):
    """Mixed-strategy pool churn (kappa prunes, bon releases EOS rows
    eagerly, greedy holds one row) never double-frees or leaks: the free
    heap returns to the full pool after run()."""
    cfg, params, kcfg, prompts, max_seq = setup
    specs = [("kappa", 20), ("bon", 12), ("greedy", 16), ("kappa", 8)]
    sched = PagedScheduler(params, cfg, kcfg, rows=10, max_seq=max_seq,
                           page_size=4, num_pages=48, method="kappa",
                           eos_id=tok.EOS, bos_id=tok.BOS)
    for i, (m, mn) in enumerate(specs):
        sched.submit(prompts[i % len(prompts)], jax.random.PRNGKey(i),
                     max_new=mn, method=m)
    res = sched.run()
    assert len(res) == len(specs)
    assert sched.alloc.free_count == sched.num_pages
    assert sorted(sched.free) == list(range(10))
    _check_invariants(sched.alloc)


def test_paged_request_bytes_allocator_truth(setup):
    """request_bytes() reports what the pool actually holds: distinct
    referenced pages x per-page bytes (shared prompt pages charged once)
    plus the analytic non-paged per-row state — not a contiguous
    min(pos, max_seq) estimate."""
    cfg, params, kcfg, prompts, max_seq = setup
    ps, N = 4, kcfg.num_branches
    sched = PagedScheduler(params, cfg, kcfg, rows=4, max_seq=max_seq,
                           page_size=ps, num_pages=64, method="kappa",
                           eos_id=tok.EOS, bos_id=tok.BOS)
    rid = sched.submit(prompts[0], jax.random.PRNGKey(0))
    assert sched._admit_one()
    got = sched.request_bytes()[rid]
    rs, slots = sched.active[rid]
    pages = {int(p) for s in slots for p in sched.alloc.row_pages(s)}
    pb = cache_lib.page_bytes(cfg, ps)
    want = len(pages) * pb + cache_lib.used_cache_bytes(
        cfg, len(slots), rs.pos, sched.max_seq, skip_global=True)
    assert got == want
    # sharing is visible: N branches cost less than N private copies
    full = len(prompts[0]) // ps
    assert len(pages) < N * (full + 2)
    sched.run()


def test_paged_sjf_admission_order(setup):
    """Among queued requests that fit, the paged scheduler picks the
    shortest job (fewest reserved pages), FIFO on ties — unlike the
    contiguous scheduler's strict head-of-line FIFO."""
    cfg, params, kcfg, prompts, max_seq = setup
    sched = PagedScheduler(params, cfg, kcfg, rows=8, max_seq=max_seq,
                           page_size=8, num_pages=64, method="kappa",
                           eos_id=tok.EOS, bos_id=tok.BOS)
    sched.submit(prompts[0], jax.random.PRNGKey(0), max_new=20)   # long
    sched.submit(prompts[2], jax.random.PRNGKey(2), max_new=6)    # short
    sched.submit(prompts[1], jax.random.PRNGKey(1), max_new=6)    # short, longer prompt
    picked = sched._select_admit()
    assert sched.queue[picked].rid == 1          # shortest need wins
    # FIFO tie-break: equal-need requests admit in arrival order
    sched.queue[picked].need = sched.queue[2].need
    assert sched.queue[sched._select_admit()].rid == 1


def _drive_with_short_stream(sched, long_rid, prompts, ticks):
    """Tick the scheduler while feeding it a fresh short request every
    tick; returns True iff the long request got admitted."""
    for i in range(ticks):
        if long_rid in sched._admit_seq or long_rid in sched.results:
            return True
        sched.submit(prompts[0], jax.random.PRNGKey(100 + i), max_new=4,
                     method="greedy")
        sched.tick()
    return long_rid in sched._admit_seq or long_rid in sched.results


def test_sjf_aging_prevents_starvation(setup):
    """Regression for SJF starvation: under a steady stream of short
    submissions a long request was bypassed forever. With bounded bypass
    (after max_bypass bypasses the head admits next-fit-or-nothing) it
    gets in; with the old unbounded policy (max_bypass=inf) it starves —
    this test fails on the pre-fix policy."""
    cfg, params, kcfg, prompts, max_seq = setup

    long_prompt = np.concatenate([prompts[0], prompts[1][1:], prompts[2][1:]])

    def build(max_bypass):
        sched = PagedScheduler(params, cfg, kcfg, rows=4,
                               max_seq=len(long_prompt) + 20,
                               page_size=4, num_pages=11, method="greedy",
                               eos_id=tok.EOS, bos_id=tok.BOS,
                               max_bypass=max_bypass)
        # two shorts occupy the pool first; then the long job (7 pages up
        # front, 11 worst case) joins the queue — inadmissible whenever
        # >= 2 of the streaming shorts (3 pages each) are in flight
        for i in range(2):
            sched.submit(prompts[0], jax.random.PRNGKey(50 + i), max_new=4,
                         method="greedy")
        long_rid = sched.submit(long_prompt, jax.random.PRNGKey(0),
                                max_new=20, method="greedy")
        return sched, long_rid

    TICKS = 80
    sched, long_rid = build(max_bypass=4)
    assert _drive_with_short_stream(sched, long_rid, prompts, TICKS), \
        "aged head request was never admitted"
    # control: the unbounded-bypass policy starves the same request
    sched, long_rid = build(max_bypass=10**9)
    assert not _drive_with_short_stream(sched, long_rid, prompts, TICKS), \
        "starvation scenario no longer reproduces - tighten the setup"


# ----------------------------------------------- paged kernel wiring

def _paged_decode_fixture(setup, cfg):
    """Install a prefilled prompt into a fresh paged pool; returns the
    pieces a decode_step call needs."""
    _, params, _, prompts, _ = setup
    ps, max_seq = 8, 32
    MP = max_seq // ps
    rows, num_pages = 3, 14
    prompt = prompts[0]
    _, c1 = engine._prefill_one(params, cfg, prompt, max_seq)
    alloc = PageAllocator(num_pages, ps, rows, MP)
    for r in range(rows):
        alloc.alloc_row(r, MP)
    pool = init_paged_cache(cfg, rows, num_pages, ps, max_seq)
    pool = cache_lib.install_paged(
        cfg, pool, jnp.arange(rows), jnp.asarray(alloc.block.reshape(-1)),
        cache_lib.broadcast_batch(c1, rows), ps)
    pos = jnp.array([len(prompt)] * rows, jnp.int32)
    bt = jnp.asarray(alloc.block)
    return pool, pos, bt


@pytest.mark.parametrize("kv_dtype", ["model", "int8"])
def test_attn_decode_paged_kernel_wiring(setup, kv_dtype):
    """attn_decode_paged routes through paged_decode_attn_pallas when
    the kernel path is enabled (forced here, running the Pallas
    interpreter on CPU) and matches the jnp gather oracle.

    The backend counters make silent fallback a hard failure: with the
    kernel forced, not a single layer may take the oracle branch. The
    int8 case is the regression for the quantized bypass — the old
    dispatch quietly dropped to the gather oracle whenever the cache was
    quantized, and the allclose alone never noticed."""
    import dataclasses
    from repro.models import attention as attn_mod
    cfg, params = setup[0], setup[1]
    if kv_dtype != "model":
        cfg = dataclasses.replace(cfg, kv_cache_dtype=kv_dtype)
    pool, pos, bt = _paged_decode_fixture(setup, cfg)
    toks = jnp.array([5, 9, 7])
    # eager (unjitted) calls so the kernel toggle takes effect per call
    lo, _ = decode_step(params, cfg, toks, pos, pool, bt)
    attn_mod.reset_paged_backend_counts()
    attn_mod.set_paged_kernel(True)
    try:
        lk, _ = decode_step(params, cfg, toks, pos, pool, bt)
    finally:
        attn_mod.set_paged_kernel(None)
    counts = attn_mod.paged_backend_counts()
    assert counts["decode_kernel"] >= 1, "kernel path never taken"
    assert counts["decode_oracle"] == 0, \
        f"silent fallback to the gather oracle: {counts}"
    np.testing.assert_allclose(np.asarray(lk), np.asarray(lo),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("kv_dtype", ["model", "int8"])
def test_attn_prefill_chunk_paged_kernel_wiring(setup, kv_dtype):
    """Chunked paged prefill routes through paged_prefill_attn_pallas
    when the kernel path is forced — backend counters prove no layer
    fell back to the jnp gather oracle — and the last-chunk logits match
    the oracle run."""
    import dataclasses
    from repro.models import attention as attn_mod
    from repro.models import init_cache, prefill_chunk
    cfg, params, _, prompts, max_seq = setup
    if kv_dtype != "model":
        cfg = dataclasses.replace(cfg, kv_cache_dtype=kv_dtype)
    prompt, ps, chunk, num_pages = prompts[1], 4, 3, 12
    MP = -(-max_seq // ps)

    def run_prefill():
        alloc = PageAllocator(num_pages, ps, rows=2, max_pages=MP)
        pool = init_paged_cache(cfg, 2, num_pages, ps, MP * ps)
        aux = init_cache(cfg, 1, 1)
        logits, filled = None, 0
        while filled < len(prompt):
            piece = prompt[filled:filled + chunk]
            need = alloc.pages_for(filled + len(piece))
            while int(alloc.owned[0]) < need:
                if int(alloc.owned[0]) == 0:
                    alloc.set_row_pages(0, alloc.alloc_pages(1))
                else:
                    alloc.append_page(0)
            qpos = np.arange(filled, filled + len(piece))
            cpages = alloc.block[0][qpos // ps]
            logits, pool, aux = prefill_chunk(
                params, cfg, jnp.asarray(piece)[None],
                jnp.full((1,), filled, jnp.int32), 0, pool,
                jnp.asarray(alloc.block[0:1]),
                jnp.asarray(cpages.astype(np.int32))[None], aux)
            filled += len(piece)
        return np.asarray(logits)

    lo = run_prefill()
    attn_mod.reset_paged_backend_counts()
    attn_mod.set_paged_kernel(True)
    try:
        lk = run_prefill()
    finally:
        attn_mod.set_paged_kernel(None)
    counts = attn_mod.paged_backend_counts()
    assert counts["prefill_kernel"] >= 1, "prefill kernel path never taken"
    assert counts["prefill_oracle"] == 0, \
        f"silent fallback to the gather oracle: {counts}"
    np.testing.assert_allclose(lk, lo, rtol=2e-5, atol=2e-5)


# ------------------------------------------------- int8 paged serving

def _paged_leaf_axis(leaf, num_pages):
    """Axis of the physical-page dimension in a paged global leaf, or
    None for per-row leaves. Pools may stack layers (leading K axis)."""
    if leaf.ndim >= 1 and leaf.shape[0] == num_pages + 1:
        return 0
    if leaf.ndim >= 2 and leaf.shape[1] == num_pages + 1:
        return 1
    return None


@pytest.mark.parametrize("kv_dtype", ["model", "int8"])
def test_page_bytes_matches_leaf_nbytes(setup, kv_dtype):
    """page_bytes() is allocator truth, not an estimate: summed over the
    pool's global-layer leaves (values AND the int8 scale leaves, minus
    the trash page) it equals num_pages * page_bytes exactly. The old
    amortized float cost (1 + 4/hd per element) drifted under int()."""
    import dataclasses
    cfg = setup[0]
    if kv_dtype != "model":
        cfg = dataclasses.replace(cfg, kv_cache_dtype=kv_dtype)
    ps, num_pages, rows, max_seq = 8, 14, 3, 32
    pool = init_paged_cache(cfg, rows, num_pages, ps, max_seq)
    per_page = 0
    for leaf in jax.tree.leaves(pool):
        ax = _paged_leaf_axis(leaf, num_pages)
        if ax is not None:
            assert leaf.nbytes % (num_pages + 1) == 0
            per_page += leaf.nbytes // (num_pages + 1)
    assert per_page > 0, "no paged global leaves found"
    assert cache_lib.page_bytes(cfg, ps) == per_page
    assert cache_lib.page_bytes(cfg, ps) * num_pages \
        == per_page * num_pages


def test_int8_scale_leaves_ride_cow_paths(setup):
    """COW plumbing carries the quantization scales: install_paged_shared
    scatters k_s/v_s page-wise next to the int8 values (staying float32 —
    an astype into the value dtype would truncate them to garbage), and
    copy_pages duplicates them onto the boundary COW copy."""
    import dataclasses
    from jax.tree_util import keystr, tree_flatten_with_path
    cfg = dataclasses.replace(setup[0], kv_cache_dtype="int8")
    params, prompts = setup[1], setup[3]
    prompt = prompts[1]                 # len 9 @ ps=4: 2 full + boundary
    ps, num_pages, max_seq, n = 4, 12, 32, 2
    _, c1 = engine._prefill_one(params, cfg, prompt, max_seq)
    pool = init_paged_cache(cfg, n, num_pages, ps, max_seq)
    # shared map: full prompt pages 0,1 once; boundary page 2 per branch
    src_idx = np.asarray([0, 1, 2, 2], np.int32)
    phys = np.asarray([0, 1, 2, 3], np.int32)
    pool = cache_lib.install_paged_shared(
        cfg, pool, jnp.arange(n), jnp.asarray(src_idx), jnp.asarray(phys),
        c1, ps)
    sub = {keystr(p): l for p, l in tree_flatten_with_path(c1)[0]}
    checked = 0
    for path, a in tree_flatten_with_path(pool)[0]:
        key = keystr(path)
        if "k_s" not in key and "v_s" not in key:
            continue
        ax = _paged_leaf_axis(a, num_pages)
        if ax is None:
            continue                    # per-row aux scales (ring layers)
        assert a.dtype == jnp.float32, f"{key} truncated to {a.dtype}"
        b = np.asarray(sub[key])
        if ax == 0:                     # b: (1, S, KV)
            br = b[0].reshape((b.shape[1] // ps, ps) + b.shape[2:])
            got, want = np.asarray(a)[phys], br[src_idx]
        else:                           # stacked, b: (K, 1, S, KV)
            br = b[:, 0].reshape((b.shape[0], b.shape[2] // ps, ps)
                                 + b.shape[3:])
            got, want = np.asarray(a)[:, phys], br[:, src_idx]
        assert np.array_equal(got, want), f"{key} scales mangled"
        checked += 1
    assert checked >= 2, "int8 pool grew no paged scale leaves"
    # COW page copy carries every global leaf, scales included
    pool2 = cache_lib.copy_pages(cfg, pool, jnp.asarray([2]),
                                 jnp.asarray([7]))
    for (path, a2), (_, a) in zip(tree_flatten_with_path(pool2)[0],
                                  tree_flatten_with_path(pool)[0]):
        ax = _paged_leaf_axis(a2, num_pages)
        if ax is None:
            continue
        a2, a = np.asarray(a2), np.asarray(a)
        if ax == 0:
            assert np.array_equal(a2[7], a[2]), keystr(path)
        else:
            assert np.array_equal(a2[:, 7], a[:, 2]), keystr(path)


def test_paged_scheduler_int8_mixed_matches_sequential(setup):
    """Token-for-token int8 serving: a mixed kappa/bon/stbon/greedy
    paged pool with a quantized cache reproduces the sequential engine
    (also int8) exactly — paging moves quantized bytes and their scales,
    it never re-rounds them."""
    import dataclasses
    cfg, params, kcfg, prompts, max_seq = setup
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    specs = [("kappa", 20), ("bon", 12), ("stbon", 12), ("greedy", 16)]
    seq = []
    for i, (m, mn) in enumerate(specs):
        kc = dataclasses.replace(kcfg, max_new_tokens=mn)
        fn = getattr(engine, f"generate_{m}")
        seq.append(fn(params, cfg8, kc, prompts[i % len(prompts)],
                      jax.random.PRNGKey(i), eos_id=tok.EOS, bos_id=tok.BOS,
                      max_seq=max_seq))
    sched = PagedScheduler(params, cfg8, kcfg, rows=10, max_seq=max_seq,
                           page_size=8, num_pages=48, method="kappa",
                           eos_id=tok.EOS, bos_id=tok.BOS)
    rids = [sched.submit(prompts[i % len(prompts)], jax.random.PRNGKey(i),
                         max_new=mn, method=m)
            for i, (m, mn) in enumerate(specs)]
    res = sched.run()
    for s, rid, (m, _) in zip(seq, rids, specs):
        assert s.tokens == res[rid].tokens, f"{m} diverged under int8"
        assert s.logical_tokens == res[rid].logical_tokens
        assert s.steps == res[rid].steps
    assert sched.alloc.free_count == sched.num_pages
    assert sorted(sched.free) == list(range(10))
    _check_invariants(sched.alloc)


def test_page_budget_bytes_capacity(setup):
    """Admission capacity follows page_bytes: at one fixed HBM budget an
    int8 pool holds ~2x the pages of the model-dtype pool (exactly
    2 * hd / (hd + 4) more), and passing both num_pages and a budget is
    rejected."""
    import dataclasses
    cfg, params, kcfg, prompts, max_seq = setup
    budget = 64 * cache_lib.page_bytes(cfg, 8)
    s_fp = PagedScheduler(params, cfg, kcfg, rows=4, max_seq=max_seq,
                          page_size=8, page_budget_bytes=budget,
                          method="kappa", eos_id=tok.EOS, bos_id=tok.BOS)
    assert s_fp.num_pages == 64
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    s_i8 = PagedScheduler(params, cfg8, kcfg, rows=4, max_seq=max_seq,
                          page_size=8, page_budget_bytes=budget,
                          method="kappa", eos_id=tok.EOS, bos_id=tok.BOS)
    assert s_i8.num_pages >= int(1.8 * s_fp.num_pages)
    with pytest.raises(ValueError):
        PagedScheduler(params, cfg, kcfg, rows=4, max_seq=max_seq,
                       page_size=8, num_pages=64, page_budget_bytes=budget,
                       method="kappa", eos_id=tok.EOS, bos_id=tok.BOS)
