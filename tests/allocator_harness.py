"""Shared PageAllocator test harness (no test deps beyond numpy):
the global invariant checker and the alloc/share/COW-diverge/free/
pin/unpin op-stream interpreter. Driven by the hypothesis property test
in ``test_property.py``, the seeded tier-1 twin in ``test_paged.py`` and
the fuzz-equivalence leak checks — one interpreter, so an invariant
added here is enforced everywhere at once."""
import numpy as np

from repro.serving import cache as cache_lib


def check_invariants(alloc: "cache_lib.PageAllocator") -> None:
    """Refcounts partition into block-table references plus radix pins
    exactly, every referenced page has ref >= 1, a pinned page is live
    (pin implies ref >= 1 by construction), free pages carry no pins, a
    page sits in two tables only while ref > 1, owned prefixes hold real
    pages with all-trash tails, and free-heap + referenced partition the
    pool (no leak, no double free)."""
    refs = np.zeros((alloc.num_pages,), np.int64)
    for r in range(alloc.rows):
        n = int(alloc.owned[r])
        assert np.all(alloc.block[r, :n] < alloc.num_pages)
        assert np.all(alloc.block[r, n:] == alloc.trash)
        for p in alloc.block[r, :n]:
            refs[int(p)] += 1
    assert np.array_equal(refs + alloc.pinned, alloc.ref), \
        "refcount drift (table refs + pins != ref)"
    assert np.all(alloc.pinned >= 0), "negative pin count"
    assert np.all(alloc.ref[alloc.pinned > 0] >= 1), \
        "pinned page without a live reference"
    free = set(alloc.free_pages)
    assert len(free) == len(alloc.free_pages), "duplicate free page"
    assert all(alloc.ref[p] == 0 for p in free), "freed page still referenced"
    assert all(alloc.pinned[p] == 0 for p in free), "freed page still pinned"
    assert all(alloc.ref[p] > 0 for p in range(alloc.num_pages)
               if p not in free), "leaked page (zero refs, not free)"
    # shared pages (in >1 table) must carry ref > 1 — COW soundness
    counts: dict = {}
    for r in range(alloc.rows):
        for p in alloc.block[r, :int(alloc.owned[r])]:
            counts[int(p)] = counts.get(int(p), 0) + 1
    for p, c in counts.items():
        if c > 1:
            assert alloc.ref[p] == c + alloc.pinned[p] > 1


def run_allocator_ops(num_pages, page_size, rows, max_pages, ops):
    """Interpret a random op stream against a PageAllocator, checking
    the invariants after every step. Ops are (kind, a, b) with the
    operands reduced mod the current candidates, so any integer triple
    is a valid program — which is what makes a failing case
    shrinkable. ``pin``/``unpin`` model the radix prefix cache's claim
    on live pages: pins keep a page out of the free heap across every
    table dropping it, and the end-of-stream unpin-all is the tree-drop
    zero-leak check."""
    alloc = cache_lib.PageAllocator(num_pages, page_size, rows, max_pages)
    owners = []                              # rows with any pages
    pins = []                                # pages pinned by the "tree"
    for kind, a, b in ops:
        free_rows = [r for r in range(rows) if not alloc.owned[r]]
        if kind == "alloc" and free_rows:
            r = free_rows[a % len(free_rows)]
            n = 1 + b % max_pages
            if alloc.can_alloc(n):
                alloc.alloc_row(r, n)
                owners.append(r)
        elif kind == "share" and owners and free_rows:
            # alias one owner's pages into a free row (prefix sharing)
            src = owners[a % len(owners)]
            dst = free_rows[b % len(free_rows)]
            pages = [int(p) for p in alloc.row_pages(src)]
            alloc.set_row_pages(dst, pages)
            owners.append(dst)
        elif kind == "diverge" and owners:
            # COW divergence: grow a private decode page
            r = owners[a % len(owners)]
            if int(alloc.owned[r]) < max_pages and alloc.can_alloc(1):
                alloc.append_page(r)
        elif kind == "free" and owners:
            r = owners.pop(a % len(owners))
            alloc.free_row(r)
        elif kind == "pin" and owners:
            # publish: pin a live page some row references
            r = owners[a % len(owners)]
            pages = alloc.row_pages(r)
            if len(pages):
                p = int(pages[b % len(pages)])
                alloc.pin_page(p)
                pins.append(p)
        elif kind == "unpin" and pins:
            # eviction: release one pin (page may outlive or die)
            alloc.unpin_page(pins.pop(a % len(pins)))
        check_invariants(alloc)
    for r in list(owners):
        alloc.free_row(r)
    check_invariants(alloc)
    for p in pins:                           # tree drop
        alloc.unpin_page(p)
    check_invariants(alloc)
    assert alloc.free_count == alloc.num_pages, "quiescent leak"
    assert int(alloc.pinned.sum()) == 0, "quiescent pin"
