"""Cross-request radix prefix cache (PR 6, DESIGN.md §7).

Three layers of coverage:

  * radix tree + pin bookkeeping against a bare :class:`PageAllocator`
    (publish/lookup roundtrip, page-granular keying, idempotent
    republish, LRU leaf eviction, aliased pages never evicted, tree
    drop = zero leak);
  * serving-level equivalence and savings: repeated/shared prompts hit
    the cache, chunked prefill resumes at the cached extent, and every
    served token stays identical to the cache-off run (the fuzz sweep
    in test_fuzz_equivalence.py covers random mixes; here the targeted
    scenarios) — including the generated-prefix (Path-Consistency)
    resubmission path that aliases DECODE-written pages;
  * eviction racing preemption: under page pressure the least-recently
    -hit cached pages are released first, so a lone request never
    preempts anything (evictions > 0, preemptions == 0) and stays
    token-for-token equal.

Also covers the PR 5 follow-up satellite: multiple concurrent prefill
chunks riding ONE fused decode dispatch.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import KappaConfig
from repro.data import tokenizer as tok
from repro.models import init_params
from repro.serving import engine
from repro.serving.cache import PageAllocator, RadixPrefixCache
from repro.serving.scheduler import PagedScheduler

from allocator_harness import check_invariants

MAX_SEQ = 32
PAGE_SIZE = 4


# ------------------------------------------------------- radix tree unit

def test_radix_publish_lookup_roundtrip():
    alloc = PageAllocator(8, 4, 2, 8)
    pc = RadixPrefixCache(alloc, 4)
    toks = np.arange(12)
    alloc.alloc_row(0, 3)
    pages = [int(p) for p in alloc.row_pages(0)]
    assert pc.publish(toks, pages) == 3
    assert pc.publish(toks, pages) == 0          # idempotent republish
    assert pc.pinned_count == 3
    check_invariants(alloc)
    alloc.free_row(0)                            # pins keep pages live
    assert alloc.free_count == 8 - 3
    check_invariants(alloc)
    assert pc.lookup(toks) == pages
    assert pc.lookup(toks[:11]) == pages[:2]     # partial page never matches
    assert pc.lookup(toks[:3]) == []             # shorter than one page
    div = toks.copy()
    div[5] = 99                                  # diverges inside page 1
    assert pc.lookup(div) == pages[:1]
    assert pc.evictable_count == 3
    assert pc.drop() == 3
    check_invariants(alloc)
    assert alloc.free_count == 8 and int(alloc.pinned.sum()) == 0


def test_radix_lru_leaf_eviction_order():
    alloc = PageAllocator(8, 4, 3, 8)
    pc = RadixPrefixCache(alloc, 4)
    a = np.arange(12)                            # chain A: 3 pages
    b = np.concatenate([[50], np.arange(1, 8)])  # chain B: 2 pages
    alloc.alloc_row(0, 3)
    pc.publish(a, [int(p) for p in alloc.row_pages(0)])
    alloc.free_row(0)
    alloc.alloc_row(1, 2)
    b_pages = [int(p) for p in alloc.row_pages(1)]
    pc.publish(b, b_pages)
    alloc.free_row(1)
    pc.lookup(a)                                 # stamp chain A hotter
    # leaves only: chain B's tail is the coldest evictable node
    assert pc.evict_one() == b_pages[1]
    assert pc.evict_one() == b_pages[0]
    # chain A evicts deepest-first (inner nodes have children)
    a_hit = pc.lookup(a)
    assert pc.evict_one() == a_hit[2]
    check_invariants(alloc)
    assert pc.pinned_count == 2 and pc.evictions == 3


def test_radix_aliased_pages_never_evicted():
    alloc = PageAllocator(6, 4, 2, 6)
    pc = RadixPrefixCache(alloc, 4)
    toks = np.arange(8)
    alloc.alloc_row(0, 2)
    pages = [int(p) for p in alloc.row_pages(0)]
    pc.publish(toks, pages)
    alloc.free_row(0)
    # a later request aliases the cached pages (lookup -> set_row_pages)
    alloc.set_row_pages(1, pc.lookup(toks))
    check_invariants(alloc)
    assert pc.evictable_count == 0
    assert pc.evict_one() is None                # nothing evictable
    alloc.free_row(1)
    assert pc.evictable_count == 2
    assert pc.evict_one() is not None
    pc.drop()
    check_invariants(alloc)
    assert alloc.free_count == 6


def test_pin_requires_live_page():
    alloc = PageAllocator(4, 4, 1, 4)
    with pytest.raises(ValueError):
        alloc.pin_page(0)                        # unreferenced
    with pytest.raises(ValueError):
        alloc.unpin_page(0)                      # never pinned
    alloc.alloc_row(0, 1)
    p = int(alloc.row_pages(0)[0])
    alloc.pin_page(p)
    alloc.free_row(0)
    assert alloc.free_count == 3                 # pin holds the page
    alloc.unpin_page(p)
    assert alloc.free_count == 4


# ------------------------------------------------------ serving fixtures

@pytest.fixture(scope="module")
def setup():
    cfg = get_config("deepseek-r1-distill-qwen-1.5b").reduced(
        num_layers=2, d_model=64, vocab_size=tok.VOCAB_SIZE)
    params = init_params(jax.random.PRNGKey(0), cfg)
    kcfg = KappaConfig(num_branches=4, max_new_tokens=20, max_cutoff=4,
                       horizon=6, window=8, mom_buckets=4)
    return cfg, params, kcfg


def _prompt(seed, plen):
    body = np.random.default_rng(seed).integers(0, tok.MOD, size=plen - 2)
    return np.concatenate([[tok.BOS], body, [tok.QM]])


def _sched(setup, *, num_pages=None, prefix_cache=False, chunk=5):
    cfg, params, kcfg = setup
    return PagedScheduler(
        params, cfg, kcfg, rows=8, max_seq=MAX_SEQ, page_size=PAGE_SIZE,
        num_pages=num_pages or 8 * MAX_SEQ // PAGE_SIZE, method="kappa",
        eos_id=tok.EOS, bos_id=tok.BOS, prefill_chunk=chunk,
        prefix_cache=prefix_cache)


def _teardown_ok(sched):
    """Quiescence + zero-leak after the tree drop — the harness
    invariants hold both with live pins and after."""
    check_invariants(sched.alloc)
    if sched.pcache is not None:
        sched.pcache.drop()
    assert sched.alloc.free_count == sched.num_pages
    assert int(sched.alloc.pinned.sum()) == 0
    check_invariants(sched.alloc)


# ------------------------------------------------- hits, savings, equality

def test_repeated_prompt_hits_and_stays_equal(setup):
    """The same prompt served twice in a row: the replay aliases the
    published pages up to the full-hit cap ((plen-1)//ps pages — the
    last token always re-prefills for its logits) and both requests
    stay token-for-token equal to the cache-off run."""
    plen = 13
    p = _prompt(3, plen)

    def serve(pc):
        s = _sched(setup, prefix_cache=pc)
        r1 = s.submit(p, jax.random.PRNGKey(1), max_new=8, method="kappa")
        first = s.run()[r1].tokens
        r2 = s.submit(p, jax.random.PRNGKey(2), max_new=8, method="bon")
        second = s.run()[r2].tokens
        return first, second, s

    f0, s0, _ = serve(False)
    f1, s1, sched = serve(True)
    assert f0 == f1 and s0 == s1
    assert sched.counters["prefix_hits"] == 1
    assert sched.counters["prefix_tokens_saved"] \
        == ((plen - 1) // PAGE_SIZE) * PAGE_SIZE
    _teardown_ok(sched)


def test_generated_prefix_resubmission(setup):
    """Path-Consistency scenario: resubmitting prompt + the winner's
    generated prefix aliases DECODE-written pages and must stay exactly
    equal to re-prefilling those tokens from scratch."""
    p1 = _prompt(11, 9)
    ref = _sched(setup)
    rid = ref.submit(p1, jax.random.PRNGKey(5), max_new=10, method="kappa")
    gen = ref.run()[rid].tokens
    assert len(gen) >= 6
    p2 = np.concatenate([p1, gen[:6]])

    def serve(pc):
        s = _sched(setup, prefix_cache=pc)
        a = s.submit(p1, jax.random.PRNGKey(5), max_new=10, method="kappa")
        ra = s.run()[a].tokens
        b = s.submit(p2, jax.random.PRNGKey(9), max_new=8, method="stbon")
        rb = s.run()[b].tokens
        return ra, rb, s

    a0, b0, _ = serve(False)
    a1, b1, sched = serve(True)
    assert a0 == a1 and b0 == b1
    assert sched.counters["prefix_hits"] == 1
    # the hit extends past the original prompt into generated pages
    assert sched.counters["prefix_tokens_saved"] > len(p1)
    _teardown_ok(sched)


def test_eviction_races_preemption(setup):
    """A lone admission under page pressure reclaims pinned prefix
    pages instead of preempting: evictions > 0, preemptions == 0, and
    the tokens match the cache-off run exactly."""
    cfg, params, kcfg = setup
    prompts = [_prompt(s, 12) for s in (21, 22, 23)]

    # the admission guard needs the pool to hold one request's worst
    # case (15 pages); each completed request pins 5 prefix pages and
    # peaks at ~7 live, so by the third sequential request the 16-page
    # pool's free count (16 - 10 pinned = 6) is below its peak — it must
    # reclaim least-recently-hit pins, never preempt (it runs alone)
    def serve(pc):
        s = _sched(setup, num_pages=16, prefix_cache=pc)
        toks = []
        for i, p in enumerate(prompts):
            r = s.submit(p, jax.random.PRNGKey(i + 1), max_new=10,
                         method="kappa")
            toks.append(s.run()[r].tokens)
        return toks, s

    t0, off = serve(False)
    t1, on = serve(True)
    assert t0 == t1
    assert on.counters["prefix_evictions"] > 0, \
        "pressure never forced an eviction — scenario too loose"
    assert on.counters["preemptions"] == 0
    assert off.counters["preemptions"] == 0
    _teardown_ok(on)


def test_forced_pressure_with_cache_stays_equal(setup):
    """Concurrent mixed traffic on a pool tight enough to preempt, with
    the prefix cache live: eviction composes with youngest-first
    preemption and the result stays equal to the generous-pool run."""
    reqs = [(_prompt(31, 12), "kappa", 10), (_prompt(32, 12), "kappa", 8),
            (_prompt(31, 12), "bon", 6)]

    def serve(num_pages, pc):
        s = _sched(setup, num_pages=num_pages, prefix_cache=pc)
        rids = [s.submit(p, jax.random.PRNGKey(i), max_new=mn, method=m)
                for i, (p, m, mn) in enumerate(reqs)]
        res = s.run()
        return [res[r].tokens for r in rids], s

    base, _ = serve(None, False)
    got, sched = serve(17, True)
    assert base == got
    _teardown_ok(sched)


# ------------------------------------------- PR 5 follow-up: multi-fuse

def test_concurrent_prefill_chunks_fuse_into_one_dispatch(setup, monkeypatch):
    """Two long-prompt admissions prefilling while a third request
    decodes: BOTH pending chunks ride a single fused decode dispatch
    (PR 5 fused only the oldest), and the served tokens still match the
    sequential engine."""
    cfg, params, kcfg = setup
    import dataclasses
    calls = []
    orig = engine._fused_decode_chunks

    def spy(*args):
        calls.append(len(args[7]))
        return orig(*args)

    monkeypatch.setattr(engine, "_fused_decode_chunks", spy)
    s = _sched(setup, chunk=4)
    prompts = [_prompt(41, 8), _prompt(42, 16), _prompt(43, 16)]
    meths = ["kappa", "greedy", "greedy"]
    rids = [s.submit(p, jax.random.PRNGKey(i), max_new=10, method=m)
            for i, (p, m) in enumerate(zip(prompts, meths))]
    res = s.run()
    assert max(calls) >= 2, "younger prefill chunk did not fuse"
    assert s.counters["fused_chunks"] == sum(calls)
    for i, (p, m) in enumerate(zip(prompts, meths)):
        kc = dataclasses.replace(kcfg, max_new_tokens=10)
        ref = getattr(engine, f"generate_{m}")(
            params, cfg, kc, p, jax.random.PRNGKey(i),
            eos_id=tok.EOS, bos_id=tok.BOS, max_seq=MAX_SEQ)
        assert ref.tokens == res[rids[i]].tokens
