"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import KappaConfig
from repro.core import robust, schedule, scoring
from repro.core.kappa import _prune
from repro.data import tasks
from repro.data import tokenizer as tok
from repro.serving import cache as cache_lib
from repro.serving import sampler

SETTINGS = dict(max_examples=40, deadline=None)


# ------------------------------------------------------------- schedule

@given(n=st.integers(2, 64), horizon=st.integers(1, 64),
       kind=st.sampled_from(["linear", "cosine", "step"]))
@settings(**SETTINGS)
def test_schedule_invariants(n, horizon, kind):
    prev = n
    for t in range(horizon):
        r = int(schedule.survivors(kind, n, jnp.int32(t), horizon))
        assert 1 <= r <= n
        assert r <= prev, f"{kind} must be non-increasing"
        prev = r
    assert prev == 1, f"{kind} must reach exactly 1 at the horizon end"


# ---------------------------------------------------------------- prune

@given(n=st.integers(2, 16), r=st.integers(1, 16), seed=st.integers(0, 999))
@settings(**SETTINGS)
def test_prune_keeps_exactly_r_of_alive(n, r, seed):
    rng = np.random.default_rng(seed)
    alive = jnp.asarray(rng.random(n) < 0.8)
    traj = jnp.asarray(rng.normal(size=n).astype(np.float32))
    keep = _prune(alive, traj, jnp.int32(r))
    kept = np.asarray(keep)
    al = np.asarray(alive)
    assert not np.any(kept & ~al), "prune must never resurrect dead branches"
    n_alive = al.sum()
    assert kept.sum() == min(r, n_alive) or n_alive == 0
    # kept branches are the top-scoring alive ones
    if kept.sum() and kept.sum() < n_alive:
        worst_kept = np.asarray(traj)[kept].min()
        best_dropped = np.asarray(traj)[al & ~kept].max()
        assert worst_kept >= best_dropped


# --------------------------------------------------------------- zscore

@given(n=st.integers(2, 32), seed=st.integers(0, 999),
       clip=st.floats(0.5, 5.0))
@settings(**SETTINGS)
def test_zscore_bounded_and_centered(n, seed, clip):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(scale=10, size=n).astype(np.float32))
    alive = jnp.asarray(rng.random(n) < 0.7)
    z = np.asarray(scoring.masked_zscore(x, alive, clip))
    assert np.all(np.abs(z) <= clip + 1e-5)
    assert np.all(z[~np.asarray(alive)] == 0.0)


# ------------------------------------------------------------------ MoM

@given(w_buckets=st.sampled_from([(8, 2), (8, 4), (16, 4), (32, 8)]),
       seed=st.integers(0, 999))
@settings(**SETTINGS)
def test_mom_bounded_by_data_range(w_buckets, seed):
    w, m = w_buckets
    rng = np.random.default_rng(seed)
    data = rng.normal(size=(3, w)).astype(np.float32)
    est = np.asarray(robust.median_of_means(jnp.asarray(data), jnp.int32(w), m))
    assert np.all(est >= data.min(-1) - 1e-5)
    assert np.all(est <= data.max(-1) + 1e-5)


@given(seed=st.integers(0, 999), scale=st.floats(10.0, 1e6))
@settings(**SETTINGS)
def test_mom_beats_mean_under_one_outlier(seed, scale):
    w, m = 16, 4
    rng = np.random.default_rng(seed)
    data = rng.normal(size=w).astype(np.float32)
    data[int(rng.integers(w))] += scale
    est = float(robust.median_of_means(jnp.asarray(data)[None], jnp.int32(w), m)[0])
    mean = float(data.mean())
    true = 0.0
    assert abs(est - true) <= abs(mean - true) + 1e-3


# -------------------------------------------------------------- sampler

@given(seed=st.integers(0, 500), k=st.integers(1, 20),
       p=st.floats(0.1, 1.0))
@settings(**SETTINGS)
def test_sampler_respects_topk_support(seed, k, p):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(3, 64)).astype(np.float32))
    toks = sampler.sample(jax.random.PRNGKey(seed), logits,
                          temperature=0.7, top_k=k, top_p=p)
    topk_sets = np.argsort(-np.asarray(logits), axis=-1)[:, :k]
    for b in range(3):
        assert int(toks[b]) in topk_sets[b]


def test_sampler_greedy_is_argmax():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(5, 32)))
    toks = sampler.sample(jax.random.PRNGKey(0), logits, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, -1)))


# ------------------------------------------------------- bucket chains

@given(n=st.integers(1, 129))
@settings(**SETTINGS)
def test_bucket_chain_properties(n):
    chain = cache_lib.bucket_chain(n)
    assert chain[0] == n and chain[-1] == 1 or n == 1
    assert all(a > b for a, b in zip(chain, chain[1:]))
    for alive in range(1, n + 1):
        b = cache_lib.next_bucket(chain, alive, n)
        assert b >= alive
        assert b in chain


# -------------------------------------------------- PageAllocator (COW)
#
# The invariant checker and the alloc/share/COW-diverge/free op-stream
# interpreter live in tests/allocator_harness.py, shared with the
# seeded tier-1 twin in test_paged.py (this module skips entirely when
# hypothesis is absent).

from allocator_harness import run_allocator_ops  # noqa: E402


@given(num_pages=st.integers(4, 24), page_size=st.sampled_from([4, 8]),
       rows=st.integers(2, 8), max_pages=st.integers(1, 6),
       ops=st.lists(st.tuples(
           st.sampled_from(["alloc", "share", "diverge", "free",
                            "pin", "unpin"]),
           st.integers(0, 10 ** 6), st.integers(0, 10 ** 6)),
           max_size=60))
@settings(**SETTINGS)
def test_page_allocator_interleaving_invariants(num_pages, page_size, rows,
                                                max_pages, ops):
    """Random interleavings of alloc / share / COW-diverge / free /
    radix-pin / unpin keep every allocator invariant (refcount = table
    refs + pins) and leak nothing at quiescence after the tree drop."""
    run_allocator_ops(num_pages, page_size, rows, max_pages, ops)


# ----------------------------------------------------------------- data

@given(seed=st.integers(0, 2000), num_ops=st.integers(1, 3),
       max_operand=st.integers(2, 96))
@settings(**SETTINGS)
def test_task_answer_is_extractable_and_correct(seed, num_ops, max_operand):
    rng = np.random.default_rng(seed)
    p = tasks.make_problem(rng, num_ops=num_ops, max_operand=max_operand)
    assert tok.extract_answer(p.target) == p.answer
    assert 0 <= p.answer < tok.MOD
    # target structure: pairs of (ARROW, value) then ANS value EOS
    assert p.target[-1] == tok.EOS
    assert p.target[-3] == tok.ANS
    # prompt is well formed
    assert p.prompt[0] == tok.BOS and p.prompt[-1] == tok.QM


@given(seed=st.integers(0, 2000))
@settings(**SETTINGS)
def test_pack_batch_mask_covers_target_only(seed):
    rng = np.random.default_rng(seed)
    probs = [tasks.make_problem(rng) for _ in range(4)]
    toks, mask = tasks.pack_batch(probs, 48)
    for i, p in enumerate(probs):
        lo, hi = len(p.prompt), min(len(p.prompt) + len(p.target), 48)
        assert mask[i, :lo - 1].sum() == 0
        assert mask[i, lo - 1:hi - 1].sum() == hi - lo
