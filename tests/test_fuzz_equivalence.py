"""Randomized cross-scheduler fuzz equivalence (PR 5 satellite).

One generated workload — mixed kappa/bon/stbon/greedy strategies,
random prompt lengths (including page-aligned prompts and prompts
shorter than one chunk), a random shared preamble so request mixes
overlap on token prefixes, random per-request ``max_new``, random
submit order — is served six ways and must stay token-for-token
identical:

  * the sequential engine (the reference),
  * the contiguous scheduler with chunked admission,
  * the paged scheduler with chunked admission (generous pages),
  * the paged scheduler under forced page pressure (preemption live),
  * the paged scheduler with the radix prefix cache on (PR 6): later
    requests alias earlier requests' published pages,
  * the prefix cache under forced page pressure (eviction racing
    preemption).

Shapes are pinned (one ``max_seq``, one page size, a small chunk-size
menu) so the jit cache is shared across cases and the sweep stays
CPU-friendly. With hypothesis installed the sweep draws cases through
real strategies (seeded, shrinkable); without it a fixed seed list
exercises the same generator. One small case runs in tier-1; the sweep
is marked ``slow`` + ``fuzz``.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import KappaConfig
from repro.data import tokenizer as tok
from repro.models import init_params
from repro.serving import engine
from repro.serving.scheduler import ContinuousBatchingScheduler, PagedScheduler

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # tier-1 still runs the seeded generator
    HAVE_HYPOTHESIS = False

MAX_SEQ = 32                 # fixed: every case shares one compiled shape
PAGE_SIZE = 4
METHODS = ("kappa", "bon", "stbon", "greedy")
# prompt lengths: 8 and 16 are page-aligned (no COW boundary page),
# 3 is shorter than every chunk size in the menu
PLENS = (3, 5, 8, 9, 12, 16)
MAX_NEWS = (4, 6, 10, 14)
CHUNKS = (4, 5, 7)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("deepseek-r1-distill-qwen-1.5b").reduced(
        num_layers=2, d_model=64, vocab_size=tok.VOCAB_SIZE)
    params = init_params(jax.random.PRNGKey(0), cfg)
    kcfg = KappaConfig(num_branches=4, max_new_tokens=20, max_cutoff=4,
                       horizon=6, window=8, mom_buckets=4)
    return cfg, params, kcfg


PRE_LENS = (0, 4, 8, 11)     # shared-preamble lengths (0 = disjoint)


def _case_from_seed(seed: int, n_requests=None):
    """Seeded case generator — the no-hypothesis path (and the prompt
    body source for both paths)."""
    rng = np.random.default_rng(seed)
    n = n_requests or int(rng.integers(2, 5))
    reqs = []
    for _ in range(n):
        reqs.append((METHODS[int(rng.integers(len(METHODS)))],
                     int(rng.choice(PLENS)),
                     int(rng.choice(MAX_NEWS))))
    return {"seed": seed, "reqs": reqs,
            "order": rng.permutation(n).tolist(),
            "chunk": int(rng.choice(CHUNKS)),
            "pre_len": int(rng.choice(PRE_LENS))}


def _prompt(seed: int, i: int, plen: int, pre_len: int = 0) -> np.ndarray:
    """BOS + shared preamble prefix + private body + QM. Every request
    of one case draws the SAME per-case preamble, so requests whose
    bodies are long enough share a real token prefix — the radix
    prefix-cache hit population (and, truncated at ``plen``, a source of
    partial-page overlaps the page-granular keying must not match)."""
    body_len = plen - 2
    head = np.random.default_rng(seed * 7 + 3).integers(
        0, tok.MOD, size=min(pre_len, body_len))
    body = np.random.default_rng(seed * 1000 + i).integers(
        0, tok.MOD, size=body_len - len(head))
    return np.concatenate([[tok.BOS], head, body, [tok.QM]])


def _worst_pages(method: str, plen: int, max_new: int, n_branch: int) -> int:
    n = 1 if method == "greedy" else n_branch
    full = plen // PAGE_SIZE
    need = -(-(plen + max_new) // PAGE_SIZE)
    return full + n * (need - full)


from allocator_harness import check_invariants as _allocator_invariants  # noqa: E402


def _run_case(setup, case):
    cfg, params, kcfg = setup
    reqs, order, chunk = case["reqs"], case["order"], case["chunk"]
    pre_len = case.get("pre_len", 0)
    prompts = [_prompt(case["seed"], i, plen, pre_len)
               for i, (_, plen, _) in enumerate(reqs)]

    seq = []
    for i, (method, _, max_new) in enumerate(reqs):
        import dataclasses
        kc = dataclasses.replace(kcfg, max_new_tokens=max_new)
        fn = getattr(engine, f"generate_{method}")
        seq.append(fn(params, cfg, kc, prompts[i], jax.random.PRNGKey(i),
                      eos_id=tok.EOS, bos_id=tok.BOS, max_seq=MAX_SEQ))

    def serve(sched):
        rids = {}
        for i in order:
            method, _, max_new = reqs[i]
            rids[i] = sched.submit(prompts[i], jax.random.PRNGKey(i),
                                   max_new=max_new, method=method)
        res = sched.run()
        return {i: res[r] for i, r in rids.items()}

    tight = max(_worst_pages(m, p, mn, kcfg.num_branches)
                for m, p, mn in reqs) + 2
    modes = {
        "contiguous": ContinuousBatchingScheduler(
            params, cfg, kcfg, rows=8, max_seq=MAX_SEQ, method="kappa",
            eos_id=tok.EOS, bos_id=tok.BOS, prefill_chunk=chunk),
        "paged": PagedScheduler(
            params, cfg, kcfg, rows=8, max_seq=MAX_SEQ,
            page_size=PAGE_SIZE, num_pages=8 * MAX_SEQ // PAGE_SIZE,
            method="kappa", eos_id=tok.EOS, bos_id=tok.BOS,
            prefill_chunk=chunk),
        "paged-pressure": PagedScheduler(
            params, cfg, kcfg, rows=8, max_seq=MAX_SEQ,
            page_size=PAGE_SIZE, num_pages=tight, method="kappa",
            eos_id=tok.EOS, bos_id=tok.BOS, prefill_chunk=chunk),
        "paged-prefix": PagedScheduler(
            params, cfg, kcfg, rows=8, max_seq=MAX_SEQ,
            page_size=PAGE_SIZE, num_pages=8 * MAX_SEQ // PAGE_SIZE,
            method="kappa", eos_id=tok.EOS, bos_id=tok.BOS,
            prefill_chunk=chunk, prefix_cache=True),
        "paged-prefix-pressure": PagedScheduler(
            params, cfg, kcfg, rows=8, max_seq=MAX_SEQ,
            page_size=PAGE_SIZE, num_pages=tight, method="kappa",
            eos_id=tok.EOS, bos_id=tok.BOS, prefill_chunk=chunk,
            prefix_cache=True),
    }
    for name, sched in modes.items():
        res = serve(sched)
        for i, s in enumerate(seq):
            c = res[i]
            ctx = f"case={case} mode={name} req={i} ({reqs[i]})"
            assert s.tokens == c.tokens, ctx
            assert s.chosen_branch == c.chosen_branch, ctx
            assert s.logical_tokens == c.logical_tokens, ctx
            assert s.steps == c.steps, ctx
        assert sorted(sched.free) == list(range(8)), name
        assert not sched.prefilling and not sched.active, name
        if getattr(sched, "pcache", None) is not None:
            _allocator_invariants(sched.alloc)   # with live pins
            sched.pcache.drop()                  # tree drop frees pins
        if hasattr(sched, "alloc"):
            assert sched.alloc.free_count == sched.num_pages, \
                f"{name}: leaked pages"
            assert int(sched.alloc.pinned.sum()) == 0, name
            _allocator_invariants(sched.alloc)


# ------------------------------------------------------------- tier-1

def test_fuzz_equivalence_small(setup):
    """One small fixed case in tier-1: mixed methods, a page-aligned
    prompt, a prompt shorter than the chunk, forced page pressure."""
    case = {"seed": 7,
            "reqs": [("kappa", 8, 10), ("greedy", 3, 6), ("bon", 9, 6)],
            "order": [1, 0, 2], "chunk": 5, "pre_len": 8}
    _run_case(setup, case)


def test_fuzz_equivalence_int8_small(setup):
    """The tier-1 case replayed with a quantized KV cache: all six
    serving modes must stay token-for-token equal to the (also int8)
    sequential reference — COW, preemption replay and the prefix cache
    move quantized pages plus their scale leaves, never re-rounding."""
    import dataclasses
    cfg, params, kcfg = setup
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    case = {"seed": 7,
            "reqs": [("kappa", 8, 10), ("greedy", 3, 6), ("bon", 9, 6)],
            "order": [1, 0, 2], "chunk": 5, "pre_len": 8}
    _run_case((cfg8, params, kcfg), case)


def test_fuzz_equivalence_stbon_aligned(setup):
    """Second fixed tier-1 case: ST-BoN in the mix, prompt length an
    exact multiple of both page size and chunk."""
    case = {"seed": 13,
            "reqs": [("stbon", 16, 10), ("kappa", 5, 6)],
            "order": [0, 1], "chunk": 4, "pre_len": 11}
    _run_case(setup, case)


# --------------------------------------------------------------- chaos

from repro.serving.faults import FaultPlan  # noqa: E402

TERMINAL = {"OK", "CANCELLED", "TIMEOUT", "FAILED", "SHED"}


def _run_chaos_case(setup, case):
    """The lifecycle-hardening twin of :func:`_run_case`: the same
    workload served under seeded fault injection plus random cancels
    and tick budgets, on both schedulers (prefix cache on and off).
    Every request must reach a terminal status, OK survivors must stay
    token-for-token equal to the sequential reference (the fault-replay
    determinism guarantee), and nothing may leak."""
    cfg, params, kcfg = setup
    reqs, order, chunk = case["reqs"], case["order"], case["chunk"]
    pre_len = case.get("pre_len", 0)
    prompts = [_prompt(case["seed"], i, plen, pre_len)
               for i, (_, plen, _) in enumerate(reqs)]
    cancels = dict(case.get("cancel", {}))   # req index -> cancel tick
    budgets = case.get("ticks", {})          # req index -> max_wall_ticks

    seq = []
    for i, (method, _, max_new) in enumerate(reqs):
        import dataclasses
        kc = dataclasses.replace(kcfg, max_new_tokens=max_new)
        fn = getattr(engine, f"generate_{method}")
        seq.append(fn(params, cfg, kc, prompts[i], jax.random.PRNGKey(i),
                      eos_id=tok.EOS, bos_id=tok.BOS, max_seq=MAX_SEQ))

    # a fresh FaultPlan per mode: its memo/fired state is mutable, and
    # the two backends' tick counts differ
    def plan():
        return FaultPlan(seed=case["fault_seed"], max_faults=6)

    common = dict(rows=8, max_seq=MAX_SEQ, method="kappa", eos_id=tok.EOS,
                  bos_id=tok.BOS, prefill_chunk=chunk, max_retries=8)
    modes = {
        "contiguous": lambda: ContinuousBatchingScheduler(
            params, cfg, kcfg, faults=plan(), **common),
        "paged": lambda: PagedScheduler(
            params, cfg, kcfg, page_size=PAGE_SIZE,
            num_pages=8 * MAX_SEQ // PAGE_SIZE, faults=plan(), **common),
        "paged-prefix": lambda: PagedScheduler(
            params, cfg, kcfg, page_size=PAGE_SIZE,
            num_pages=8 * MAX_SEQ // PAGE_SIZE, prefix_cache=True,
            faults=plan(), **common),
    }
    for name, mk in modes.items():
        sched = mk()
        rids = {}
        for i in order:
            method, _, max_new = reqs[i]
            rids[i] = sched.submit(prompts[i], jax.random.PRNGKey(i),
                                   max_new=max_new, method=method,
                                   max_wall_ticks=budgets.get(i))
        pending = dict(cancels)
        for _ in range(600):                 # bounded: a wedge fails loudly
            if not (sched.queue or sched.active or sched.prefilling):
                break
            for i in [i for i, t in pending.items() if sched.ticks >= t]:
                sched.cancel(rids[i])
                del pending[i]
            sched.tick()
        assert not (sched.queue or sched.active or sched.prefilling), \
            f"{name}: pool did not drain under chaos (case={case})"

        res = {i: sched.results[r] for i, r in rids.items()}
        for i, s in enumerate(seq):
            c = res[i]
            ctx = f"case={case} mode={name} req={i} ({reqs[i]})"
            assert c.status in TERMINAL, ctx
            if c.status == "OK" and i not in cancels and i not in budgets:
                # an undisturbed-or-replayed survivor is token-equal
                assert s.tokens == c.tokens, ctx
                assert s.chosen_branch == c.chosen_branch, ctx
                assert s.logical_tokens == c.logical_tokens, ctx
        # zero-leak: every row, page and pin returned
        assert sorted(sched.free) == list(range(8)), name
        if getattr(sched, "pcache", None) is not None:
            _allocator_invariants(sched.alloc)
            sched.pcache.drop()
        if hasattr(sched, "alloc"):
            assert sched.alloc.free_count == sched.num_pages, \
                f"{name}: leaked pages under chaos"
            assert int(sched.alloc.pinned.sum()) == 0, name
            _allocator_invariants(sched.alloc)


def _chaos_case_from_seed(seed: int):
    case = _case_from_seed(seed)
    rng = np.random.default_rng(seed + 5000)
    n = len(case["reqs"])
    case["fault_seed"] = int(rng.integers(0, 100))
    if rng.random() < 0.7:
        case["cancel"] = {int(rng.integers(n)): int(rng.integers(2, 20))}
    if rng.random() < 0.7:
        case["ticks"] = {int(rng.integers(n)): int(rng.integers(4, 25))}
    return case


@pytest.mark.faults
def test_chaos_lifecycle_small(setup):
    """Tier-1 chaos case: faults + one mid-run cancel + one tick budget
    over mixed methods, all three serving modes."""
    case = {"seed": 21, "fault_seed": 5,
            "reqs": [("kappa", 8, 10), ("greedy", 5, 6), ("bon", 9, 6)],
            "order": [2, 0, 1], "chunk": 4, "pre_len": 4,
            "cancel": {1: 6}, "ticks": {2: 15}}
    _run_chaos_case(setup, case)


@pytest.mark.slow
@pytest.mark.fuzz
@pytest.mark.faults
@pytest.mark.parametrize("seed", [5, 17, 29, 41])
def test_chaos_lifecycle_sweep(setup, seed):
    _run_chaos_case(setup, _chaos_case_from_seed(seed))


# --------------------------------------------------------------- sweep

if HAVE_HYPOTHESIS:
    @pytest.mark.slow
    @pytest.mark.fuzz
    @settings(max_examples=8, deadline=None)
    @given(data=st.data())
    def test_fuzz_equivalence_sweep(setup, data):
        n = data.draw(st.integers(2, 4), label="n_requests")
        reqs = [(data.draw(st.sampled_from(METHODS), label=f"method{i}"),
                 data.draw(st.sampled_from(PLENS), label=f"plen{i}"),
                 data.draw(st.sampled_from(MAX_NEWS), label=f"max_new{i}"))
                for i in range(n)]
        order = data.draw(st.permutations(range(n)), label="order")
        case = {"seed": data.draw(st.integers(0, 9999), label="seed"),
                "reqs": reqs, "order": list(order),
                "chunk": data.draw(st.sampled_from(CHUNKS), label="chunk"),
                "pre_len": data.draw(st.sampled_from(PRE_LENS),
                                     label="pre_len")}
        _run_case(setup, case)
else:
    @pytest.mark.slow
    @pytest.mark.fuzz
    @pytest.mark.parametrize("seed", [11, 23, 37, 59])
    def test_fuzz_equivalence_sweep(setup, seed):
        _run_case(setup, _case_from_seed(seed))
