"""int8 KV cache: decode stays within quantization tolerance of the
teacher-forced logits; byte accounting reflects the 2× saving."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import decode_step, init_cache, init_params, prefill, train_logits
from repro.serving import cache as cache_lib


@pytest.mark.parametrize("arch", ["granite-3-8b", "gemma3-4b", "starcoder2-3b"])
def test_int8_cache_decode_close_to_fp(arch):
    cfg = dataclasses.replace(get_config(arch).reduced(), kv_cache_dtype="int8")
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    logits, _ = train_logits(params, cfg, tokens)
    cache = init_cache(cfg, B, max_seq=32)
    pf, cache = prefill(params, cfg, tokens[:, :S - 1], cache)
    # prefill attends over the dequantized cache — the same values every
    # serving mode (one-shot, chunked, paged) and decode see, so int8
    # results never depend on how a prompt was admitted. The price is
    # that prefill logits carry quantization noise like decode does:
    # close to fp, not exact.
    a, b = np.asarray(pf).ravel(), np.asarray(logits[:, S - 2]).ravel()
    corr = np.corrcoef(a, b)[0, 1]
    assert corr > 0.999, f"int8 prefill drifted: corr={corr}"
    assert np.max(np.abs(a - b)) < 0.5
    dec, _ = decode_step(params, cfg, tokens[:, S - 1], jnp.int32(S - 1), cache)
    a, b = np.asarray(dec).ravel(), np.asarray(logits[:, S - 1]).ravel()
    corr = np.corrcoef(a, b)[0, 1]
    assert corr > 0.999, f"int8 decode drifted: corr={corr}"
    assert np.max(np.abs(a - b)) < 0.5


def test_int8_cache_leaves_are_int8():
    cfg = dataclasses.replace(get_config("granite-3-8b").reduced(),
                              kv_cache_dtype="int8")
    cache = init_cache(cfg, 2, 16)
    leaves = {str(l.dtype) for l in jax.tree.leaves(cache)}
    assert "int8" in leaves and "float32" in leaves


def test_int8_used_bytes_half_of_bf16():
    cfg = get_config("granite-3-8b")
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    b16 = cache_lib.used_cache_bytes(cfg, 8, 1000, 4096)
    b8 = cache_lib.used_cache_bytes(cfg8, 8, 1000, 4096)
    assert 0.4 < b8 / b16 < 0.6
