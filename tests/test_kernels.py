"""Pallas kernel sweeps: shapes × dtypes, assert_allclose against the
pure-jnp ref.py oracles (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attn.ops import (decode_attn, paged_decode_attn,
                                           paged_prefill_attn)
from repro.kernels.decode_attn.ref import (decode_attn_ref,
                                           paged_decode_attn_ref,
                                           paged_prefill_attn_ref)
from repro.kernels.fused_score.ops import fused_score
from repro.kernels.fused_score.ref import fused_score_ref
from repro.kernels.rwkv6_scan.ops import rwkv6_scan
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref


# ------------------------------------------------------------ fused_score

@pytest.mark.parametrize("B,V", [(1, 128), (5, 1000), (8, 4096), (16, 2048),
                                 (3, 50257), (2, 151936)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_score_sweep(B, V, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(B * V))
    logits = (jax.random.normal(k1, (B, V)) * 3).astype(dtype)
    log_q = jax.nn.log_softmax(jax.random.normal(k2, (V,)))
    out = fused_score(logits, log_q)
    ref = fused_score_ref(logits, log_q)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    for o, r, name in zip(out, ref, ["kl", "conf", "ent"]):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   rtol=tol, atol=tol, err_msg=name)


def test_fused_score_extreme_logits():
    """Large-magnitude logits must not overflow the online softmax."""
    logits = jnp.array([[1e4, 0.0, -1e4] + [0.0] * 125,
                        [-1e4] * 64 + [1e4] * 64])
    log_q = jax.nn.log_softmax(jnp.zeros(128))
    kl, conf, ent = fused_score(logits, log_q)
    rkl, rconf, rent = fused_score_ref(logits, log_q)
    assert np.all(np.isfinite(np.asarray(kl)))
    np.testing.assert_allclose(np.asarray(conf), np.asarray(rconf), rtol=1e-4)


def test_fused_score_odd_vocab_padding():
    """Non-tile-multiple vocab (e.g. granite's 49155) pads correctly."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(9))
    logits = jax.random.normal(k1, (4, 49155))
    log_q = jax.nn.log_softmax(jax.random.normal(k2, (49155,)))
    out = fused_score(logits, log_q)
    ref = fused_score_ref(logits, log_q)
    for o, r in zip(out, ref):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=1e-4,
                                   atol=1e-5)


# ------------------------------------------------------------ decode_attn

@pytest.mark.parametrize("B,H,KV,hd,S,pos,window,ring", [
    (2, 8, 2, 64, 256, 100, 0, False),       # GQA, early pos
    (1, 4, 4, 32, 512, 511, 0, False),       # MHA, cache full
    (2, 6, 3, 128, 300, 299, 64, False),     # sliding window, odd S
    (2, 4, 1, 64, 128, 500, 128, True),      # MQA ring buffer, wrapped
    (1, 16, 2, 64, 1024, 700, 256, True),    # ring, window < ring size
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attn_sweep(B, H, KV, hd, S, pos, window, ring, dtype):
    ks = jax.random.split(jax.random.PRNGKey(hash((B, H, S, pos)) % 2**31), 3)
    q = jax.random.normal(ks[0], (B, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd)).astype(dtype)
    out = decode_attn(q, k, v, pos, window=window, ring=ring)
    ref = decode_attn_ref(q, k, v, pos, window=window, ring=ring)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,H,KV,hd,ps,MP,P", [
    (2, 8, 2, 64, 16, 4, 12),     # GQA
    (1, 4, 4, 32, 8, 8, 10),      # MHA, many small pages
    (3, 6, 3, 128, 32, 2, 8),     # odd head count, 2 logical pages
    (2, 4, 1, 64, 64, 3, 7),      # MQA, page = S-tile
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attn_sweep(B, H, KV, hd, ps, MP, P, dtype):
    """Paged kernel vs the pure-jnp paged oracle, scrambled block tables
    and per-row positions (trash-aliased tails included)."""
    rng = np.random.RandomState(B * H + ps)
    ks = jax.random.split(jax.random.PRNGKey(hash((B, H, ps, MP)) % 2**31), 3)
    q = jax.random.normal(ks[0], (B, H, hd)).astype(dtype)
    kp = jax.random.normal(ks[1], (P, ps, KV, hd)).astype(dtype)
    vp = jax.random.normal(ks[2], (P, ps, KV, hd)).astype(dtype)
    # each row: random position, owned pages drawn without replacement,
    # unowned entries alias the last physical page (trash convention)
    pos = rng.randint(0, MP * ps, size=B).astype(np.int32)
    bt = np.full((B, MP), P - 1, np.int32)
    for b in range(B):
        owned = pos[b] // ps + 1
        bt[b, :owned] = rng.choice(P - 1, size=owned, replace=False)
    out = paged_decode_attn(q, kp, vp, jnp.asarray(bt), jnp.asarray(pos))
    ref = paged_decode_attn_ref(q, kp, vp, jnp.asarray(bt), jnp.asarray(pos))
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


def _quantize_pages(x):
    """Per-(page, slot, kv-head) absmax int8 quantization — the same
    layout the serving cache uses for its ``k_s``/``v_s`` scale leaves."""
    x = np.asarray(x, np.float32)
    s = np.maximum(np.abs(x).max(axis=-1), 1e-8) / 127.0
    q = np.clip(np.round(x / s[..., None]), -127, 127).astype(np.int8)
    return jnp.asarray(q), jnp.asarray(s.astype(np.float32))


def _scrambled_tables(rng, B, MP, P, ps, pos):
    """Owned pages drawn without replacement, tails alias the trash page
    (index P-1) — same convention as the fp sweep above."""
    bt = np.full((B, MP), P - 1, np.int32)
    for b in range(B):
        owned = int(pos[b]) // ps + 1
        bt[b, :owned] = rng.choice(P - 1, size=owned, replace=False)
    return bt


@pytest.mark.parametrize("B,H,KV,hd,ps,MP,P", [
    (2, 8, 2, 64, 16, 4, 12),     # GQA
    (1, 4, 4, 32, 8, 8, 10),      # MHA, many small pages
    (2, 4, 1, 64, 64, 3, 7),      # MQA, page = S-tile
])
def test_paged_decode_attn_int8_sweep(B, H, KV, hd, ps, MP, P):
    """Int8 paged kernel vs the int8-aware oracle: both dequantize the
    same int8 pages with the same scales, so the comparison is tight.
    A loose check against the unquantized oracle bounds the actual
    quantization error."""
    rng = np.random.RandomState(B * H + ps + 1)
    ks = jax.random.split(jax.random.PRNGKey(hash((B, H, ps, MP, 8)) % 2**31), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (P, ps, KV, hd))
    vp = jax.random.normal(ks[2], (P, ps, KV, hd))
    kq, ksc = _quantize_pages(kp)
    vq, vsc = _quantize_pages(vp)
    pos = rng.randint(0, MP * ps, size=B).astype(np.int32)
    bt = _scrambled_tables(rng, B, MP, P, ps, pos)
    out = paged_decode_attn(q, kq, vq, jnp.asarray(bt), jnp.asarray(pos),
                            k_scales=ksc, v_scales=vsc)
    ref = paged_decode_attn_ref(q, kq, vq, jnp.asarray(bt), jnp.asarray(pos),
                                k_scales=ksc, v_scales=vsc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    full = paged_decode_attn_ref(q, kp, vp, jnp.asarray(bt), jnp.asarray(pos))
    np.testing.assert_allclose(np.asarray(out), np.asarray(full),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("B,C,H,KV,hd,ps,MP,P", [
    (2, 4, 8, 2, 64, 16, 4, 12),  # GQA, mid-size chunk
    (1, 7, 4, 4, 32, 8, 8, 10),   # MHA, chunk not a page multiple
    (2, 1, 4, 1, 64, 16, 3, 7),   # MQA, single-token chunk (= decode)
    (1, 16, 6, 3, 128, 32, 2, 8), # odd head count, chunk = half a page
])
@pytest.mark.parametrize("quant", [False, True])
def test_paged_prefill_attn_sweep(B, C, H, KV, hd, ps, MP, P, quant):
    """Paged chunk-prefill kernel vs the pure-jnp causal oracle: random
    chunk offsets ``pos0`` (chunk straddles page boundaries), scrambled
    block tables, fp32 and int8 pages."""
    rng = np.random.RandomState(B * C + ps)
    ks = jax.random.split(jax.random.PRNGKey(hash((B, C, H, ps)) % 2**31), 3)
    q = jax.random.normal(ks[0], (B, C, H, hd))
    kp = jax.random.normal(ks[1], (P, ps, KV, hd))
    vp = jax.random.normal(ks[2], (P, ps, KV, hd))
    # pos0 = position of the chunk's FIRST token; last token must fit
    pos0 = rng.randint(0, MP * ps - C + 1, size=B).astype(np.int32)
    last = pos0 + C - 1
    bt = _scrambled_tables(rng, B, MP, P, ps, last)
    if quant:
        kp, ksc = _quantize_pages(kp)
        vp, vsc = _quantize_pages(vp)
    else:
        ksc = vsc = None
    out = paged_prefill_attn(q, kp, vp, jnp.asarray(bt), jnp.asarray(pos0),
                             k_scales=ksc, v_scales=vsc)
    ref = paged_prefill_attn_ref(q, kp, vp, jnp.asarray(bt),
                                 jnp.asarray(pos0),
                                 k_scales=ksc, v_scales=vsc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_paged_prefill_single_token_matches_decode():
    """A one-token chunk through the prefill entry equals the decode
    entry bitwise — they share one kernel body."""
    B, H, KV, hd, ps, MP, P = 2, 8, 2, 64, 16, 4, 12
    rng = np.random.RandomState(0)
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (P, ps, KV, hd))
    vp = jax.random.normal(ks[2], (P, ps, KV, hd))
    pos = rng.randint(0, MP * ps, size=B).astype(np.int32)
    bt = _scrambled_tables(rng, B, MP, P, ps, pos)
    d = paged_decode_attn(q, kp, vp, jnp.asarray(bt), jnp.asarray(pos))
    p = paged_prefill_attn(q[:, None], kp, vp, jnp.asarray(bt),
                           jnp.asarray(pos))
    assert np.array_equal(np.asarray(d), np.asarray(p[:, 0]))


def test_paged_decode_attn_matches_contiguous_kernel():
    """Gathering a row's pages into a contiguous cache and running the
    existing flash-decode kernel gives the same answer — the paged kernel
    only changes *where* the S-tiles come from."""
    B, H, KV, hd, ps, MP, P = 3, 8, 2, 64, 16, 4, 14
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (P, ps, KV, hd))
    vp = jax.random.normal(ks[2], (P, ps, KV, hd))
    rng = np.random.RandomState(1)
    bt = np.stack([rng.choice(P, size=MP, replace=False) for _ in range(B)])
    pos = np.array([5, 63, 40], np.int32)
    out = paged_decode_attn(q, kp, vp, jnp.asarray(bt), jnp.asarray(pos))
    for b in range(B):
        kc = kp[jnp.asarray(bt[b])].reshape(1, MP * ps, KV, hd)
        vc = vp[jnp.asarray(bt[b])].reshape(1, MP * ps, KV, hd)
        oc = decode_attn(q[b:b + 1], kc, vc, int(pos[b]))
        np.testing.assert_allclose(np.asarray(out[b:b + 1]), np.asarray(oc),
                                   rtol=2e-5, atol=2e-5)


def test_decode_attn_pos_zero():
    """Only slot 0 valid — attention must equal v[:, 0]."""
    B, H, KV, hd, S = 1, 2, 2, 16, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    out = decode_attn(q, k, v, 0)
    np.testing.assert_allclose(np.asarray(out[0, 0]), np.asarray(v[0, 0, 0]),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- rwkv6_scan

@pytest.mark.parametrize("B,T,H,hd,chunk", [
    (2, 64, 3, 32, 16), (1, 128, 2, 64, 32), (2, 50, 2, 16, 32),
    (1, 33, 4, 64, 16),
])
@pytest.mark.parametrize("with_s0", [False, True])
def test_rwkv6_scan_sweep(B, T, H, hd, chunk, with_s0):
    ks = jax.random.split(jax.random.PRNGKey(T * hd + with_s0), 6)
    r = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd))
    v = jax.random.normal(ks[2], (B, T, H, hd))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd))) * 0.98 + 0.01
    u = jax.random.normal(ks[4], (H, hd)) * 0.5
    s0 = jax.random.normal(ks[5], (B, H, hd, hd)) * 0.1 if with_s0 else None
    y, sf = rwkv6_scan(r, k, v, w, u, s0, chunk=chunk)
    s0_ref = s0 if s0 is not None else jnp.zeros((B, H, hd, hd))
    yr, sr = rwkv6_scan_ref(r, k, v, w, u, s0_ref)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr), rtol=3e-4, atol=3e-4)


def test_rwkv6_scan_tiny_decays():
    """Near-zero decay (strong forgetting) must stay finite in the
    log-space chunked form."""
    B, T, H, hd = 1, 32, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    r = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd))
    v = jax.random.normal(ks[2], (B, T, H, hd))
    w = jnp.full((B, T, H, hd), 1e-6)
    u = jnp.zeros((H, hd))
    y, sf = rwkv6_scan(r, k, v, w, u, chunk=16)
    yr, sr = rwkv6_scan_ref(r, k, v, w, u, jnp.zeros((B, H, hd, hd)))
    assert np.all(np.isfinite(np.asarray(y)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-3, atol=1e-3)


def test_rwkv6_chunk_boundary_equivalence():
    """Different chunk sizes give identical results (associativity)."""
    B, T, H, hd = 1, 64, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    r = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd))
    v = jax.random.normal(ks[2], (B, T, H, hd))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd)))
    u = jnp.ones((H, hd)) * 0.3
    y16, s16 = rwkv6_scan(r, k, v, w, u, chunk=16)
    y32, s32 = rwkv6_scan(r, k, v, w, u, chunk=32)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y32), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s16), np.asarray(s32), rtol=2e-4,
                               atol=2e-4)
