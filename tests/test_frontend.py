"""Streaming front-end semantics (DESIGN.md §9): per-rid event order
with exactly one terminal event, token-for-token equality between
streamed and batch ``run()`` serving across all three backends,
mid-stream cancellation, mixed-strategy concurrent streams vs the
sequential engine, the thread fallback backend, and zero-leak
shutdown. No pytest-asyncio: each async scenario runs under
``asyncio.run`` inside a sync test."""
import asyncio
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import KappaConfig
from repro.data import tokenizer as tok
from repro.models import init_params
from repro.serving import engine
from repro.serving.frontend import ServingFrontend
from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                     PagedScheduler)

MAX_SEQ = 32
PAGE_SIZE = 4
ROWS = 8
BACKENDS = ["contig", "paged", "paged+prefix"]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("deepseek-r1-distill-qwen-1.5b").reduced(
        num_layers=2, d_model=64, vocab_size=tok.VOCAB_SIZE)
    params = init_params(jax.random.PRNGKey(0), cfg)
    kcfg = KappaConfig(num_branches=4, max_new_tokens=12, max_cutoff=4,
                       horizon=6, window=8, mom_buckets=4)
    prompts = [
        np.array([tok.BOS, tok.PROB, 3, tok.PLUS, 4, tok.EQ, tok.QM]),
        np.array([tok.BOS, tok.PROB, 7, tok.PLUS, 2, tok.PLUS, 1, tok.EQ,
                  tok.QM]),
        np.array([tok.BOS, tok.PROB, 5, tok.PLUS, 5, tok.EQ, tok.QM]),
    ]
    return cfg, params, kcfg, prompts


def _mk(setup, backend, **kw):
    cfg, params, kcfg, _ = setup
    base = dict(rows=ROWS, max_seq=MAX_SEQ, method="kappa",
                eos_id=tok.EOS, bos_id=tok.BOS, prefill_chunk=4)
    base.update(kw)
    if backend == "contig":
        return ContinuousBatchingScheduler(params, cfg, kcfg, **base)
    return PagedScheduler(params, cfg, kcfg, page_size=PAGE_SIZE,
                          num_pages=ROWS * MAX_SEQ // PAGE_SIZE,
                          prefix_cache=backend.endswith("prefix"), **base)


def _assert_no_leaks(sched):
    assert sorted(sched.free) == list(range(sched.rows))
    assert not sched.active and not sched.prefilling and not sched.queue
    if getattr(sched, "pcache", None) is not None:
        sched.pcache.drop()
    if hasattr(sched, "alloc"):
        assert sched.alloc.free_count == sched.num_pages, "leaked pages"
        assert int(sched.alloc.pinned.sum()) == 0, "leaked pins"


async def _consume(fe, prompt, i, **kw):
    """Stream one request; returns (events, token list, terminal result)."""
    evs = []
    async for ev in fe.submit_stream(prompt, jax.random.PRNGKey(i), **kw):
        evs.append(ev)
    toks = [e.token for e in evs if e.kind == "token"]
    return evs, toks, evs[-1].result


# ------------------------------------------------------- event contract

def test_event_order_and_single_terminal(setup):
    _, _, _, prompts = setup
    sched = _mk(setup, "paged")

    async def go():
        async with ServingFrontend(sched) as fe:
            return await asyncio.gather(
                *[_consume(fe, p, i) for i, p in enumerate(prompts)])

    for evs, toks, res in asyncio.run(go()):
        ends = [e for e in evs if e.kind == "end"]
        assert len(ends) == 1, "exactly one terminal event per rid"
        assert evs[-1] is ends[0], "terminal event ends the stream"
        assert res.status == "OK"
        # strict decode order: indices 0..n-1 with no gaps or repeats
        idx = [e.index for e in evs if e.kind == "token"]
        assert idx == list(range(len(toks)))
        assert ends[0].index == len(res.tokens)
        assert toks == res.tokens
        # every event belongs to this rid
        assert len({e.rid for e in evs}) == 1
    _assert_no_leaks(sched)


@pytest.mark.parametrize("backend", BACKENDS)
def test_stream_matches_batch_run(setup, backend):
    """The acceptance property: streamed requests are token-for-token
    equal to batch ``run()`` on the same seeds, for contiguous, paged,
    and paged+prefix-cache backends."""
    _, _, _, prompts = setup
    batch_sched = _mk(setup, backend)
    rids = [batch_sched.submit(p, jax.random.PRNGKey(i))
            for i, p in enumerate(prompts)]
    batch = batch_sched.run()

    stream_sched = _mk(setup, backend)

    async def go():
        async with ServingFrontend(stream_sched) as fe:
            return await asyncio.gather(
                *[_consume(fe, p, i) for i, p in enumerate(prompts)])

    outs = asyncio.run(go())
    for rid, (evs, toks, res) in zip(rids, outs):
        assert toks == batch[rid].tokens, f"{backend} stream diverged"
        assert res.tokens == batch[rid].tokens
        assert res.chosen_branch == batch[rid].chosen_branch
        assert res.steps == batch[rid].steps
    _assert_no_leaks(stream_sched)


# ------------------------------------------------------------- cancel

def test_cancel_mid_stream_ends_iterator(setup):
    _, _, _, prompts = setup
    sched = _mk(setup, "paged")

    async def go():
        async with ServingFrontend(sched) as fe:
            rid = fe.submit_nowait(prompts[0], jax.random.PRNGKey(0),
                                   method="greedy", max_new=12)
            got = []
            async for ev in fe.events(rid):
                got.append(ev)
                if sum(e.kind == "token" for e in got) == 2:
                    fe.cancel(rid)
            res = await fe.result(rid)
            return got, res

    got, res = asyncio.run(go())
    assert got[-1].kind == "end" and got[-1].status == "CANCELLED"
    assert res.status == "CANCELLED"
    assert 0 < res.steps < 12            # genuinely cut short mid-decode
    # the partial stream is exactly the terminal result's tokens
    assert [e.token for e in got if e.kind == "token"] == res.tokens
    _assert_no_leaks(sched)


# ------------------------------------------------- mixed-strategy pool

def test_mixed_pool_concurrent_streams_match_sequential(setup):
    """Concurrent kappa + bon + greedy streams over one paged pool
    produce the same tokens as dedicated sequential engine runs."""
    cfg, params, kcfg, prompts = setup
    specs = [("kappa", 12), ("bon", 10), ("greedy", 12)]
    seq = []
    for i, (p, (m, mn)) in enumerate(zip(prompts, specs)):
        kc = dataclasses.replace(kcfg, max_new_tokens=mn)
        fn = getattr(engine, f"generate_{m}")
        seq.append(fn(params, cfg, kc, p, jax.random.PRNGKey(i),
                      eos_id=tok.EOS, bos_id=tok.BOS, max_seq=MAX_SEQ))

    sched = _mk(setup, "paged")

    async def go():
        async with ServingFrontend(sched) as fe:
            return await asyncio.gather(
                *[_consume(fe, p, i, method=m, max_new=mn)
                  for i, (p, (m, mn)) in enumerate(zip(prompts, specs))])

    outs = asyncio.run(go())
    for s, (evs, toks, res), (m, _) in zip(seq, outs, specs):
        assert toks == s.tokens, f"{m} stream diverged from sequential"
        assert res.chosen_branch == s.chosen_branch
        assert res.logical_tokens == s.logical_tokens
    _assert_no_leaks(sched)


# ------------------------------------------------------ thread backend

def test_thread_backend_stream_and_result(setup):
    _, _, _, prompts = setup
    sched = _mk(setup, "contig")
    with ServingFrontend(sched) as fe:
        r0 = fe.submit_nowait(prompts[0], jax.random.PRNGKey(0),
                              method="greedy")
        r1 = fe.submit_nowait(prompts[1], jax.random.PRNGKey(1))
        evs = list(fe.stream(r0, timeout=120))
        res0 = fe.wait_result(r0, timeout=120)
        res1 = fe.wait_result(r1, timeout=120)
    assert evs[-1].kind == "end" and res0.status == "OK"
    assert [e.token for e in evs if e.kind == "token"] == res0.tokens
    assert res1.status == "OK" and len(res1.tokens) > 0
    _assert_no_leaks(sched)


# --------------------------------------------------- shed + shutdown

def test_shed_stream_is_single_end_event(setup):
    """A request shed at the submit door (bounded queue) emits its
    terminal event synchronously inside ``submit`` — before the rid's
    channel exists — and the stream still sees exactly one SHED end."""
    _, _, _, prompts = setup
    sched = _mk(setup, "paged", max_queue=1)

    async def go():
        async with ServingFrontend(sched) as fe:
            rids = [fe.submit_nowait(p, jax.random.PRNGKey(i))
                    for i, p in enumerate(prompts)]
            outs = []
            for rid in rids:
                outs.append([ev async for ev in fe.events(rid)])
            return rids, outs

    rids, outs = asyncio.run(go())
    statuses = [evs[-1].status for evs in outs]
    assert statuses.count("SHED") == 2 and statuses.count("OK") == 1
    for evs in outs:
        if evs[-1].status == "SHED":
            assert [e.kind for e in evs] == ["end"], \
                "shed stream is exactly one terminal event"
            assert evs[-1].result.tokens == []
    assert sched.counters["shed"] == 2
    _assert_no_leaks(sched)


def test_shutdown_drains_zero_leaks(setup):
    """``aclose`` drains in-flight work before stopping the tick task:
    no leaked rows, pages, or pins, even with the prefix cache pinning
    prompt pages (dropped explicitly like the batch path does)."""
    _, _, _, prompts = setup
    sched = _mk(setup, "paged+prefix")

    async def go():
        fe = ServingFrontend(sched)
        fe.start_async()
        for i, p in enumerate(prompts):
            fe.submit_nowait(p, jax.random.PRNGKey(i))
        await fe.aclose()            # must drain, not abandon

    asyncio.run(go())
    assert len(sched.results) == len(prompts)
    assert all(r.status == "OK" for r in sched.results.values())
    assert sched.event_sink is None      # frontend detached cleanly
    _assert_no_leaks(sched)
