"""repro-lint (src/repro/analysis) unit tests.

One positive + one negative fixture per rule R1–R8, driven through
``analyze_source`` with repo-shaped relative paths (rules scope on path
components, so ``src/repro/serving/strategies.py`` behaves exactly like
the real module). Plus: inline suppressions, baseline round-trip, CLI
exit codes, and the meta-test that the repo itself is lint-clean under
the checked-in baseline.
"""
from pathlib import Path

import pytest

from repro.analysis import analyze_paths, analyze_source, baseline
from repro.analysis.cli import main as lint_main
from repro.analysis.core import all_rules

REPO = Path(__file__).resolve().parents[1]

SERVING = "src/repro/serving/strategies.py"
KERNELS = "src/repro/kernels/fixture.py"


def only(findings, rule):
    return [f for f in findings if f.rule == rule]


def test_registry_has_all_eight_rules():
    ids = set(all_rules())
    assert ids == {
        "replay-determinism", "sync-discipline", "donation-safety",
        "interpret-default", "traced-branch", "alloc-pairing",
        "strategy-protocol", "jit-key-hygiene",
    }


def test_rules_carry_explain_metadata():
    for rule in all_rules().values():
        assert rule.contract and rule.rationale and rule.example, rule.id


# --------------------------------------------------- R1 replay-determinism

R1_POS = """\
import time

def watchdog(self):
    now = time.time()
    return now
"""

R1_NEG = """\
import time
import numpy as np

def ok(self, clock=time.monotonic):
    rng = np.random.default_rng(42)
    return clock, rng
"""


def test_r1_flags_wall_clock_in_serving():
    hits = only(analyze_source(R1_POS, SERVING), "replay-determinism")
    assert len(hits) == 1 and "time.time" in hits[0].message


def test_r1_allows_clock_default_and_seeded_rng():
    assert only(analyze_source(R1_NEG, SERVING), "replay-determinism") == []


def test_r1_scoped_to_replay_critical_modules():
    # same wall-clock call outside serving/core/serve.py: not R1's beat
    hits = analyze_source(R1_POS, "src/repro/models/x.py")
    assert only(hits, "replay-determinism") == []


# ------------------------------------------------------ R2 sync-discipline

R2_POS = """\
import numpy as np

def step(self, state):
    alive = np.asarray(state.alive)
    return alive
"""

R2_NEG = """\
import numpy as np

def sample_and_advance(self, logits):
    return np.asarray(logits)
"""


def test_r2_flags_host_sync_in_tick_path():
    hits = only(analyze_source(R2_POS, SERVING), "sync-discipline")
    assert len(hits) == 1 and "np.asarray" in hits[0].message


def test_r2_allowlists_sanctioned_sites():
    assert only(analyze_source(R2_NEG, SERVING), "sync-discipline") == []


def test_r2_scoped_to_tick_modules():
    hits = analyze_source(R2_POS, "src/repro/serving/frontend.py")
    assert only(hits, "sync-discipline") == []


# ------------------------------------------------------ R3 donation-safety

R3_POS = """\
import jax

def _f(cache, tok):
    return cache

step = jax.jit(_f, donate_argnums=(0,))

def tick(cache, tok):
    logits = step(cache, tok)
    return logits, cache
"""

R3_NEG = """\
import jax

def _f(cache, tok):
    return cache

step = jax.jit(_f, donate_argnums=(0,))

def tick(cache, tok):
    logits, cache = step(cache, tok)
    return logits, cache
"""


def test_r3_flags_read_after_donation():
    hits = only(analyze_source(R3_POS, "src/repro/serving/x.py"),
                "donation-safety")
    assert len(hits) == 1 and "`cache`" in hits[0].message


def test_r3_allows_rebinding_assignment():
    assert only(analyze_source(R3_NEG, "src/repro/serving/x.py"),
                "donation-safety") == []


# ---------------------------------------------------- R4 interpret-default

R4_POS = """\
def my_kernel(x, interpret=True):
    return x
"""

R4_CALLSITE_POS = """\
def run(fn, x):
    return fn(x, interpret=True)
"""

R4_NEG = """\
from repro.kernels import interpret_mode

def good_kernel(x, interpret=None):
    interpret = interpret_mode() if interpret is None else interpret
    return x

def _private_jit_body(x, interpret=True):
    return x
"""


def test_r4_flags_hardcoded_interpret_default():
    hits = only(analyze_source(R4_POS, KERNELS), "interpret-default")
    assert len(hits) == 1 and "interpret=True" in hits[0].message


def test_r4_flags_hardcoded_interpret_at_call_site():
    hits = only(analyze_source(R4_CALLSITE_POS, "src/repro/serving/e.py"),
                "interpret-default")
    assert len(hits) == 1 and "call site" in hits[0].message


def test_r4_allows_none_default_resolved_via_interpret_mode():
    assert only(analyze_source(R4_NEG, KERNELS), "interpret-default") == []


def test_r4_ignores_tests_tree():
    hits = analyze_source(R4_CALLSITE_POS, "tests/test_kernels.py")
    assert only(hits, "interpret-default") == []


# -------------------------------------------------------- R5 traced-branch

R5_POS = """\
import jax

@jax.jit
def step(state, x):
    if x > 0:
        return state + x
    return state
"""

R5_NEG = """\
import functools

import jax

@functools.partial(jax.jit, static_argnums=(1,))
def step2(state, n):
    if n > 0:
        return state * n
    return state

@jax.jit
def step3(state, x):
    if x.shape[0] > 1:
        return state
    return state + x
"""


def test_r5_flags_python_branch_on_traced_value():
    hits = only(analyze_source(R5_POS, "src/repro/core/k.py"),
                "traced-branch")
    assert len(hits) == 1 and "`x`" in hits[0].message


def test_r5_allows_static_args_and_shape_branches():
    assert only(analyze_source(R5_NEG, "src/repro/core/k.py"),
                "traced-branch") == []


# -------------------------------------------------------- R6 alloc-pairing

R6_POS = """\
def grow(self, alloc, row, n):
    pages = alloc.alloc_row(row, n)
    if not pages:
        return None
    alloc.free_row(row)
    return pages
"""

R6_NEG = """\
def balanced(self, alloc, row, n):
    pages = alloc.alloc_row(row, n)
    try:
        return pages
    finally:
        alloc.free_row(row)

def pin_only(self, cache, page):
    cache.pin_page(page)
"""


def test_r6_flags_leak_on_early_return_path():
    hits = only(analyze_source(R6_POS, "src/repro/serving/cache.py"),
                "alloc-pairing")
    assert len(hits) == 1 and "alloc_row/free_row" in hits[0].message


def test_r6_allows_balanced_and_single_sided_functions():
    assert only(analyze_source(R6_NEG, "src/repro/serving/cache.py"),
                "alloc-pairing") == []


# ---------------------------------------------------- R7 strategy-protocol

R7_POS = """\
class DecodeStrategy:
    pass

class Mine(DecodeStrategy):
    name = "mine"

    def choose(self, branch_ids, done):
        return 0
"""

R7_NEG = """\
class DecodeStrategy:
    pass

class Good(DecodeStrategy):
    name = "good"

    def step(self, *a, **kw):
        return None

    def decided_branch(self, branch_ids, done):
        return None

class Derived(Good):
    name = "derived"

class _AbstractHelper(DecodeStrategy):
    def shared(self):
        return 1

class NoNameYet(DecodeStrategy):
    def helper(self):
        return 1
"""


def test_r7_flags_incomplete_concrete_strategy():
    hits = only(analyze_source(R7_POS, SERVING), "strategy-protocol")
    assert len(hits) == 1
    assert "step" in hits[0].message
    assert "decided_branch" in hits[0].message


def test_r7_allows_conforming_inherited_abstract_and_unnamed():
    assert only(analyze_source(R7_NEG, SERVING), "strategy-protocol") == []


# ------------------------------------------------------ R8 jit-key-hygiene

R8_POS = """\
import jax

def _f(x, key):
    return x

step = jax.jit(_f, static_argnums=(1,))

def tick(self, x, n):
    return step(x, f"rows={n}")
"""

R8_NEG = """\
import jax

def _f(x, key):
    return x

step = jax.jit(_f, static_argnums=(1,))

def tick(self, x, cfg):
    return step(x, cfg)
"""


def test_r8_flags_fresh_literal_static_arg():
    hits = only(analyze_source(R8_POS, "src/repro/serving/scheduler.py"),
                "jit-key-hygiene")
    assert len(hits) == 1 and "f-string" in hits[0].message


def test_r8_allows_stable_static_args():
    assert only(analyze_source(R8_NEG, "src/repro/serving/scheduler.py"),
                "jit-key-hygiene") == []


# --------------------------------------------------- suppressions / parse

def test_inline_suppression_same_line():
    src = R2_POS.replace(
        "np.asarray(state.alive)",
        "np.asarray(state.alive)  # repro-lint: disable=sync-discipline")
    assert only(analyze_source(src, SERVING), "sync-discipline") == []


def test_inline_suppression_next_line():
    src = R2_POS.replace(
        "    alive = np.asarray(state.alive)",
        "    # repro-lint: disable-next-line=sync-discipline\n"
        "    alive = np.asarray(state.alive)")
    assert only(analyze_source(src, SERVING), "sync-discipline") == []


def test_suppression_is_per_rule():
    src = R2_POS.replace(
        "np.asarray(state.alive)",
        "np.asarray(state.alive)  # repro-lint: disable=traced-branch")
    assert len(only(analyze_source(src, SERVING), "sync-discipline")) == 1


def test_parse_error_is_a_finding_not_a_crash():
    hits = analyze_source("def broken(:\n", "src/repro/serving/x.py")
    assert len(hits) == 1 and hits[0].rule == "parse-error"


# ------------------------------------------------------- baseline machinery

def test_baseline_round_trip(tmp_path):
    findings = analyze_source(R2_POS, SERVING)
    assert findings
    entries = baseline.from_findings(findings, reason="test fixture")
    path = tmp_path / "b.json"
    baseline.save(path, entries)
    loaded = baseline.load(path)
    new, old, stale = baseline.partition(findings, loaded)
    assert new == [] and len(old) == len(findings) and stale == []


def test_baseline_matching_is_line_number_independent():
    findings = analyze_source(R2_POS, SERVING)
    entries = baseline.from_findings(findings)
    shifted = analyze_source("\n\n\n" + R2_POS, SERVING)
    new, old, _ = baseline.partition(shifted, entries)
    assert new == [] and len(old) == len(findings)


def test_baseline_count_budget_and_staleness():
    findings = analyze_source(R2_POS, SERVING)
    entries = baseline.from_findings(findings)
    # duplicating a baselined sin on a second line exceeds the budget
    doubled = analyze_source(
        R2_POS.replace("    return alive",
                       "    alive = np.asarray(state.alive)\n"
                       "    return alive"),
        SERVING)
    new, old, _ = baseline.partition(doubled, entries)
    assert len(old) == len(findings) and len(new) == 1
    # a fixed violation leaves its entry stale for deletion
    _, _, stale = baseline.partition([], entries)
    assert stale == entries


# ----------------------------------------------------------- CLI contract

def _fixture_tree(tmp_path, source):
    mod = tmp_path / "src" / "repro" / "serving" / "strategies.py"
    mod.parent.mkdir(parents=True)
    mod.write_text(source)
    return tmp_path


def test_cli_exit_nonzero_on_finding(tmp_path, capsys):
    root = _fixture_tree(tmp_path, R2_POS)
    rc = lint_main(["--no-baseline", "--root", str(root), "src"])
    assert rc == 1
    assert "sync-discipline" in capsys.readouterr().out


def test_cli_exit_zero_on_clean_tree(tmp_path, capsys):
    root = _fixture_tree(tmp_path, R2_NEG)
    assert lint_main(["--no-baseline", "--root", str(root), "src"]) == 0


def test_cli_github_format_emits_annotations(tmp_path, capsys):
    root = _fixture_tree(tmp_path, R2_POS)
    rc = lint_main(["--no-baseline", "--format", "github",
                    "--root", str(root), "src"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "::error file=src/repro/serving/strategies.py" in out


def test_cli_write_baseline_then_clean(tmp_path, capsys):
    root = _fixture_tree(tmp_path, R2_POS)
    bl = tmp_path / "baseline.json"
    assert lint_main(["--write-baseline", "--baseline", str(bl),
                      "--root", str(root), "src"]) == 0
    assert bl.exists()
    assert lint_main(["--baseline", str(bl),
                      "--root", str(root), "src"]) == 0


def test_cli_explain(capsys):
    assert lint_main(["--explain", "all"]) == 0
    out = capsys.readouterr().out
    for rid in all_rules():
        assert rid in out
    assert lint_main(["--explain", "no-such-rule"]) == 2


# ------------------------------------------------------- repo is clean

@pytest.mark.parametrize("tree", ["src", "benchmarks", "examples"])
def test_repo_tree_is_lint_clean_under_baseline(tree):
    if not (REPO / tree).exists():
        pytest.skip(f"{tree}/ not present")
    findings = analyze_paths([tree], REPO)
    entries = baseline.load(REPO / baseline.BASELINE_NAME)
    new, _, _ = baseline.partition(findings, entries)
    assert new == [], "\n".join(
        f"{f.path}:{f.line}: {f.rule}: {f.message}" for f in new)


def test_baseline_has_no_stale_entries_and_real_reasons():
    findings = analyze_paths(["src", "benchmarks", "examples"], REPO)
    entries = baseline.load(REPO / baseline.BASELINE_NAME)
    _, _, stale = baseline.partition(findings, entries)
    assert stale == [], f"stale baseline entries: {stale}"
    for e in entries:
        assert e["reason"] and "TODO" not in e["reason"], e
