"""Lock jax to the single host CPU device before any test import can
touch dry-run machinery (which sets XLA_FLAGS for its own process), and
provide a per-test timeout fallback when pytest-timeout is missing."""
import signal
import threading

import jax
import pytest

_ = jax.devices()  # initialize backend: tests must see exactly 1 device


class FakeClock:
    """Deterministic stand-in for the schedulers' injectable monotonic
    clock: time moves only when a test calls ``advance()``, so deadline
    and latency-window tests never real-sleep."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        assert dt >= 0, "monotonic clocks do not rewind"
        self.t += dt
        return self.t


@pytest.fixture
def fake_clock():
    return FakeClock()


# Dynamic twin of repro-lint's static R2 sync-discipline rule: the
# static allowlist (rules/determinism.py ALLOWED_SYNC_SITES) names the
# sanctioned blocking-transfer call sites; this guard asserts the
# runtime counters those sites increment stay within the DESIGN.md §4
# budget — ≤1 pooled-controller sync per tick riding ≤2 blocking
# transfers per tick — on EVERY scheduler any scheduler-level test
# constructs. The two can't drift apart silently: a new sync site
# trips the lint, a new per-tick transfer trips this.
_SYNC_GUARDED_FILES = ("test_scheduler.py", "test_paged.py")


@pytest.fixture(autouse=True)
def _sync_budget_guard(request, monkeypatch):
    if getattr(request.node, "fspath", None) is None or \
            request.node.fspath.basename not in _SYNC_GUARDED_FILES:
        yield
        return
    from repro.serving import scheduler as sched_mod
    created = []
    orig_init = sched_mod._SchedulerBase.__init__

    def _tracking_init(self, *args, **kwargs):
        orig_init(self, *args, **kwargs)
        created.append(self)

    monkeypatch.setattr(sched_mod._SchedulerBase, "__init__",
                        _tracking_init)
    yield
    for sched in created:
        c = sched.counters
        # one pooled dispatch per tick at most, and every dispatch's
        # outputs ride exactly one blocking transfer
        assert c["controller_syncs"] <= c["controller_dispatches"] \
            <= sched.ticks, (
            "pooled-controller sync budget exceeded: "
            f"{c['controller_syncs']} syncs / "
            f"{c['controller_dispatches']} dispatches over "
            f"{sched.ticks} ticks (≤1 per tick, DESIGN.md §4)")
        # the fused tick's two sanctioned transfers: sampler keys + THE
        # tokens/controller/finite transfer
        assert c["host_syncs"] <= 2 * sched.ticks, (
            f"host-sync budget exceeded: {c['host_syncs']} blocking "
            f"transfers over {sched.ticks} ticks (≤2 per tick)")

try:
    import pytest_timeout  # noqa: F401
    _HAVE_TIMEOUT_PLUGIN = True
except ImportError:
    _HAVE_TIMEOUT_PLUGIN = False


def pytest_addoption(parser):
    if not _HAVE_TIMEOUT_PLUGIN:
        # claim pytest-timeout's ini keys so plugin-absent runs stay
        # clean under --strict-config (no "unknown config option")
        parser.addini("timeout", "per-test timeout (pytest-timeout "
                      "fallback)", default="900")
        parser.addini("timeout_method", "ignored by the fallback",
                      default="signal")


if not _HAVE_TIMEOUT_PLUGIN and hasattr(signal, "SIGALRM"):
    # degraded stand-in for pytest-timeout (pyproject sets timeout=900):
    # a SIGALRM per test so a hung fuzz case raises loudly instead of
    # wedging the run. Main-thread only; the real plugin supersedes it.

    @pytest.fixture(autouse=True)
    def _fallback_test_timeout(request):
        if threading.current_thread() is not threading.main_thread():
            yield
            return
        marker = request.node.get_closest_marker("timeout")
        limit = int(float(marker.args[0])) if (marker and marker.args) \
            else int(float(request.config.getini("timeout")))

        def _on_alarm(signum, frame):
            raise TimeoutError(
                f"test exceeded fallback timeout of {limit}s "
                "(install pytest-timeout for precise per-test caps)")

        prev = signal.signal(signal.SIGALRM, _on_alarm)
        signal.alarm(limit)
        try:
            yield
        finally:
            signal.alarm(0)
            signal.signal(signal.SIGALRM, prev)
