"""Lock jax to the single host CPU device before any test import can
touch dry-run machinery (which sets XLA_FLAGS for its own process)."""
import jax

_ = jax.devices()  # initialize backend: tests must see exactly 1 device
