"""KAPPA core: signals, robustification, scoring, schedule, controller."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import KappaConfig
from repro.core import kappa as K
from repro.core import robust, schedule, scoring, signals


# ------------------------------------------------------------- signals

def test_signals_match_manual():
    rng = jax.random.PRNGKey(0)
    logits = jax.random.normal(rng, (4, 100)) * 2
    qlogits = jax.random.normal(jax.random.PRNGKey(1), (100,))
    log_q = signals.reference_log_q(qlogits)
    kl, conf, ent = signals.compute_signals(logits, log_q)

    p = np.asarray(jax.nn.softmax(logits, axis=-1), np.float64)
    q = np.asarray(jnp.exp(log_q), np.float64)
    np.testing.assert_allclose(np.asarray(kl), (p * np.log(p / q)).sum(-1), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(conf), p.max(-1), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(ent), -(p * np.log(p + 1e-9)).sum(-1),
                               rtol=1e-4)


def test_kl_nonnegative_and_zero_iff_equal():
    logits = jnp.tile(jnp.arange(50.0), (3, 1))
    log_q = signals.reference_log_q(jnp.arange(50.0))
    kl, _, _ = signals.compute_signals(logits, log_q)
    np.testing.assert_allclose(np.asarray(kl), 0.0, atol=1e-5)
    kl2, _, _ = signals.compute_signals(logits + jnp.eye(3, 50) * 5, log_q)
    assert np.all(np.asarray(kl2) >= -1e-6)


# --------------------------------------------------------------- robust

def test_median_of_means_resists_outlier():
    w, m = 16, 4
    clean = jnp.ones((1, w))
    dirty = clean.at[0, 3].set(1e6)  # one catastrophic outlier
    est = robust.median_of_means(dirty, jnp.int32(w), m)
    assert float(est[0]) < 1e5, "MoM must not follow a single outlier"
    mean = float(jnp.mean(dirty))
    assert abs(float(est[0]) - 1.0) < abs(mean - 1.0)


def test_median_of_means_partial_window():
    w, m = 8, 4
    buf = jnp.zeros((2, w)).at[:, :3].set(5.0)  # only 3 valid entries
    est = robust.median_of_means(buf, jnp.int32(3), m)
    np.testing.assert_allclose(np.asarray(est), 5.0, rtol=1e-6)


def test_ema_debias_first_step_identity():
    ema = robust.ema_update(jnp.zeros(3), jnp.array([1.0, 2.0, 3.0]), 0.5)
    hat = robust.ema_debias(ema, jnp.int32(1), 0.5)
    np.testing.assert_allclose(np.asarray(hat), [1.0, 2.0, 3.0], rtol=1e-6)


def test_ema_converges_to_constant():
    ema = jnp.zeros(1)
    for t in range(1, 60):
        ema = robust.ema_update(ema, jnp.array([7.0]), 0.5)
    hat = robust.ema_debias(ema, jnp.int32(59), 0.5)
    np.testing.assert_allclose(np.asarray(hat), 7.0, rtol=1e-5)


# -------------------------------------------------------------- scoring

def test_masked_zscore_ignores_dead_branches():
    x = jnp.array([1.0, 2.0, 3.0, 1e9])
    alive = jnp.array([True, True, True, False])
    z = scoring.masked_zscore(x, alive)
    np.testing.assert_allclose(float(z[3]), 0.0)
    live = np.asarray(z[:3])
    assert abs(live.mean()) < 1e-5
    assert np.all(np.abs(live) <= 3.0)


def test_trajectory_weights_recent_more():
    num = jnp.zeros(2)
    den = jnp.float32(0.0)
    # branch 0: good early, bad late; branch 1: the reverse
    for t, s in [(1, jnp.array([1.0, -1.0])), (2, jnp.array([1.0, -1.0])),
                 (3, jnp.array([-1.0, 1.0])), (4, jnp.array([-1.0, 1.0]))]:
        num, den, traj = scoring.trajectory_update(num, den, s, jnp.int32(t))
    assert float(traj[1]) > float(traj[0]), "recent steps must weigh more"


# ------------------------------------------------------------- schedule

@pytest.mark.parametrize("kind", ["linear", "cosine", "step"])
def test_schedule_monotone_and_terminates_at_one(kind):
    n, horizon = 10, 16
    rs = [int(schedule.survivors(kind, n, jnp.int32(t), horizon))
          for t in range(horizon)]
    assert all(1 <= r <= n for r in rs)
    assert all(a >= b for a, b in zip(rs, rs[1:])), f"{kind} not monotone: {rs}"
    assert rs[-1] == 1, f"{kind} must end at 1: {rs}"


def test_linear_schedule_matches_paper_formula():
    n, horizon = 8, 8
    for t in range(horizon):
        r = int(schedule.survivors("linear", n, jnp.int32(t), horizon))
        expected = max(1, n - ((t + 1) * n) // horizon)
        assert r == expected


# ----------------------------------------------------------- controller

def _mk_cfg(**kw):
    base = dict(num_branches=4, adaptive_cutoff=False, draft_cutoff=2,
                horizon=4, window=8, mom_buckets=4, max_new_tokens=64)
    base.update(kw)
    return KappaConfig(**base)


def _logits_for(good_branch, n=4, v=64, sharp=8.0):
    """Branch `good_branch` gets a confident (low-entropy, high-KL-vs-
    uniform) distribution; others get near-uniform noise."""
    base = jnp.zeros((n, v))
    base = base.at[good_branch, 7].set(sharp)
    return base + jax.random.normal(jax.random.PRNGKey(0), (n, v)) * 0.01


def test_kappa_prunes_to_single_survivor():
    cfg = _mk_cfg()
    state = K.init_state(cfg)
    log_q = signals.reference_log_q(jnp.zeros(64))
    tokens = jnp.arange(4, dtype=jnp.int32)  # all distinct
    for t in range(12):
        state = K.kappa_step(state, _logits_for(2), tokens, log_q, cfg)
    assert int(K.num_alive(state)) == 1
    assert int(K.survivor_index(state)) == 2, "confident branch must survive"


def test_kappa_never_prunes_all():
    cfg = _mk_cfg()
    state = K.init_state(cfg)
    log_q = signals.reference_log_q(jnp.zeros(64))
    logits = jax.random.normal(jax.random.PRNGKey(3), (4, 64))
    for t in range(20):
        state = K.kappa_step(state, logits, jnp.arange(4, dtype=jnp.int32),
                             log_q, cfg)
        assert int(K.num_alive(state)) >= 1


def test_kappa_no_pruning_during_draft():
    cfg = _mk_cfg(draft_cutoff=5)
    state = K.init_state(cfg)
    log_q = signals.reference_log_q(jnp.zeros(64))
    for t in range(5):
        state = K.kappa_step(state, _logits_for(0), jnp.arange(4, dtype=jnp.int32),
                             log_q, cfg)
        if t < 4:  # still in draft on the first 5 steps (cutoff at step>=5)
            assert int(K.num_alive(state)) == 4


def test_di_ring_buffer_wraps_and_mom_tracks_fresh_values():
    """Regression: the ΔI ring slot must come from a MONOTONE write
    pointer. Indexing by the clamped ``di_count`` pins every post-warmup
    write to slot 0, so after the ΔI level shifts the median-of-means
    keeps reporting the stale pre-shift level forever."""
    cfg = _mk_cfg(window=8, mom_buckets=4)
    state = K.init_state(cfg)
    kl = 0.0
    for t in range(16):                      # 2× window: forces a wrap
        kl += 1.0 if t < 8 else 5.0          # ΔI jumps 1.0 → 5.0 at t=8
        sigs = (jnp.full((4,), kl), jnp.zeros(4), jnp.zeros(4))
        state, _ = K._score_update(state, sigs, cfg)
    assert int(state.di_ptr) == 16, "write pointer must be monotone"
    assert int(state.di_count) == 8, "valid-entry count stays clamped at w"
    # the window holds only post-shift ΔI values …
    np.testing.assert_allclose(np.asarray(state.di_buf), 5.0, rtol=1e-6)
    # … so the MoM estimate tracks the fresh level (pre-fix: ≈1.0,
    # the stale entries in slots 1..w-1 dominate the bucket medians)
    est = robust.median_of_means(state.di_buf, state.di_count,
                                 cfg.mom_buckets)
    np.testing.assert_allclose(np.asarray(est), 5.0, rtol=1e-6)


def test_di_ring_buffer_partial_window_order():
    """Before the first wrap the ring is chronological: slot t holds the
    ΔI of scoring step t, and di_count == di_ptr."""
    cfg = _mk_cfg(window=8, mom_buckets=4)
    state = K.init_state(cfg)
    kl = 0.0
    for t in range(5):
        kl += float(t + 1)                   # ΔI sequence 1, 2, 3, 4, 5
        sigs = (jnp.full((4,), kl), jnp.zeros(4), jnp.zeros(4))
        state, _ = K._score_update(state, sigs, cfg)
    assert int(state.di_ptr) == int(state.di_count) == 5
    np.testing.assert_allclose(np.asarray(state.di_buf[0, :5]),
                               [1.0, 2.0, 3.0, 4.0, 5.0], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(state.di_buf[:, 5:]), 0.0)


def test_adaptive_cutoff_waits_for_divergence():
    cfg = _mk_cfg(adaptive_cutoff=True, max_cutoff=50)
    state = K.init_state(cfg)
    log_q = signals.reference_log_q(jnp.zeros(64))
    same = jnp.zeros(4, dtype=jnp.int32)  # identical tokens → no divergence
    for _ in range(6):
        state = K.kappa_step(state, _logits_for(1), same, log_q, cfg)
    assert not bool(state.in_gating)
    distinct = jnp.arange(4, dtype=jnp.int32)
    state = K.kappa_step(state, _logits_for(1), distinct, log_q, cfg)
    assert bool(state.in_gating)


def test_compact_state_preserves_per_branch_rows():
    cfg = _mk_cfg()
    state = K.init_state(cfg)
    log_q = signals.reference_log_q(jnp.zeros(64))
    for t in range(3):
        state = K.kappa_step(state, _logits_for(1), jnp.arange(4, dtype=jnp.int32),
                             log_q, cfg)
    idx = jnp.array([1, 3])
    small = K.compact_state(state, idx)
    np.testing.assert_allclose(np.asarray(small.traj),
                               np.asarray(state.traj[idx]))
    np.testing.assert_allclose(np.asarray(small.di_buf),
                               np.asarray(state.di_buf[idx]))
    assert small.diverged.shape == (2, 2)


def test_init_state_row_subset_view():
    """init_state(cfg, n) builds an n-row state the controller can drive
    (scheduler admitting fewer rows than the configured fan-out); the
    pruning schedule still anneals from cfg.num_branches."""
    cfg = _mk_cfg()
    state = K.init_state(cfg, n=3)
    assert state.alive.shape == (3,)
    assert state.diverged.shape == (3, 3)
    assert state.di_buf.shape == (3, cfg.window)
    log_q = signals.reference_log_q(jnp.zeros(64))
    logits = jax.random.normal(jax.random.PRNGKey(0), (3, 64))
    for t in range(3):
        state = K.kappa_step(state, logits, jnp.arange(3, dtype=jnp.int32),
                             log_q, cfg)
    assert state.alive.shape == (3,)
    assert int(K.num_alive(state)) >= 1
    small = K.compact_state(state, jnp.array([0, 2]))
    assert small.alive.shape == (2,)
    np.testing.assert_allclose(np.asarray(small.traj),
                               np.asarray(state.traj[jnp.array([0, 2])]))


# ------------------------------------------------------ pooled controller

def test_pooled_step_bitwise_matches_per_request():
    """One vmapped pooled_step over S stacked controllers must equal S
    independent kappa_step calls bit for bit — the property the batched
    scheduler's token-for-token guarantee rests on."""
    cfg = _mk_cfg()
    log_q = signals.reference_log_q(jnp.zeros(64))
    S = 3
    pool = K.init_pool(cfg, S)
    per = [K.init_state(cfg) for _ in range(S)]
    rng = jax.random.PRNGKey(42)
    for step in range(7):
        rng, k1, k2 = jax.random.split(rng, 3)
        logits = jax.random.normal(k1, (S, 4, 64)) * 3
        tokens = jax.random.randint(k2, (S, 4), 0, 64)
        pool = K.pooled_step(pool, logits, tokens, log_q, cfg)
        per = [K.kappa_step(s, logits[i], tokens[i], log_q, cfg)
               for i, s in enumerate(per)]
        for i, s in enumerate(per):
            for a, b in zip(jax.tree.leaves(jax.tree.map(lambda x: x[i], pool)),
                            jax.tree.leaves(s)):
                assert np.array_equal(np.asarray(a), np.asarray(b)), \
                    f"slot {i} diverged at step {step}"


def test_pooled_masked_rows_match_subset_state():
    """A full-fan-out slot whose padding rows are masked dead behaves
    exactly like the n-row subset state: dead rows contribute exact-zero
    terms to the masked statistics and rank below every alive row."""
    cfg = _mk_cfg()                          # num_branches=4
    n = 3
    log_q = signals.reference_log_q(jnp.zeros(64))
    sub = K.init_state(cfg, n=n)
    pool = K.init_pool_rows(cfg, jnp.array([n], jnp.int32))
    rng = jax.random.PRNGKey(7)
    for _ in range(8):
        rng, k1, k2 = jax.random.split(rng, 3)
        logits = jax.random.normal(k1, (n, 64)) * 2
        tokens = jax.random.randint(k2, (n,), 0, 64)
        # padding row rides along with arbitrary-but-finite inputs
        pad_logits = jnp.concatenate([logits, jnp.zeros((1, 64))])
        pad_tokens = jnp.concatenate([tokens, jnp.zeros((1,), jnp.int32)])
        sub = K.kappa_step(sub, logits, tokens, log_q, cfg)
        pool = K.pooled_step(pool, pad_logits[None], pad_tokens[None],
                             log_q, cfg)
    assert not bool(pool.alive[0, n]), "padding row must stay dead"
    np.testing.assert_array_equal(np.asarray(pool.alive[0, :n]),
                                  np.asarray(sub.alive))
    assert np.array_equal(np.asarray(pool.traj[0, :n]), np.asarray(sub.traj))
    assert int(pool.cutoff[0]) == int(sub.cutoff)
    assert bool(pool.in_gating[0]) == bool(sub.in_gating)
    assert int(pool.step[0]) == int(sub.step)


def test_init_pool_rows_padding_masks():
    cfg = _mk_cfg()
    pool = K.init_pool_rows(cfg, jnp.array([4, 2, 1], jnp.int32))
    assert pool.alive.shape == (3, 4)
    np.testing.assert_array_equal(np.asarray(pool.alive),
                                  [[True] * 4,
                                   [True, True, False, False],
                                   [True, False, False, False]])
    # padding rows read as diverged against everyone (adaptive-cutoff
    # checks on the masked state equal those on the subset state)
    div = np.asarray(pool.diverged)
    assert div[1, 2:, :].all() and div[1, :, 2:].all()
    assert not div[1, 0, 1] and not div[1, 1, 0]


def test_adaptive_horizon_scales_with_difficulty():
    """Paper §5 future work: flat (hard) distributions lengthen τ,
    sharp (easy) ones shorten it."""
    cfg = _mk_cfg(draft_cutoff=1, horizon=8, adaptive_horizon=True)
    log_q = signals.reference_log_q(jnp.zeros(64))

    def run(logits):
        st = K.init_state(cfg)
        for _ in range(3):
            st = K.kappa_step(st, logits, jnp.arange(4, dtype=jnp.int32),
                              log_q, cfg)
        return int(st.horizon_dyn)

    tau_hard = run(jnp.zeros((4, 64)))          # maximum entropy
    tau_easy = run(jnp.eye(4, 64) * 20.0)       # near-deterministic
    assert tau_hard == 16                        # 2×τ cap
    assert tau_easy == 4                         # τ/2 floor
    assert tau_hard > tau_easy


def test_adaptive_horizon_frozen_after_entry():
    cfg = _mk_cfg(draft_cutoff=1, horizon=8, adaptive_horizon=True)
    log_q = signals.reference_log_q(jnp.zeros(64))
    st = K.init_state(cfg)
    flat = jnp.zeros((4, 64))
    sharp = jnp.eye(4, 64) * 20.0
    for _ in range(3):
        st = K.kappa_step(st, flat, jnp.arange(4, dtype=jnp.int32), log_q, cfg)
    tau_at_entry = int(st.horizon_dyn)
    for _ in range(3):  # later sharp logits must not rewrite τ
        st = K.kappa_step(st, sharp, jnp.arange(4, dtype=jnp.int32), log_q, cfg)
    assert int(st.horizon_dyn) == tau_at_entry
