"""Request lifecycle & fault handling (DESIGN.md §8): cancellation in
every lifecycle state, Unservable rejection, deadline/tick-budget
timeouts, bounded-queue shedding, fault-retry quarantine, FaultPlan
determinism, and the pooled controller's finite-guard."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import KappaConfig
from repro.core import kappa as K
from repro.core import signals
from repro.data import tokenizer as tok
from repro.models import init_params
from repro.serving.faults import FaultPlan, InjectedStepFault, parse_fault_spec
from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                     PagedScheduler, Unservable)

MAX_SEQ = 32
PAGE_SIZE = 4
ROWS = 8
TERMINAL = {"OK", "CANCELLED", "TIMEOUT", "FAILED", "SHED"}


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("deepseek-r1-distill-qwen-1.5b").reduced(
        num_layers=2, d_model=64, vocab_size=tok.VOCAB_SIZE)
    params = init_params(jax.random.PRNGKey(0), cfg)
    kcfg = KappaConfig(num_branches=4, max_new_tokens=12, max_cutoff=4,
                       horizon=6, window=8, mom_buckets=4)
    return cfg, params, kcfg


def _prompt(i, plen=7):
    body = np.random.default_rng(100 + i).integers(0, tok.MOD, size=plen - 2)
    return np.concatenate([[tok.BOS], body, [tok.QM]])


def _mk(setup, paged, **kw):
    cfg, params, kcfg = setup
    base = dict(rows=ROWS, max_seq=MAX_SEQ, method="kappa",
                eos_id=tok.EOS, bos_id=tok.BOS)
    base.update(kw)
    if paged:
        return PagedScheduler(params, cfg, kcfg, page_size=PAGE_SIZE,
                              num_pages=ROWS * MAX_SEQ // PAGE_SIZE, **base)
    return ContinuousBatchingScheduler(params, cfg, kcfg, **base)


def _assert_no_leaks(sched):
    assert sorted(sched.free) == list(range(sched.rows))
    assert not sched.active and not sched.prefilling and not sched.queue
    if getattr(sched, "pcache", None) is not None:
        sched.pcache.drop()
    if hasattr(sched, "alloc"):
        assert sched.alloc.free_count == sched.num_pages, "leaked pages"
        assert int(sched.alloc.pinned.sum()) == 0, "leaked pins"


# ------------------------------------------------------------- cancel

@pytest.mark.parametrize("paged", [False, True])
def test_cancel_queued(setup, paged):
    sched = _mk(setup, paged)
    r0 = sched.submit(_prompt(0), jax.random.PRNGKey(0))
    r1 = sched.submit(_prompt(1), jax.random.PRNGKey(1))
    res1 = sched.cancel(r1)          # never admitted: no partial tokens
    assert res1.status == "CANCELLED" and res1.tokens == []
    assert res1.chosen_branch == -1
    assert sched.cancel(r1) is res1  # idempotent once terminal
    with pytest.raises(KeyError):
        sched.cancel(999)
    out = sched.run()
    assert out[r0].status == "OK" and out[r1].status == "CANCELLED"
    assert sched.counters["cancelled"] == 1
    assert sched.throughput()["status_counts"] == {"OK": 1, "CANCELLED": 1}
    _assert_no_leaks(sched)


@pytest.mark.parametrize("paged", [False, True])
def test_cancel_active_returns_partial_tokens(setup, paged):
    sched = _mk(setup, paged)
    rid = sched.submit(_prompt(0), jax.random.PRNGKey(0), method="greedy",
                       max_new=12)
    for _ in range(5):
        sched.tick()
    assert rid in sched.active
    res = sched.cancel(rid)
    assert res.status == "CANCELLED"
    assert 0 < res.steps < 12           # truncated, not complete
    # partial decode came back: prefill's sampled token + one per tick
    assert len(res.tokens) == res.steps + 1
    assert sched.run()[rid] is res
    _assert_no_leaks(sched)


@pytest.mark.parametrize("paged", [False, True])
def test_cancel_mid_prefill(setup, paged):
    sched = _mk(setup, paged, prefill_chunk=2)
    rid = sched.submit(_prompt(0, plen=7), jax.random.PRNGKey(0))
    sched.tick()                        # admits; 7-token prompt > one chunk
    assert rid in sched.prefilling
    res = sched.cancel(rid)
    assert res.status == "CANCELLED" and res.tokens == []
    sched.run()
    _assert_no_leaks(sched)


@pytest.mark.parametrize("paged", [False, True])
def test_cancellation_storm_zero_leak(setup, paged):
    """Cancel everything — queued, PREFILLING, active — mid-flight; the
    pool must come back empty with every page/pin/slot returned."""
    kw = dict(prefill_chunk=3)
    if paged:
        kw["prefix_cache"] = True
    sched = _mk(setup, paged, **kw)
    rids = [sched.submit(_prompt(i), jax.random.PRNGKey(i))
            for i in range(6)]
    for _ in range(3):
        sched.tick()
    for rid in rids:
        res = sched.cancel(rid)
        assert res.status in ("CANCELLED", "OK")
    out = sched.run()
    assert set(out) == set(rids)
    assert all(out[r].status in TERMINAL for r in rids)
    _assert_no_leaks(sched)


# --------------------------------------------------------- unservable

class _WideFanOut:
    """Strategy stub whose fan-out can never fit the pool."""

    def rows(self, kcfg):
        return ROWS + 1


def test_unservable_is_typed_and_early(setup):
    sched = _mk(setup, paged=False)
    assert issubclass(Unservable, ValueError)   # old callers keep working
    with pytest.raises(Unservable, match="max_seq"):
        sched.submit(_prompt(0, plen=MAX_SEQ), jax.random.PRNGKey(0))
    with pytest.raises(Unservable, match="rows"):
        sched.submit(_prompt(0), jax.random.PRNGKey(0),
                     strategy_factory=_WideFanOut)
    assert not sched.queue              # rejected at the door, not queued


def test_unservable_paged_page_budget(setup):
    cfg, params, kcfg = setup
    sched = PagedScheduler(params, cfg, kcfg, rows=ROWS, max_seq=MAX_SEQ,
                           page_size=PAGE_SIZE, num_pages=6, method="kappa",
                           eos_id=tok.EOS, bos_id=tok.BOS)
    with pytest.raises(Unservable, match="pages"):
        sched.submit(_prompt(0, plen=8), jax.random.PRNGKey(0))
    assert not sched.queue              # rejected at the door, not queued


# ----------------------------------------------------------- deadlines

@pytest.mark.parametrize("paged", [False, True])
def test_tick_budget_truncates_active(setup, paged):
    sched = _mk(setup, paged)
    rid = sched.submit(_prompt(0), jax.random.PRNGKey(0), method="greedy",
                       max_new=12, max_wall_ticks=4)
    out = sched.run()
    res = out[rid]
    assert res.status == "TIMEOUT"
    assert 0 < res.steps < 12           # truncate-and-return kept partials
    assert sched.counters["timeouts"] == 1
    _assert_no_leaks(sched)


@pytest.mark.parametrize("paged", [False, True])
def test_tick_budget_expires_queued(setup, paged):
    # 4-row pool: the kappa request (fan-out 4) saturates it, the queued
    # greedy request's one-tick budget expires before it can admit
    sched = _mk(setup, paged, rows=4)
    r0 = sched.submit(_prompt(0), jax.random.PRNGKey(0))
    r1 = sched.submit(_prompt(1), jax.random.PRNGKey(1), method="greedy",
                      max_wall_ticks=1)
    out = sched.run()
    assert out[r0].status == "OK"
    assert out[r1].status == "TIMEOUT" and out[r1].tokens == []
    assert sorted(sched.free) == list(range(4))


def test_wall_clock_deadline_truncates_active(setup, fake_clock):
    """Deadline crossing is observed through the injectable clock — no
    real sleeping: decode a few ticks, jump time past the deadline, and
    the next tick's watchdog truncates with the partial tokens kept."""
    sched = _mk(setup, paged=False, clock=fake_clock)
    rid = sched.submit(_prompt(0), jax.random.PRNGKey(0), method="greedy",
                       max_new=12, deadline_s=5.0)
    for _ in range(3):
        sched.tick()
    assert rid in sched.active
    fake_clock.advance(6.0)              # cross the deadline, zero wall time
    sched.tick()
    res = sched.results[rid]
    assert res.status == "TIMEOUT"
    assert 0 < res.steps < 12
    assert len(res.tokens) == res.steps + 1   # truncate-and-return
    assert sched.counters["timeouts"] == 1
    _assert_no_leaks(sched)


@pytest.mark.parametrize("paged", [False, True])
def test_wall_clock_deadline_expires_queued(setup, paged, fake_clock):
    # 4-row pool: the kappa request saturates it; the queued greedy
    # request's wall deadline expires (via the fake clock) before a row
    # frees up, so the watchdog sheds it from the queue with no tokens
    sched = _mk(setup, paged, rows=4, clock=fake_clock)
    r0 = sched.submit(_prompt(0), jax.random.PRNGKey(0))
    r1 = sched.submit(_prompt(1), jax.random.PRNGKey(1), method="greedy",
                      deadline_s=2.0)
    sched.tick()
    assert r0 in sched.active or r0 in sched.prefilling
    fake_clock.advance(3.0)
    sched.tick()
    assert sched.results[r1].status == "TIMEOUT"
    assert sched.results[r1].tokens == []
    out = sched.run()
    assert out[r0].status == "OK"
    _assert_no_leaks(sched)


# ---------------------------------------------------------------- shed

@pytest.mark.parametrize("paged", [False, True])
def test_bounded_queue_sheds(setup, paged):
    sched = _mk(setup, paged, max_queue=2)
    rids = [sched.submit(_prompt(i), jax.random.PRNGKey(i), method="greedy")
            for i in range(3)]
    assert rids[2] in sched.results     # shed at submit time, terminal
    assert sched.results[rids[2]].status == "SHED"
    assert sched.counters["shed"] == 1
    out = sched.run()
    assert out[rids[0]].status == "OK" and out[rids[1]].status == "OK"
    sc = sched.throughput()["status_counts"]
    assert sc == {"OK": 2, "SHED": 1}
    _assert_no_leaks(sched)


# --------------------------------------------------- retry / quarantine

@pytest.mark.faults
@pytest.mark.parametrize("paged", [False, True])
def test_step_fault_quarantine_after_max_retries(setup, paged):
    """A permanently-faulting device step burns each request's retry
    budget and quarantines it as FAILED — the pool never wedges."""
    plan = FaultPlan(seed=0, p_step=1.0, p_alloc=0.0, p_nan=0.0)
    sched = _mk(setup, paged, faults=plan, max_retries=1, retry_backoff=1)
    rids = [sched.submit(_prompt(i), jax.random.PRNGKey(i), method="greedy")
            for i in range(2)]
    out = sched.run()
    for rid in rids:
        assert out[rid].status == "FAILED"
        assert out[rid].tokens == []    # post-fault state is suspect
        assert out[rid].n_retries == 1
    assert sched.counters["failures"] == 2
    assert sched.counters["retries"] == 2
    assert sched.counters["faults_injected"] > 0
    _assert_no_leaks(sched)


@pytest.mark.faults
@pytest.mark.parametrize("paged", [False, True])
def test_nan_fault_replay_token_equal(setup, paged):
    """NaN-poisoned rows are torn down and replayed from the original
    submission RNG: the survivors' tokens match a fault-free run."""
    clean = _mk(setup, paged)
    rids_c = [clean.submit(_prompt(i), jax.random.PRNGKey(i))
              for i in range(3)]
    ref = clean.run()
    plan = FaultPlan(seed=11, p_step=0.0, p_alloc=0.0, p_nan=0.4,
                     nan_rows=2, max_faults=4)
    sched = _mk(setup, paged, faults=plan, max_retries=8)
    rids = [sched.submit(_prompt(i), jax.random.PRNGKey(i))
            for i in range(3)]
    out = sched.run()
    assert sched.counters["retries"] > 0, "the plan never fired — tune it"
    for rc, rf in zip(rids_c, rids):
        assert out[rf].status == "OK"
        assert out[rf].tokens == ref[rc].tokens
        assert out[rf].chosen_branch == ref[rc].chosen_branch
    _assert_no_leaks(sched)


# ------------------------------------------------------------ FaultPlan

def test_fault_plan_deterministic_and_memoized():
    a = FaultPlan(seed=7)
    b = FaultPlan(seed=7)
    sched_a = [(a.step_fault(t), a.page_holdback(t),
                a.nan_rows_for(t, 8).tolist()) for t in range(60)]
    sched_b = [(b.step_fault(t), b.page_holdback(t),
                b.nan_rows_for(t, 8).tolist()) for t in range(60)]
    assert sched_a == sched_b           # pure function of (seed, site, tick)
    assert any(x or y or z for x, y, z in sched_a), "defaults too quiet"
    # re-consulting a tick replays the memo without re-counting
    fired = a.fired
    assert [(a.step_fault(t), a.page_holdback(t),
             a.nan_rows_for(t, 8).tolist()) for t in range(60)] == sched_a
    assert a.fired == fired
    # a different seed gives a different schedule
    c = FaultPlan(seed=8)
    assert sched_a != [(c.step_fault(t), c.page_holdback(t),
                        c.nan_rows_for(t, 8).tolist()) for t in range(60)]


def test_fault_plan_max_faults_cap():
    plan = FaultPlan(seed=1, p_step=1.0, p_alloc=1.0, p_nan=1.0,
                     max_faults=5)
    for t in range(50):
        plan.step_fault(t)
        plan.page_holdback(t)
        plan.nan_rows_for(t, 8)
    assert plan.fired == 5
    assert not plan.step_fault(100)     # quiet once the cap is spent


def test_parse_fault_spec():
    plan = parse_fault_spec("seed:7,step:0.1,alloc:0.2,nan:0.05,"
                            "holdback:4,rows:3,max:20")
    assert (plan.seed, plan.p_step, plan.p_alloc, plan.p_nan) \
        == (7, 0.1, 0.2, 0.05)
    assert (plan.holdback, plan.nan_rows, plan.max_faults) == (4, 3, 20)
    assert parse_fault_spec("seed:3").seed == 3
    with pytest.raises(ValueError, match="seed"):
        parse_fault_spec("step:0.5")
    with pytest.raises(ValueError, match="bad fault spec"):
        parse_fault_spec("seed:7,bogus:1")
    assert issubclass(InjectedStepFault, RuntimeError)


# ------------------------------------------------- kappa finite-guard

def _guard_cfg(**kw):
    base = dict(num_branches=4, adaptive_cutoff=False, draft_cutoff=1,
                horizon=8, window=8, mom_buckets=4, max_new_tokens=64)
    base.update(kw)
    return KappaConfig(**base)


def _state_after(steps, logits, cfg, state=None):
    log_q = signals.reference_log_q(jnp.zeros(64))
    state = K.init_state(cfg) if state is None else state
    for _ in range(steps):
        state = K.kappa_step(state, logits, jnp.arange(4, dtype=jnp.int32),
                             log_q, cfg)
    return state


def test_finite_guard_kills_poisoned_branch_only():
    cfg = _guard_cfg()
    clean = jax.random.normal(jax.random.PRNGKey(2), (4, 64))
    state = _state_after(3, clean, cfg)
    poisoned = clean.at[2].set(jnp.nan)
    nxt = _state_after(1, poisoned, cfg, state)
    assert not bool(nxt.alive[2]), "poisoned branch must be pruned"
    # the poison never reaches sibling statistics: every state leaf
    # stays finite, and decoding can continue cleanly afterwards
    for leaf in jax.tree.leaves(nxt):
        arr = np.asarray(leaf)
        if np.issubdtype(arr.dtype, np.floating):
            assert np.all(np.isfinite(arr))
    cont = _state_after(3, clean, cfg, nxt)
    assert int(K.num_alive(cont)) >= 1
    for leaf in jax.tree.leaves(cont):
        arr = np.asarray(leaf)
        if np.issubdtype(arr.dtype, np.floating):
            assert np.all(np.isfinite(arr))


def test_finite_guard_never_kills_everyone():
    cfg = _guard_cfg()
    state = _state_after(
        3, jax.random.normal(jax.random.PRNGKey(2), (4, 64)), cfg)
    all_bad = jnp.full((4, 64), jnp.nan)
    nxt = _state_after(1, all_bad, cfg, state)
    # an all-poisoned step cannot prune the request to zero branches —
    # the guard falls back to the pre-guard alive set
    assert int(K.num_alive(nxt)) >= 1


def test_finite_guard_applies_during_draft():
    """The kill is outside the gating window: a branch poisoned while
    the controller is still drafting (no pruning yet) dies immediately
    instead of contributing NaN history to later scoring steps."""
    cfg = _guard_cfg(draft_cutoff=6)
    clean = jax.random.normal(jax.random.PRNGKey(4), (4, 64))
    state = _state_after(2, clean, cfg)         # still in draft
    assert int(K.num_alive(state)) == 4
    nxt = _state_after(1, clean.at[1].set(jnp.inf), cfg, state)
    assert not bool(nxt.alive[1])
    assert int(K.num_alive(nxt)) == 3
