"""Windowed metrics + SLO-adaptive admission (DESIGN.md §9): the
scheduler's ``snapshot(reset_window=True)`` percentiles under a fake
clock, the controller's hysteretic escalation ladder against a stub
scheduler, and the closed loop on a real pool — a fake-clock-forced
ITL violation walks the knobs down (halve chunks, pause admits, shed)
and an idle pool walks them back up."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import KappaConfig
from repro.data import tokenizer as tok
from repro.models import init_params
from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                     PagedScheduler)
from repro.serving.slo import SLOConfig, SLOController

MAX_SEQ = 32
PAGE_SIZE = 4


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("deepseek-r1-distill-qwen-1.5b").reduced(
        num_layers=2, d_model=64, vocab_size=tok.VOCAB_SIZE)
    params = init_params(jax.random.PRNGKey(0), cfg)
    kcfg = KappaConfig(num_branches=4, max_new_tokens=12, max_cutoff=4,
                       horizon=6, window=8, mom_buckets=4)
    return cfg, params, kcfg


def _prompt(i, plen=7):
    body = np.random.default_rng(100 + i).integers(0, tok.MOD, size=plen - 2)
    return np.concatenate([[tok.BOS], body, [tok.QM]])


def _mk(setup, paged, rows=8, **kw):
    cfg, params, kcfg = setup
    base = dict(rows=rows, max_seq=MAX_SEQ, method="kappa",
                eos_id=tok.EOS, bos_id=tok.BOS)
    base.update(kw)
    if paged:
        return PagedScheduler(params, cfg, kcfg, page_size=PAGE_SIZE,
                              num_pages=rows * MAX_SEQ // PAGE_SIZE, **base)
    return ContinuousBatchingScheduler(params, cfg, kcfg, **base)


# --------------------------------------------------- windowed snapshot

def test_snapshot_windows_reset(setup, fake_clock):
    sched = _mk(setup, paged=False, clock=fake_clock)
    rid = sched.submit(_prompt(0), jax.random.PRNGKey(0), method="greedy",
                       max_new=12)
    sched.tick()                       # admit + first decode at t=0
    for _ in range(4):
        fake_clock.advance(0.25)       # every later tick is 0.25s apart
        sched.tick()
    snap = sched.snapshot(reset_window=True)
    assert snap["window_s"] == pytest.approx(1.0)
    assert snap["window_ticks"] == 5
    assert snap["itl_count"] >= 4
    assert snap["itl_p50_s"] == pytest.approx(0.25)
    assert snap["itl_p99_s"] == pytest.approx(0.25)
    assert snap["ttft_count"] == 1 and snap["completed"] == 0

    # the reset actually reset: a fresh window sees only what's new
    fresh = sched.snapshot()
    assert fresh["itl_count"] == 0 and fresh["ttft_count"] == 0
    assert fresh["window_ticks"] == 0

    fake_clock.advance(2.0)
    out = sched.run()
    assert out[rid].status == "OK"
    final = sched.snapshot(reset_window=True)
    assert final["completed"] == 1 and final["ok"] == 1
    assert final["ok_tokens"] == out[rid].logical_tokens
    # goodput is OK tokens over the WINDOW clock, not run lifetime
    assert final["goodput_tokens_per_s"] == pytest.approx(
        final["ok_tokens"] / final["window_s"])


def test_snapshot_counts_shed(setup):
    sched = _mk(setup, paged=False, max_queue=1)
    sched.submit(_prompt(0), jax.random.PRNGKey(0))
    sched.submit(_prompt(1), jax.random.PRNGKey(1))   # shed at the door
    snap = sched.snapshot()
    assert snap["shed"] == 1 and snap["completed"] == 1 and snap["ok"] == 0


# -------------------------------------------------- controller ladder

class _StubSched:
    """Knob surface the controller touches, with a scripted snapshot."""

    def __init__(self):
        self.prefill_chunk = 8
        self.prefill_budget = None
        self.max_queue = 16
        self.admit_paused = False
        self.ticks = 0
        self.queue = []
        self.snap = {}

    def snapshot(self, reset_window=False):
        return dict(self.snap)


def _stub_snap(itl_count=10, itl_p99=0.0, ttft_count=0, ttft_p99=0.0):
    return {"itl_count": itl_count, "itl_p99_s": itl_p99,
            "ttft_count": ttft_count, "ttft_p99_s": ttft_p99}


def test_controller_escalation_and_hysteresis():
    s = _StubSched()
    ctl = SLOController(s, SLOConfig(target_itl_p99_s=0.1,
                                     min_itl_samples=4))
    s.snap = _stub_snap(itl_p99=0.5)          # violated window
    ctl.update()
    assert ctl.level == 1
    assert s.prefill_chunk == 4 and not s.admit_paused
    assert s.prefill_budget == 8              # paced to one base chunk
    ctl.update()
    assert ctl.level == 2 and s.admit_paused
    assert s.max_queue == 16                  # queue untouched until 3
    ctl.update()
    assert ctl.level == 3 and s.max_queue == 8
    ctl.update()
    assert ctl.level == 3                     # clamped at max_level

    # in-between window (under target, above recover_frac*target): hold
    s.snap = _stub_snap(itl_p99=0.09)
    ctl.update()
    assert ctl.level == 3

    # clearly-healthy windows de-escalate one level each
    s.snap = _stub_snap(itl_p99=0.01)
    ctl.update()
    assert ctl.level == 2 and s.max_queue == 16
    ctl.update()
    assert ctl.level == 1 and not s.admit_paused
    ctl.update()
    assert ctl.level == 0 and s.prefill_chunk == 8
    assert s.prefill_budget is None           # pacing lifted at level 0
    assert len(ctl.history) == 8


def test_controller_unwedges_on_idle():
    """Too few samples to judge must read as healthy: a paused, drained
    pool produces no ITL samples, and staying paused forever would
    wedge admission shut."""
    s = _StubSched()
    ctl = SLOController(s, SLOConfig(target_itl_p99_s=0.1,
                                     min_itl_samples=4))
    s.snap = _stub_snap(itl_p99=9.0)
    ctl.update()
    ctl.update()
    assert s.admit_paused
    s.snap = _stub_snap(itl_count=0)          # idle: nothing to measure
    ctl.update()
    ctl.update()
    assert ctl.level == 0 and not s.admit_paused


def test_controller_ttft_target_escalates():
    s = _StubSched()
    ctl = SLOController(s, SLOConfig(target_itl_p99_s=1.0,
                                     target_ttft_p99_s=0.2,
                                     min_itl_samples=4))
    s.snap = _stub_snap(itl_p99=0.01, ttft_count=6, ttft_p99=0.9)
    ctl.update()
    assert ctl.level == 1                     # TTFT alone can escalate


# ----------------------------------------------- admission pacing knob

def test_prefill_budget_paces_admission(setup):
    """``prefill_budget`` spreads a burst of arrivals across ticks: one
    admission per tick with budget < prompt length, instead of all
    three riding the first tick's dispatch — and nothing is lost."""
    sched = _mk(setup, paged=True, prefill_chunk=4, method="greedy")
    sched.prefill_budget = 1
    rids = [sched.submit(_prompt(i), jax.random.PRNGKey(i),
                         method="greedy", max_new=4) for i in range(3)]
    sched.tick()
    assert len(sched.prefilling) + len(sched.active) == 1
    assert len(sched.queue) == 2
    sched.tick()
    assert len(sched.prefilling) + len(sched.active) == 2
    assert len(sched.queue) == 1
    out = sched.run()
    assert all(out[r].status == "OK" for r in rids)
    assert sorted(sched.free) == list(range(sched.rows))


# ------------------------------------------------------- closed loop

def test_slo_loop_degrades_then_recovers(setup, fake_clock):
    """Real pool, fake time: 0.5s ticks blow a 0.1s ITL p99 target, so
    the controller walks the full ladder (halve chunk → pause admits →
    shrink queue until a submit sheds); freezing the clock makes every
    window healthy and the ladder walks back to level 0, after which
    the queued work drains normally."""
    sched = _mk(setup, paged=True, rows=2, prefill_chunk=2, max_queue=8,
                method="greedy", clock=fake_clock)
    ctl = SLOController(sched, SLOConfig(target_itl_p99_s=0.1,
                                         window_ticks=4,
                                         min_itl_samples=2))
    rids = [sched.submit(_prompt(i), jax.random.PRNGKey(i),
                         method="greedy", max_new=20)
            for i in range(6)]                # 2 admit, 4 queue behind

    def drive(n, dt):
        for _ in range(n):
            fake_clock.advance(dt)
            if sched.has_work:
                sched.tick()
            ctl.on_tick()

    drive(4, 0.5)       # warmup window: chunked prefill, no ITL samples
    assert ctl.level == 0                     # nothing to judge yet
    drive(4, 0.5)
    assert ctl.level == 1 and sched.prefill_chunk == 1
    drive(4, 0.5)
    assert ctl.level == 2 and sched.admit_paused
    drive(4, 0.5)
    assert ctl.level == 3 and sched.max_queue == 4
    # the shrunken queue sheds at the door now
    shed_rid = sched.submit(_prompt(9), jax.random.PRNGKey(9),
                            method="greedy")
    assert sched.results[shed_rid].status == "SHED"

    drive(12, 0.0)                            # healthy windows: recover
    assert ctl.level == 0
    assert not sched.admit_paused
    assert sched.prefill_chunk == 2 and sched.max_queue == 8

    out = sched.run()                         # queued work drains
    assert all(out[r].status == "OK" for r in rids)
    assert sorted(sched.free) == list(range(sched.rows))
    assert any(h["violated"] for h in ctl.history)
    assert any(h["healthy"] for h in ctl.history)
