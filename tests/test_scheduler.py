"""Continuous-batching scheduler: token-for-token equivalence with the
sequential engine, per-row-position decode correctness, row-pool
lifecycle (admission, prune-backfill, release)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import KappaConfig
from repro.data import tokenizer as tok
from repro.models import decode_step, init_params
from repro.serving import cache as cache_lib
from repro.serving import engine
from repro.serving.scheduler import ContinuousBatchingScheduler


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("deepseek-r1-distill-qwen-1.5b").reduced(
        num_layers=2, d_model=64, vocab_size=tok.VOCAB_SIZE)
    params = init_params(jax.random.PRNGKey(0), cfg)
    kcfg = KappaConfig(num_branches=4, max_new_tokens=20, max_cutoff=4,
                       horizon=6, window=8, mom_buckets=4)
    # different lengths so pool rows sit at genuinely different positions
    prompts = [
        np.array([tok.BOS, tok.PROB, 3, tok.PLUS, 4, tok.EQ, tok.QM]),
        np.array([tok.BOS, tok.PROB, 7, tok.PLUS, 2, tok.PLUS, 1, tok.EQ, tok.QM]),
        np.array([tok.BOS, tok.PROB, 5, tok.PLUS, 5, tok.EQ, tok.QM]),
    ]
    max_seq = max(len(p) for p in prompts) + kcfg.max_new_tokens
    return cfg, params, kcfg, prompts, max_seq


def _sequential(setup, method, **kw):
    cfg, params, kcfg, prompts, max_seq = setup
    fn = getattr(engine, f"generate_{method}")
    return [fn(params, cfg, kcfg, p, jax.random.PRNGKey(i), eos_id=tok.EOS,
               bos_id=tok.BOS, max_seq=max_seq, **kw)
            for i, p in enumerate(prompts)]


def _scheduled(setup, method, rows, **sched_kw):
    cfg, params, kcfg, prompts, max_seq = setup
    sched = ContinuousBatchingScheduler(
        params, cfg, kcfg, rows=rows, max_seq=max_seq, method=method,
        eos_id=tok.EOS, bos_id=tok.BOS, **sched_kw)
    rids = [sched.submit(p, jax.random.PRNGKey(i))
            for i, p in enumerate(prompts)]
    res = sched.run()
    return sched, [res[r] for r in rids]


def test_kappa_scheduler_matches_sequential(setup):
    """The issue's acceptance property: continuous-batched KAPPA over K
    prompts == K sequential generate_kappa calls, token for token, with
    the same per-request RNG keys."""
    seq = _sequential(setup, "kappa")
    # rows=6 < 3*4: the 2nd/3rd requests only admit after prunes free rows
    sched, conc = _scheduled(setup, "kappa", rows=6)
    for s, c in zip(seq, conc):
        assert s.tokens == c.tokens
        assert s.chosen_branch == c.chosen_branch
        assert s.logical_tokens == c.logical_tokens
        assert s.compute_tokens == c.compute_tokens
        assert s.steps == c.steps
        assert s.compactions == c.compactions
    # backfill actually happened: more ticks than any single request's steps,
    # fewer than the sequential total
    assert sched.ticks < sum(s.steps for s in seq)


def test_greedy_scheduler_staggered_positions(setup):
    """Two greedy rows decode concurrently at different positions —
    exercises the per-row-pos fused decode path end to end."""
    seq = _sequential(setup, "greedy")
    _, conc = _scheduled(setup, "greedy", rows=2)
    for s, c in zip(seq, conc):
        assert s.tokens == c.tokens
        assert s.logical_tokens == c.logical_tokens


def test_bon_scheduler_matches_sequential(setup):
    """BoN with eager EOS-row release: branches finish at different
    steps, rows are handed back mid-request, and scheduler output still
    matches sequential serving (regression for the sum_lp/count
    accounting being indexed by surviving rows instead of branch id)."""
    cfg, params, kcfg, prompts, max_seq = setup
    seq = _sequential(setup, "bon")
    sched, conc = _scheduled(setup, "bon", rows=8)
    for s, c in zip(seq, conc):
        assert s.tokens == c.tokens
        assert s.chosen_branch == c.chosen_branch
        assert s.logical_tokens == c.logical_tokens
        assert s.extra["neg_ppl"] == c.extra["neg_ppl"]
    # the eager release actually fired somewhere: some request compacted
    # without a pruning strategy in play
    assert any(s.compactions for s in seq)


def test_stbon_scheduler_matches_sequential(setup):
    seq = _sequential(setup, "stbon", buffer_window=4)
    from repro.serving import strategies
    _, conc = _scheduled(
        setup, "stbon", rows=8,
        strategy_factory=lambda: strategies.STBoNStrategy(buffer_window=4))
    for s, c in zip(seq, conc):
        assert s.tokens == c.tokens
        assert s.chosen_branch == c.chosen_branch
        assert s.logical_tokens == c.logical_tokens


def test_kappa_scheduler_batched_controller_contract(setup):
    """The batched-controller guarantee: the pooled KAPPA controller
    makes at most ONE device dispatch and rides at most ONE blocking
    transfer per tick, no matter how many kappa requests are active."""
    from repro.serving import sampler
    cfg, params, kcfg, prompts, max_seq = setup
    sampler.reset_dispatch_counters()
    sched, conc = _scheduled(setup, "kappa", rows=8)
    assert sched._kappa_pool is not None
    assert sched._kappa_pool.dispatches == \
        sched.counters["controller_dispatches"]
    assert 0 < sched.counters["controller_dispatches"] <= sched.ticks
    assert sched.counters["controller_syncs"] == \
        sched.counters["controller_dispatches"]
    # the sampler stays fused too: one pool-wide sample_rows per tick
    # plus one per admission (prefill fan-out sampling)
    assert sampler.DISPATCHES["sample_rows"] <= sched.ticks + len(prompts)
    # all controller slots returned
    assert sorted(sched._kappa_pool.free) == list(range(8))


def test_mixed_strategy_pool_matches_sequential(setup):
    """One pool serving kappa + bon + greedy requests with per-request
    max_new stays token-for-token equivalent to dedicated sequential
    runs of each method."""
    import dataclasses
    cfg, params, kcfg, prompts, max_seq = setup
    specs = [("kappa", 20), ("bon", 12), ("greedy", 16)]
    seq = []
    for i, (p, (m, mn)) in enumerate(zip(prompts, specs)):
        kc = dataclasses.replace(kcfg, max_new_tokens=mn)
        fn = getattr(engine, f"generate_{m}")
        seq.append(fn(params, cfg, kc, p, jax.random.PRNGKey(i),
                      eos_id=tok.EOS, bos_id=tok.BOS, max_seq=max_seq))
    sched = ContinuousBatchingScheduler(
        params, cfg, kcfg, rows=8, max_seq=max_seq, method="kappa",
        eos_id=tok.EOS, bos_id=tok.BOS)
    rids = [sched.submit(p, jax.random.PRNGKey(i), max_new=mn, method=m)
            for i, (p, (m, mn)) in enumerate(zip(prompts, specs))]
    res = sched.run()
    for s, rid, (m, mn) in zip(seq, rids, specs):
        c = res[rid]
        assert s.tokens == c.tokens, f"{m} diverged in the mixed pool"
        assert s.chosen_branch == c.chosen_branch
        assert s.logical_tokens == c.logical_tokens
        assert s.compute_tokens == c.compute_tokens
        assert s.steps == c.steps
    # kappa ran pooled even in mixed company
    assert sched._kappa_pool is not None
    assert sched.counters["controller_dispatches"] <= sched.ticks


def test_scheduler_pool_lifecycle(setup):
    cfg, params, kcfg, prompts, max_seq = setup
    sched, conc = _scheduled(setup, "kappa", rows=6)
    # every slot returned to the free list after the run
    assert sorted(sched.free) == list(range(6))
    assert not sched.active and not sched.queue
    tp = sched.throughput()
    assert tp["requests"] == len(prompts)
    assert 0.0 < tp["row_utilization"] <= 1.0
    assert tp["logical_tokens"] == sum(c.logical_tokens for c in conc)


def test_scheduler_rejects_oversized(setup):
    cfg, params, kcfg, prompts, max_seq = setup
    with pytest.raises(ValueError):
        ContinuousBatchingScheduler(params, cfg, kcfg, rows=2,
                                    max_seq=max_seq, method="kappa",
                                    eos_id=tok.EOS)  # fan-out 4 > 2 rows
    sched = ContinuousBatchingScheduler(params, cfg, kcfg, rows=4,
                                        max_seq=8, method="kappa",
                                        eos_id=tok.EOS)
    with pytest.raises(ValueError):
        sched.submit(prompts[0], jax.random.PRNGKey(0))  # prompt+max_new > 8


# ------------------------------------------------- per-row decode step

def test_decode_step_vector_pos_matches_scalar(setup):
    """decode_step with a (B,) position vector is row-wise identical to
    the scalar-pos step — the property the fused pool step relies on."""
    cfg, params, kcfg, prompts, max_seq = setup
    step = jax.jit(decode_step, static_argnums=(1,))

    pf, c1 = engine._prefill_one(params, cfg, prompts[0], max_seq)
    pf2, c2 = engine._prefill_one(params, cfg, prompts[1], max_seq)
    pos1, pos2 = len(prompts[0]), len(prompts[1])
    toks = jnp.array([5, 9, 7], jnp.int32)

    # pool of 3 rows: rows 0,2 from prompt 0 at pos1; row 1 from prompt 1
    pool = cache_lib.broadcast_batch(c1, 3)
    pool = cache_lib.scatter_batch(pool, jnp.array([1]), c2)
    posv = jnp.array([pos1, pos2, pos1], jnp.int32)
    lv, _ = step(params, cfg, toks, posv, pool)

    ls1, _ = step(params, cfg, toks[jnp.array([0, 2])], jnp.int32(pos1),
                  cache_lib.gather_batch(pool, jnp.array([0, 2])))
    ls2, _ = step(params, cfg, toks[jnp.array([1])], jnp.int32(pos2),
                  cache_lib.gather_batch(pool, jnp.array([1])))
    assert np.array_equal(np.asarray(lv)[[0, 2]], np.asarray(ls1))
    assert np.array_equal(np.asarray(lv)[[1]], np.asarray(ls2))


def test_scatter_gather_roundtrip(setup):
    cfg, params, kcfg, prompts, max_seq = setup
    _, c1 = engine._prefill_one(params, cfg, prompts[0], max_seq)
    pool = cache_lib.broadcast_batch(c1, 4)
    _, c2 = engine._prefill_one(params, cfg, prompts[1], max_seq)
    sub = cache_lib.broadcast_batch(c2, 2)
    idx = jnp.array([1, 3])
    pool2 = cache_lib.scatter_batch(pool, idx, sub)
    back = cache_lib.gather_batch(pool2, idx)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(sub)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # untouched rows unchanged
    keep = cache_lib.gather_batch(pool2, jnp.array([0, 2]))
    orig = cache_lib.gather_batch(pool, jnp.array([0, 2]))
    for a, b in zip(jax.tree.leaves(keep), jax.tree.leaves(orig)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
