"""Expert-parallel shard_map MoE: validated in a subprocess with an
8-device host mesh (this test process must keep seeing 1 device)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_expert_parallel_matches_oracle_on_8_device_mesh():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))
    proc = subprocess.run(
        [sys.executable, os.path.join(root, "scripts", "validate_moe_ep.py")],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "dropless oracle: OK" in proc.stdout
    assert "gradients: OK" in proc.stdout
