"""Config registry: all assigned architectures, reduced variants,
shape applicability."""
import pytest

from repro.configs import (
    ASSIGNED_ARCHS,
    INPUT_SHAPES,
    all_configs,
    applicable_shapes,
    get_config,
)

EXPECTED = {
    "granite-moe-3b-a800m": dict(num_layers=32, d_model=1536, num_heads=24,
                                 num_kv_heads=8, d_ff=512, vocab_size=49155,
                                 num_experts=40, experts_per_tok=8),
    "qwen1.5-4b": dict(num_layers=40, d_model=2560, num_heads=20,
                       num_kv_heads=20, d_ff=6912, vocab_size=151936,
                       qkv_bias=True),
    "gemma3-4b": dict(num_layers=34, d_model=2560, num_heads=8,
                      num_kv_heads=4, d_ff=10240, vocab_size=262144),
    "qwen3-moe-30b-a3b": dict(num_layers=48, d_model=2048, num_heads=32,
                              num_kv_heads=4, d_ff=768, vocab_size=151936,
                              num_experts=128, experts_per_tok=8),
    "recurrentgemma-9b": dict(num_layers=38, d_model=4096, num_heads=16,
                              num_kv_heads=1, d_ff=12288, vocab_size=256000),
    "internvl2-76b": dict(num_layers=80, d_model=8192, num_heads=64,
                          num_kv_heads=8, d_ff=28672, vocab_size=128256),
    "starcoder2-3b": dict(num_layers=30, d_model=3072, num_heads=24,
                          num_kv_heads=2, d_ff=12288, vocab_size=49152),
    "whisper-small": dict(num_layers=12, d_model=768, num_heads=12,
                          num_kv_heads=12, d_ff=3072, vocab_size=51865),
    "granite-3-8b": dict(num_layers=40, d_model=4096, num_heads=32,
                         num_kv_heads=8, d_ff=12800, vocab_size=49155),
    "rwkv6-3b": dict(num_layers=32, d_model=2560, d_ff=8960,
                     vocab_size=65536),
}


def test_all_ten_assigned_archs_present():
    assert len(ASSIGNED_ARCHS) == 10
    assert set(EXPECTED) == set(ASSIGNED_ARCHS)


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_exact_assigned_config(arch):
    cfg = get_config(arch)
    for field, val in EXPECTED[arch].items():
        assert getattr(cfg, field) == val, f"{arch}.{field}"
    assert cfg.source, "every config must cite its source"


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_reduced_variant_constraints(arch):
    r = get_config(arch).reduced()
    assert r.num_layers <= 2
    assert r.d_model <= 512
    assert r.num_experts <= 4
    assert r.family == get_config(arch).family


def test_input_shapes_exact():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq_len == 32768
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].seq_len == 32768
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
    assert INPUT_SHAPES["long_500k"].global_batch == 1


def test_long_500k_applicability():
    """long_500k only for sub-quadratic-capable archs (DESIGN.md §4)."""
    runs = {a for a in ASSIGNED_ARCHS
            if "long_500k" in applicable_shapes(get_config(a))}
    assert runs == {"gemma3-4b", "recurrentgemma-9b", "starcoder2-3b",
                    "rwkv6-3b"}


def test_every_arch_gets_first_three_shapes():
    for arch in ASSIGNED_ARCHS:
        shapes = applicable_shapes(get_config(arch))
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)


def test_param_counts_in_family_ballpark():
    # analytic counts should land near the model names' advertised sizes
    assert 2.5e9 < get_config("granite-moe-3b-a800m").param_count() < 4.0e9
    assert 25e9 < get_config("qwen3-moe-30b-a3b").param_count() < 33e9
    assert 2.0e9 < get_config("qwen3-moe-30b-a3b").active_param_count() < 4.0e9
    assert 60e9 < get_config("internvl2-76b").param_count() < 80e9
    assert 7e9 < get_config("granite-3-8b").param_count() < 9.5e9
