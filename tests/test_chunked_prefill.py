"""Chunked prefill interleaved with decode (DESIGN.md §6): bitwise
equality of the final chunk's logits with one-shot prefill, chunk/page
boundary edge cases, scheduler equivalence with chunked admission on
both backends, mid-PREFILLING preemption replay, decode-stall bounds,
and the prompt-sized admission-cache regression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import KappaConfig
from repro.data import tokenizer as tok
from repro.models import (init_cache, init_paged_cache, init_params,
                          prefill_chunk)
from repro.serving import cache as cache_lib
from repro.serving import engine
from repro.serving.cache import PageAllocator
from repro.serving.scheduler import ContinuousBatchingScheduler, PagedScheduler


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("deepseek-r1-distill-qwen-1.5b").reduced(
        num_layers=2, d_model=64, vocab_size=tok.VOCAB_SIZE)
    params = init_params(jax.random.PRNGKey(0), cfg)
    kcfg = KappaConfig(num_branches=4, max_new_tokens=20, max_cutoff=4,
                       horizon=6, window=8, mom_buckets=4)
    prompts = [
        np.array([tok.BOS, tok.PROB, 3, tok.PLUS, 4, tok.EQ, tok.QM]),
        np.array([tok.BOS, tok.PROB, 7, tok.PLUS, 2, tok.PLUS, 1, tok.EQ, tok.QM]),
        np.array([tok.BOS, tok.PROB, 5, tok.PLUS, 5, tok.EQ, tok.QM]),
    ]
    max_seq = max(len(p) for p in prompts) + kcfg.max_new_tokens
    return cfg, params, kcfg, prompts, max_seq


# ------------------------------------------------- bitwise logit parity

def test_prefill_chunked_bitwise_matches_oneshot(setup):
    """The acceptance property: on a global-attention layer pattern the
    final chunk's logits are BITWISE equal to the one-shot prefill —
    chunk == prompt, chunk dividing the prompt, chunk > prompt, and
    chunk = 1 (pure decode-style prefill) alike."""
    cfg, params, kcfg, prompts, max_seq = setup
    prompt = prompts[1]                       # len 9
    pf, _ = engine._prefill_one(params, cfg, prompt, max_seq)
    pf = np.asarray(pf)
    for chunk in (1, 3, 4, len(prompt), len(prompt) + 5):
        lc, _ = engine.prefill_chunked(params, cfg, prompt, max_seq, chunk)
        assert np.array_equal(np.asarray(lc), pf), f"chunk={chunk} diverged"


def test_prefill_chunked_paged_bitwise_matches_oneshot(setup):
    """Paged edition: chunk K/V written straight into allocator-owned
    pages, attention through the block table — last chunk's logits stay
    bitwise equal to the contiguous one-shot prefill."""
    cfg, params, kcfg, prompts, max_seq = setup
    prompt = prompts[1]
    ps = 4
    pf, _ = engine._prefill_one(params, cfg, prompt, max_seq)
    pf = np.asarray(pf)
    for chunk in (3, len(prompt), 2 * ps):    # incl. chunk == 2 pages
        num_pages = 12
        alloc = PageAllocator(num_pages, ps, rows=2,
                              max_pages=-(-max_seq // ps))
        pool = init_paged_cache(cfg, 2, num_pages, ps,
                                -(-max_seq // ps) * ps)
        aux = init_cache(cfg, 1, 1)
        logits, filled = None, 0
        while filled < len(prompt):
            piece = prompt[filled:filled + chunk]
            need = alloc.pages_for(filled + len(piece))
            while int(alloc.owned[0]) < need:
                if int(alloc.owned[0]) == 0:
                    alloc.set_row_pages(0, alloc.alloc_pages(1))
                else:
                    alloc.append_page(0)
            qpos = np.arange(filled, filled + len(piece))
            cpages = alloc.block[0][qpos // ps]
            logits, pool, aux = prefill_chunk(
                params, cfg, jnp.asarray(piece)[None],
                jnp.full((1,), filled, jnp.int32), 0, pool,
                jnp.asarray(alloc.block[0:1]),
                jnp.asarray(cpages.astype(np.int32))[None], aux)
            filled += len(piece)
        assert np.array_equal(np.asarray(logits)[0], pf), \
            f"paged chunk={chunk} diverged"


def test_prefill_chunked_bitwise_on_ring_pattern():
    """Sliding-window layers: the chunked path re-gathers the ring
    window in ascending absolute-position order, so the nonzero softmax
    terms sum in the same order as one-shot prefill and the final
    chunk's logits are BITWISE equal across chunk arrangements (was
    allclose-only while the history rode in rotated slot order)."""
    cfg = get_config("gemma3-4b").reduced(num_layers=6, d_model=64,
                                          vocab_size=tok.VOCAB_SIZE)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(2, 15) % 10 + 2
    pf, _ = engine._prefill_one(params, cfg, prompt, 40)
    for chunk in (1, 3, 4, 5, len(prompt), len(prompt) + 3):
        lc, _ = engine.prefill_chunked(params, cfg, prompt, 40, chunk)
        assert np.array_equal(np.asarray(lc), np.asarray(pf)), \
            f"ring chunk={chunk} diverged"


# ------------------------------------------------ scheduler equivalence

def _sequential(setup, method):
    cfg, params, kcfg, prompts, max_seq = setup
    fn = getattr(engine, f"generate_{method}")
    return [fn(params, cfg, kcfg, p, jax.random.PRNGKey(i), eos_id=tok.EOS,
               bos_id=tok.BOS, max_seq=max_seq)
            for i, p in enumerate(prompts)]


def _check_equal(seq, res, rids):
    for s, rid in zip(seq, rids):
        c = res[rid]
        assert s.tokens == c.tokens
        assert s.chosen_branch == c.chosen_branch
        assert s.logical_tokens == c.logical_tokens
        assert s.compute_tokens == c.compute_tokens
        assert s.steps == c.steps


def test_chunked_contiguous_scheduler_matches_sequential(setup):
    cfg, params, kcfg, prompts, max_seq = setup
    seq = _sequential(setup, "kappa")
    sched = ContinuousBatchingScheduler(
        params, cfg, kcfg, rows=6, max_seq=max_seq, method="kappa",
        eos_id=tok.EOS, bos_id=tok.BOS, prefill_chunk=3)
    rids = [sched.submit(p, jax.random.PRNGKey(i))
            for i, p in enumerate(prompts)]
    _check_equal(seq, sched.run(), rids)
    assert sorted(sched.free) == list(range(6))
    assert not sched.prefilling


def test_chunked_paged_scheduler_matches_sequential(setup):
    cfg, params, kcfg, prompts, max_seq = setup
    seq = _sequential(setup, "kappa")
    sched = PagedScheduler(
        params, cfg, kcfg, rows=6, max_seq=max_seq, page_size=8,
        num_pages=24, method="kappa", eos_id=tok.EOS, bos_id=tok.BOS,
        prefill_chunk=3)
    rids = [sched.submit(p, jax.random.PRNGKey(i))
            for i, p in enumerate(prompts)]
    _check_equal(seq, sched.run(), rids)
    assert sched.alloc.free_count == 24        # zero leaked pages
    assert sorted(sched.free) == list(range(6))


def test_chunked_mixed_strategies_match_sequential(setup):
    """Chunked admission under mixed kappa/bon/greedy traffic with
    per-request max_new — the whole strategy surface rides the same
    final-chunk logits."""
    cfg, params, kcfg, prompts, max_seq = setup
    specs = [("kappa", 20), ("bon", 12), ("greedy", 16)]
    seq = []
    for i, (p, (m, mn)) in enumerate(zip(prompts, specs)):
        kc = dataclasses.replace(kcfg, max_new_tokens=mn)
        fn = getattr(engine, f"generate_{m}")
        seq.append(fn(params, cfg, kc, p, jax.random.PRNGKey(i),
                      eos_id=tok.EOS, bos_id=tok.BOS, max_seq=max_seq))
    sched = PagedScheduler(params, cfg, kcfg, rows=12, max_seq=max_seq,
                           page_size=8, num_pages=64, method="kappa",
                           eos_id=tok.EOS, bos_id=tok.BOS, prefill_chunk=4)
    rids = [sched.submit(p, jax.random.PRNGKey(i), max_new=mn, method=m)
            for i, (p, (m, mn)) in enumerate(zip(prompts, specs))]
    res = sched.run()
    for s, rid in zip(seq, rids):
        assert s.tokens == res[rid].tokens
        assert s.logical_tokens == res[rid].logical_tokens
    assert sched.alloc.free_count == sched.num_pages


# ------------------------------------------------------ edge cases

def test_chunk_boundary_edge_cases(setup):
    """Prompt exactly one chunk, prompt an exact chunk multiple, chunk
    larger than the prompt, and a page-aligned prompt (no COW boundary
    page) all reproduce the sequential engine."""
    cfg, params, kcfg, prompts, max_seq = setup
    ps = 4
    cases = [
        (prompts[0], len(prompts[0])),        # one chunk == prompt
        (prompts[2], len(prompts[2]) // 2),   # len 6, chunk 3: multiple
        (prompts[1], 2 * len(prompts[1])),    # chunk > prompt
        (np.concatenate([prompts[0], [5]]), 3),  # len 8 = 2 pages exactly
    ]
    assert len(cases[3][0]) % ps == 0
    for prompt, chunk in cases:
        seq = engine.generate_kappa(params, cfg, kcfg, prompt,
                                    jax.random.PRNGKey(7), eos_id=tok.EOS,
                                    bos_id=tok.BOS, max_seq=max_seq)
        sched = PagedScheduler(params, cfg, kcfg, rows=4, max_seq=max_seq,
                               page_size=ps, num_pages=40, method="kappa",
                               eos_id=tok.EOS, bos_id=tok.BOS,
                               prefill_chunk=chunk)
        rid = sched.submit(prompt, jax.random.PRNGKey(7))
        res = sched.run()
        assert seq.tokens == res[rid].tokens, f"chunk={chunk} diverged"
        assert sched.alloc.free_count == sched.num_pages
        if len(prompt) % ps == 0 and kcfg.num_branches > 1:
            # page-aligned prompt: finalize shares every prompt page,
            # no boundary copy was ever allocated
            assert sched._page_peak <= len(prompt) // ps \
                + kcfg.num_branches * (sched.alloc.pages_for(
                    len(prompt) + kcfg.max_new_tokens) - len(prompt) // ps)


def test_eos_on_first_post_prefill_token(setup):
    """A greedy request whose very first sampled token is EOS finishes
    at activation: the chunked path must release its rows and pages
    without ever joining a decode tick."""
    cfg, params, kcfg, prompts, max_seq = setup
    prompt = prompts[0]
    pf, _ = engine._prefill_one(params, cfg, prompt, max_seq)
    eos = int(np.argmax(np.asarray(pf)))      # force: argmax IS the EOS id
    seq = engine.generate_greedy(params, cfg, kcfg, prompt,
                                 jax.random.PRNGKey(0), eos_id=eos,
                                 bos_id=tok.BOS, max_seq=max_seq)
    assert seq.tokens == [eos]
    sched = PagedScheduler(params, cfg, kcfg, rows=4, max_seq=max_seq,
                           page_size=4, num_pages=32, method="greedy",
                           eos_id=eos, bos_id=tok.BOS, prefill_chunk=3)
    rid = sched.submit(prompt, jax.random.PRNGKey(0))
    res = sched.run()
    assert res[rid].tokens == [eos]
    assert res[rid].steps == 0
    assert not sched.active and not sched.prefilling
    assert sched.alloc.free_count == sched.num_pages
    assert sorted(sched.free) == list(range(4))


def test_preemption_mid_prefill_replays_token_equal(setup):
    """Page pressure evicts the youngest request while it is still
    PREFILLING: its pages and rows come back, the original submission is
    requeued, and the replay is token-for-token identical to an
    unpreempted run."""
    cfg, params, kcfg, prompts, max_seq = setup
    short = prompts[0]
    long_p = np.concatenate([short] + [short[1:]] * 4)   # len 31
    max_seq2 = len(long_p) + kcfg.max_new_tokens + 1
    seq_a = engine.generate_bon(params, cfg, kcfg, short,
                                jax.random.PRNGKey(0), eos_id=tok.EOS,
                                bos_id=tok.BOS, max_seq=max_seq2)
    seq_b = engine.generate_greedy(params, cfg, kcfg, long_p,
                                   jax.random.PRNGKey(1), eos_id=tok.EOS,
                                   bos_id=tok.BOS, max_seq=max_seq2)
    sched = PagedScheduler(params, cfg, kcfg, rows=6, max_seq=max_seq2,
                           page_size=4, num_pages=26, method="bon",
                           eos_id=tok.EOS, bos_id=tok.BOS, prefill_chunk=2)
    ra = sched.submit(short, jax.random.PRNGKey(0), method="bon")
    sched.tick()                              # A enters the pool first
    rb = sched.submit(long_p, jax.random.PRNGKey(1), method="greedy")
    saw_mid_prefill = False
    for _ in range(400):
        sched.tick()
        pf = sched.prefilling.get(rb)
        if pf is not None and 0 < pf.filled < len(long_p):
            saw_mid_prefill = True
        if not (sched.queue or sched.active or sched.prefilling):
            break
    assert saw_mid_prefill, "long request never observed mid-PREFILLING"
    assert sched.counters["preemptions"] >= 1
    assert sched.results[ra].tokens == seq_a.tokens
    assert sched.results[rb].tokens == seq_b.tokens
    assert sched.alloc.free_count == sched.num_pages
    assert sorted(sched.free) == list(range(6))


# -------------------------------------------- interleaving / no stalls

def test_decode_advances_every_tick_during_long_prefill(setup):
    """The head-of-line fix itself: while a long prompt is PREFILLING,
    already-decoding requests emit one token EVERY tick (with one-shot
    admission the whole prompt lands inside a single tick instead)."""
    cfg, params, kcfg, prompts, max_seq = setup
    long_p = np.concatenate([prompts[0]] + [prompts[0][1:]] * 4)
    max_seq2 = len(long_p) + kcfg.max_new_tokens
    sched = PagedScheduler(params, cfg, kcfg, rows=6, max_seq=max_seq2,
                           page_size=8, num_pages=64, method="greedy",
                           eos_id=tok.EOS, bos_id=tok.BOS, prefill_chunk=2)
    r1 = sched.submit(prompts[0], jax.random.PRNGKey(0))
    r2 = sched.submit(prompts[2], jax.random.PRNGKey(2))
    for _ in range(12):
        sched.tick()
        if r1 in sched.active and r2 in sched.active:
            break
    assert r1 in sched.active and r2 in sched.active
    rl = sched.submit(long_p, jax.random.PRNGKey(1))
    steps_before = sched.active[r1][0].step
    prefill_ticks = 0
    while rl in sched.prefilling or rl in (q.rid for q in sched.queue):
        sched.tick()
        prefill_ticks += 1
        if r1 not in sched.active:
            break
        # decode advanced THIS tick even though a prefill chunk also ran
        assert sched.active[r1][0].step == steps_before + prefill_ticks
    assert prefill_ticks >= len(long_p) // 2  # genuinely chunked
    sched.run()
    assert sched.alloc.free_count == sched.num_pages


def test_scheduler_latency_stats(setup):
    """TTFT / ITL accounting: every served request has a TTFT and a
    token timestamp per decode tick it participated in."""
    cfg, params, kcfg, prompts, max_seq = setup
    sched = ContinuousBatchingScheduler(
        params, cfg, kcfg, rows=6, max_seq=max_seq, method="kappa",
        eos_id=tok.EOS, bos_id=tok.BOS, prefill_chunk=4)
    rids = [sched.submit(p, jax.random.PRNGKey(i))
            for i, p in enumerate(prompts)]
    res = sched.run()
    stats = sched.latency_stats()
    assert set(rids) == set(sched.ttft)
    for rid in rids:
        assert sched.ttft[rid] > 0
        # first stamp at activation + one per decode tick the request saw
        assert len(sched.token_times[rid]) == res[rid].steps + 1
    assert stats["itl_p99_s"] >= stats["itl_p50_s"] >= 0
    assert stats["ttft_p99_s"] >= stats["ttft_p50_s"] > 0


# ------------------------------------------- admission-cache sizing fix

def test_admission_prefill_cache_sized_to_prompt(setup):
    """Regression (PR 5 satellite): the transient admission prefill
    cache is sized to the PROMPT, not max_seq — per-admission peak bytes
    shrink accordingly, and the chunked paged path's aux state is
    smaller still (global KV goes straight to pages)."""
    cfg, params, kcfg, prompts, max_seq = setup
    big_seq = 4 * max_seq                     # roomy pool, short prompts
    old_bytes = cache_lib.cache_bytes(init_cache(cfg, 1, big_seq))

    sched = ContinuousBatchingScheduler(
        params, cfg, kcfg, rows=4, max_seq=big_seq, method="kappa",
        eos_id=tok.EOS, bos_id=tok.BOS)
    rid = sched.submit(prompts[0], jax.random.PRNGKey(0), max_new=8)
    sched.run()
    prompt_bytes = cache_lib.cache_bytes(
        init_cache(cfg, 1, len(prompts[0])))
    assert sched.admit_peak_bytes == prompt_bytes
    assert sched.admit_peak_bytes * 4 <= old_bytes

    paged = PagedScheduler(params, cfg, kcfg, rows=4, max_seq=big_seq,
                           page_size=8, num_pages=64, method="kappa",
                           eos_id=tok.EOS, bos_id=tok.BOS, prefill_chunk=4)
    rid = paged.submit(prompts[0], jax.random.PRNGKey(0), max_new=8)
    paged.run()
    # chunked paged admissions carry only the batch-1 per-row aux state
    assert paged.admit_peak_bytes < prompt_bytes
