"""Training substrate: optimization actually reduces loss; checkpoints
round-trip; LR schedule shape."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import tasks
from repro.data import tokenizer as tok
from repro.training import checkpoint
from repro.training.optimizer import clip_by_global_norm, cosine_lr
from repro.training.train import init_train_state, lm_loss, train_step


def test_loss_decreases_on_tiny_task():
    cfg = get_config("deepseek-r1-distill-qwen-1.5b").reduced(
        num_layers=2, d_model=64, vocab_size=tok.VOCAB_SIZE)
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    data = tasks.make_dataset(0, 64, min_steps=1, max_steps=2, num_ops=1,
                              max_operand=5)
    toks, mask = tasks.pack_batch(data[:32], 24)
    toks, mask = jnp.asarray(toks), jnp.asarray(mask)
    losses = []
    for step in range(30):
        state, m = train_step(state, cfg, toks, mask, jnp.int32(step),
                              None, total=30, base_lr=1e-2)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses[::10]


def test_checkpoint_roundtrip():
    cfg = get_config("rwkv6-3b").reduced(num_layers=2, d_model=64)
    params = init_train_state(jax.random.PRNGKey(0), cfg).params
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ck.msgpack")
        checkpoint.save(path, params)
        restored = checkpoint.restore(path, jax.tree.map(jnp.zeros_like, params))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grad_clip_bounds_norm():
    g = {"a": jnp.full((10,), 100.0), "b": jnp.full((5,), -100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    from repro.training.optimizer import global_norm
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 1.0


def test_cosine_lr_shape():
    lrs = [float(cosine_lr(jnp.int32(s), base_lr=1.0, warmup=10, total=100))
           for s in range(100)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 0.15
    assert lrs[-1] < 0.2
    assert max(lrs) <= 1.0 + 1e-6


def test_moe_aux_loss_flows_into_training():
    cfg = get_config("granite-moe-3b-a800m").reduced(num_layers=2, d_model=64,
                                                     vocab_size=tok.VOCAB_SIZE)
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    mask = jnp.ones((2, 12), jnp.float32)
    total, (loss, aux) = lm_loss(state.params, cfg, toks, mask)
    assert float(aux) > 0.0
    assert float(total) > float(loss)
