"""End-to-end behaviour: train a tiny model on the synthetic task, run
every decoding strategy, verify the paper's qualitative claims hold
directionally (KAPPA ≤ BoN cost at comparable accuracy)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import KappaConfig
from repro.data import tasks
from repro.data import tokenizer as tok
from repro.serving import engine
from repro.training.train import init_train_state, train_step


@pytest.fixture(scope="module")
def trained():
    """~60 s CPU training — enough to make branch quality non-random."""
    cfg = get_config("deepseek-r1-distill-qwen-1.5b").reduced(
        num_layers=2, d_model=128, vocab_size=tok.VOCAB_SIZE)
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    data = tasks.make_dataset(0, 2048, min_steps=1, max_steps=3, num_ops=1,
                              max_operand=5)
    B, L = 32, 24
    for step in range(150):
        batch = [data[(step * B + i) % len(data)] for i in range(B)]
        toks, mask = tasks.pack_batch(batch, L)
        state, m = train_step(state, cfg, jnp.asarray(toks), jnp.asarray(mask),
                              jnp.int32(step), None, total=150, base_lr=5e-3)
    return cfg, state.params, float(m["loss"])


def test_training_converged_enough(trained):
    _, _, loss = trained
    assert loss < 2.0, f"tiny model failed to learn anything: loss={loss}"


def _run_all(trained, n_problems=8):
    cfg, params, _ = trained
    kcfg = KappaConfig(num_branches=5, max_new_tokens=24, max_cutoff=4,
                       horizon=6, window=8, mom_buckets=4)
    test = tasks.make_dataset(77, n_problems, min_steps=1, max_steps=3,
                              num_ops=1, max_operand=5)
    out = {}
    for name, fn in [("greedy", engine.generate_greedy),
                     ("bon", engine.generate_bon),
                     ("stbon", engine.generate_stbon),
                     ("kappa", engine.generate_kappa)]:
        accs, lts, peaks = [], [], []
        for i, prob in enumerate(test):
            r = fn(params, cfg, kcfg, np.array(prob.prompt),
                   jax.random.PRNGKey(i), eos_id=tok.EOS, bos_id=tok.BOS)
            accs.append(tasks.check_answer(r.tokens, prob))
            lts.append(r.logical_tokens)
            peaks.append(r.peak_cache_bytes)
        out[name] = dict(acc=np.mean(accs), tokens=np.mean(lts),
                         peak=max(peaks))
    return out


def test_paper_qualitative_claims(trained):
    res = _run_all(trained)
    # claim: KAPPA generates far fewer tokens than full BoN
    assert res["kappa"]["tokens"] < 0.95 * res["bon"]["tokens"]
    # claim: KAPPA's peak memory below BoN's (branch compaction)
    assert res["kappa"]["peak"] <= res["bon"]["peak"]
    # sanity: every method produced answers for some problems
    for name, r in res.items():
        assert 0.0 <= r["acc"] <= 1.0


def test_generation_emits_wellformed_cot(trained):
    cfg, params, _ = trained
    kcfg = KappaConfig(num_branches=5, max_new_tokens=24, max_cutoff=4,
                       horizon=6, window=8, mom_buckets=4)
    prob = tasks.make_dataset(5, 1, min_steps=1, max_steps=2, num_ops=1,
                              max_operand=5)[0]
    r = engine.generate_kappa(params, cfg, kcfg, np.array(prob.prompt),
                              jax.random.PRNGKey(0), eos_id=tok.EOS,
                              bos_id=tok.BOS)
    assert len(r.tokens) > 0
    assert all(0 <= t < tok.VOCAB_SIZE for t in r.tokens)
