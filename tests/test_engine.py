"""Serving-engine behaviour: all four strategies, compaction, memory and
token accounting invariants (random tiny model — accuracy-free checks)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import KappaConfig
from repro.data import tokenizer as tok
from repro.models import init_params
from repro.core import kappa as kappa_lib
from repro.core import signals
from repro.serving import cache as cache_lib
from repro.serving import engine
from repro.serving import strategies


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("deepseek-r1-distill-qwen-1.5b").reduced(
        num_layers=2, d_model=64, vocab_size=tok.VOCAB_SIZE)
    params = init_params(jax.random.PRNGKey(0), cfg)
    kcfg = KappaConfig(num_branches=5, max_new_tokens=24, max_cutoff=4,
                       horizon=6, window=8, mom_buckets=4)
    prompt = np.array([tok.BOS, tok.PROB, 3, tok.PLUS, 4, tok.EQ, tok.QM])
    return cfg, params, kcfg, prompt


def test_greedy_deterministic(setup):
    cfg, params, kcfg, prompt = setup
    r1 = engine.generate_greedy(params, cfg, kcfg, prompt, jax.random.PRNGKey(0),
                                eos_id=tok.EOS, bos_id=tok.BOS)
    r2 = engine.generate_greedy(params, cfg, kcfg, prompt, jax.random.PRNGKey(7),
                                eos_id=tok.EOS, bos_id=tok.BOS)
    assert r1.tokens == r2.tokens
    assert r1.logical_tokens == r1.compute_tokens == len(r1.tokens)


def test_bon_generates_n_branches(setup):
    cfg, params, kcfg, prompt = setup
    r = engine.generate_bon(params, cfg, kcfg, prompt, jax.random.PRNGKey(0),
                            eos_id=tok.EOS, bos_id=tok.BOS)
    assert 0 <= r.chosen_branch < kcfg.num_branches
    assert r.logical_tokens <= kcfg.num_branches * kcfg.max_new_tokens
    assert len(r.extra["neg_ppl"]) == kcfg.num_branches
    # chosen branch maximizes negative perplexity
    assert r.chosen_branch == int(np.argmax(r.extra["neg_ppl"]))


def test_kappa_prunes_and_compacts(setup):
    cfg, params, kcfg, prompt = setup
    r = engine.generate_kappa(params, cfg, kcfg, prompt, jax.random.PRNGKey(0),
                              eos_id=tok.EOS, bos_id=tok.BOS)
    assert r.compactions, "KAPPA must shrink the branch batch"
    assert r.compactions == sorted(r.compactions, reverse=True)
    assert r.compactions[-1] <= 2
    assert 0 <= r.chosen_branch < kcfg.num_branches


def test_kappa_cheaper_than_bon(setup):
    cfg, params, kcfg, prompt = setup
    rb = engine.generate_bon(params, cfg, kcfg, prompt, jax.random.PRNGKey(0),
                             eos_id=tok.EOS, bos_id=tok.BOS)
    rk = engine.generate_kappa(params, cfg, kcfg, prompt, jax.random.PRNGKey(0),
                               eos_id=tok.EOS, bos_id=tok.BOS)
    assert rk.logical_tokens < rb.logical_tokens
    assert rk.peak_cache_bytes <= rb.peak_cache_bytes


def test_stbon_truncates_to_one(setup):
    cfg, params, kcfg, prompt = setup
    r = engine.generate_stbon(params, cfg, kcfg, prompt, jax.random.PRNGKey(0),
                              eos_id=tok.EOS, bos_id=tok.BOS, buffer_window=4)
    assert r.compactions == [1]
    assert r.extra["cutoff"] is not None


def test_compaction_disabled_keeps_batch(setup):
    cfg, params, kcfg, prompt = setup
    kcfg2 = KappaConfig(num_branches=5, max_new_tokens=24, max_cutoff=4,
                        horizon=6, window=8, mom_buckets=4, compaction=False)
    r = engine.generate_kappa(params, cfg, kcfg2, prompt, jax.random.PRNGKey(0),
                              eos_id=tok.EOS, bos_id=tok.BOS)
    assert r.compactions == []
    assert r.compute_tokens >= r.logical_tokens


def test_token_log_tracks_all_branches(setup):
    cfg, params, kcfg, prompt = setup
    r = engine.generate_kappa(params, cfg, kcfg, prompt, jax.random.PRNGKey(1),
                              eos_id=tok.EOS, bos_id=tok.BOS)
    assert r.all_tokens.shape[0] == kcfg.num_branches
    assert (r.lengths > 0).all()
    assert r.lengths[r.chosen_branch] >= len(r.tokens)


# ------------------------------------------- strategy-level regressions

def _bare_kappa(kcfg, vocab=64):
    """KappaStrategy wired for direct step() calls (no model)."""
    st = strategies.KappaStrategy()
    st.kcfg = kcfg
    st.state = kappa_lib.init_state(kcfg)
    st.log_q = signals.reference_log_q(jnp.zeros(vocab))
    st.chain = cache_lib.bucket_chain(kcfg.num_branches)
    st.pool = st.slot = st.ctrl_rows = None
    return st


def test_kappa_divergence_uses_just_sampled_tokens():
    """Regression: the adaptive cutoff must fire on the step whose
    OUT tokens first all-pairwise diverge — feeding last step's tokens
    (in_tokens) delays it one step."""
    kcfg = KappaConfig(num_branches=4, adaptive_cutoff=True, max_cutoff=50,
                       horizon=6, window=8, mom_buckets=4,
                       max_new_tokens=64, compaction=False)
    st = _bare_kappa(kcfg)
    n = 4
    logits = jax.random.normal(jax.random.PRNGKey(0), (n, 64))
    bids = np.arange(n)
    done = np.zeros(n, bool)
    same = np.zeros(n, np.int32)
    distinct = np.arange(n, dtype=np.int32)
    # two steps where the JUST-sampled tokens agree; in_tokens are fed
    # distinct so the buggy (in_tokens) variant would fire immediately
    for k in (1, 2):
        st.step(logits, distinct, same, bids, done, done.copy(), k)
        assert not bool(st.state.in_gating), \
            "cutoff fired on stale (previous-step) tokens"
    # the step that samples all-distinct tokens must enter gating NOW
    st.step(logits, same, distinct, bids, done, done.copy(), 3)
    assert bool(st.state.in_gating)
    assert int(st.state.cutoff) == 2, \
        "cutoff must pin to the controller step that observed divergence"


def test_eos_step_counted_across_strategies():
    """Accounting parity: a branch's own EOS-emitting step (done_prev
    False, done True after the update) is counted/logged by EVERY
    strategy — greedy/BoN always did; kappa and ST-BoN used the
    post-update done mask and silently dropped the EOS token."""
    n = 4
    kcfg = KappaConfig(num_branches=n, adaptive_cutoff=False, draft_cutoff=8,
                       horizon=6, window=8, mom_buckets=4, max_new_tokens=64,
                       compaction=False)
    logits = jax.random.normal(jax.random.PRNGKey(1), (n, 64))
    bids = np.arange(n)
    done_prev = np.zeros(n, bool)
    done = np.zeros(n, bool)
    done[2] = True                        # branch 2 emitted EOS this step
    out = np.array([5, 6, tok.EOS, 7], np.int32)

    cfg = get_config("deepseek-r1-distill-qwen-1.5b").reduced(
        num_layers=2, d_model=64, vocab_size=64)

    kappa = _bare_kappa(kcfg)
    dec_k = kappa.step(logits, out, out, bids, done, done_prev, 1)

    stbon = strategies.STBoNStrategy(buffer_window=8)
    stbon.begin(None, cfg, kcfg, bos_id=tok.BOS)
    dec_s = stbon.step(logits, out, out, bids, done, done_prev, 1)

    bon = strategies.BoNStrategy()
    bon.begin(None, cfg, kcfg, bos_id=tok.BOS)
    dec_b = bon.step(logits, out, out, bids, done, done_prev, 1,
                     picked_lp=np.zeros(n))

    greedy = strategies.GreedyStrategy()
    dec_g = greedy.step(logits[:1], out[:1], out[:1], np.arange(1),
                        np.array([True]), np.array([False]), 1)

    for name, dec in [("kappa", dec_k), ("stbon", dec_s), ("bon", dec_b)]:
        assert dec.counted[2], f"{name} dropped the EOS-emitting step"
    assert dec_g.counted[0], "greedy dropped the EOS-emitting step"
    # and a branch already done BEFORE the step is never counted
    done_prev2 = done.copy()
    done2 = done.copy()
    dec_k2 = kappa.step(logits, out, out, bids, done2, done_prev2, 2)
    assert not dec_k2.counted[2]


def test_stbon_chooses_most_consistent_on_early_eos():
    """If every branch hits EOS before cutoff + buffer_window forces a
    truncation, ST-BoN must still select by the consistency signal it
    accumulated — not silently fall back to branch 0."""
    n = 3
    kcfg = KappaConfig(num_branches=n, max_cutoff=8, horizon=6, window=8,
                       mom_buckets=4, max_new_tokens=64)
    cfg = get_config("deepseek-r1-distill-qwen-1.5b").reduced(
        num_layers=2, d_model=64, vocab_size=16)
    st = strategies.STBoNStrategy(buffer_window=10)
    st.begin(None, cfg, kcfg, bos_id=tok.BOS)
    # branch 0 is the odd one out; branches 1 and 2 share a distribution
    logits = jnp.asarray(np.stack([
        np.eye(16)[0] * 9.0,
        np.eye(16)[3] * 9.0,
        np.eye(16)[3] * 9.0,
    ]).astype(np.float32))
    bids = np.arange(n)
    zeros = np.zeros(n, bool)
    # step 1: all-distinct tokens → cutoff hits, consistency accumulates
    st.step(logits, np.zeros(n, np.int32), np.array([0, 3, 4], np.int32),
            bids, zeros.copy(), zeros.copy(), 1)
    assert st.cutoff_hit == 1 and not st.truncated
    # step 2: every branch emits EOS — stop fires before truncation
    done = np.ones(n, bool)
    dec = st.step(logits, np.array([0, 3, 4], np.int32),
                  np.full(n, tok.EOS, np.int32), bids, done, zeros.copy(), 2)
    assert dec.stop and not st.truncated
    choice = st.choose(bids, done)
    assert choice in (1, 2), \
        f"must pick a consistent branch, not the default 0 (got {choice})"
    # the deliberate fallback: no divergence ever observed → branch 0
    st2 = strategies.STBoNStrategy(buffer_window=10)
    st2.begin(None, cfg, kcfg, bos_id=tok.BOS)
    assert st2.choose(bids, done) == 0


# ------------------------------------------------------- cache helpers

def test_broadcast_then_gather_roundtrip():
    cfg = get_config("gemma3-4b").reduced(d_model=64)
    from repro.models import init_cache
    c1 = init_cache(cfg, 1, 16)
    cn = cache_lib.broadcast_batch(c1, 4)
    for key in ("stack", "rem"):
        for l1, ln in zip(jax.tree.leaves(c1[key]), jax.tree.leaves(cn[key])):
            assert ln.shape != l1.shape
    c2 = cache_lib.gather_batch(cn, jnp.array([0]))
    for l1, l2 in zip(jax.tree.leaves(c1), jax.tree.leaves(cn)):
        pass
    for l1, l2 in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
        assert l1.shape == l2.shape


def test_bucket_chain_edge_cases():
    assert cache_lib.bucket_chain(1) == [1]          # n=1: no shrink chain
    assert cache_lib.bucket_chain(2) == [2, 1]
    assert cache_lib.bucket_chain(5) == [5, 4, 2, 1]  # non-power-of-two N
    assert cache_lib.bucket_chain(8) == [8, 4, 2, 1]  # power-of-two N
    assert cache_lib.bucket_chain(20) == [20, 16, 8, 4, 2, 1]


def test_next_bucket_edge_cases():
    chain = cache_lib.bucket_chain(5)
    assert cache_lib.next_bucket(chain, 1, 5) == 1    # shrink straight to 1
    assert cache_lib.next_bucket(chain, 3, 5) == 4    # smallest fitting bucket
    assert cache_lib.next_bucket(chain, 5, 5) == 5    # alive > every smaller
    assert cache_lib.next_bucket(chain, 7, 5) == 5    # alive > every bucket
    assert cache_lib.next_bucket(chain, 4, 4) == 4    # no shrink possible
    assert cache_lib.next_bucket(chain, 2, 4) == 2
    chain1 = cache_lib.bucket_chain(1)
    assert cache_lib.next_bucket(chain1, 1, 1) == 1


def test_used_cache_bytes_monotone():
    cfg = get_config("granite-3-8b")
    b1 = cache_lib.used_cache_bytes(cfg, 5, 100, 4096)
    b2 = cache_lib.used_cache_bytes(cfg, 5, 200, 4096)
    b3 = cache_lib.used_cache_bytes(cfg, 10, 200, 4096)
    assert b1 < b2 < b3
    # ring-bounded archs saturate
    cfg2 = get_config("rwkv6-3b")
    s1 = cache_lib.used_cache_bytes(cfg2, 5, 100, 4096)
    s2 = cache_lib.used_cache_bytes(cfg2, 5, 4000, 4096)
    assert s1 == s2, "rwkv6 state is O(1) in sequence length"
