"""Serving-engine behaviour: all four strategies, compaction, memory and
token accounting invariants (random tiny model — accuracy-free checks)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import KappaConfig
from repro.data import tokenizer as tok
from repro.models import init_params
from repro.serving import cache as cache_lib
from repro.serving import engine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("deepseek-r1-distill-qwen-1.5b").reduced(
        num_layers=2, d_model=64, vocab_size=tok.VOCAB_SIZE)
    params = init_params(jax.random.PRNGKey(0), cfg)
    kcfg = KappaConfig(num_branches=5, max_new_tokens=24, max_cutoff=4,
                       horizon=6, window=8, mom_buckets=4)
    prompt = np.array([tok.BOS, tok.PROB, 3, tok.PLUS, 4, tok.EQ, tok.QM])
    return cfg, params, kcfg, prompt


def test_greedy_deterministic(setup):
    cfg, params, kcfg, prompt = setup
    r1 = engine.generate_greedy(params, cfg, kcfg, prompt, jax.random.PRNGKey(0),
                                eos_id=tok.EOS, bos_id=tok.BOS)
    r2 = engine.generate_greedy(params, cfg, kcfg, prompt, jax.random.PRNGKey(7),
                                eos_id=tok.EOS, bos_id=tok.BOS)
    assert r1.tokens == r2.tokens
    assert r1.logical_tokens == r1.compute_tokens == len(r1.tokens)


def test_bon_generates_n_branches(setup):
    cfg, params, kcfg, prompt = setup
    r = engine.generate_bon(params, cfg, kcfg, prompt, jax.random.PRNGKey(0),
                            eos_id=tok.EOS, bos_id=tok.BOS)
    assert 0 <= r.chosen_branch < kcfg.num_branches
    assert r.logical_tokens <= kcfg.num_branches * kcfg.max_new_tokens
    assert len(r.extra["neg_ppl"]) == kcfg.num_branches
    # chosen branch maximizes negative perplexity
    assert r.chosen_branch == int(np.argmax(r.extra["neg_ppl"]))


def test_kappa_prunes_and_compacts(setup):
    cfg, params, kcfg, prompt = setup
    r = engine.generate_kappa(params, cfg, kcfg, prompt, jax.random.PRNGKey(0),
                              eos_id=tok.EOS, bos_id=tok.BOS)
    assert r.compactions, "KAPPA must shrink the branch batch"
    assert r.compactions == sorted(r.compactions, reverse=True)
    assert r.compactions[-1] <= 2
    assert 0 <= r.chosen_branch < kcfg.num_branches


def test_kappa_cheaper_than_bon(setup):
    cfg, params, kcfg, prompt = setup
    rb = engine.generate_bon(params, cfg, kcfg, prompt, jax.random.PRNGKey(0),
                             eos_id=tok.EOS, bos_id=tok.BOS)
    rk = engine.generate_kappa(params, cfg, kcfg, prompt, jax.random.PRNGKey(0),
                               eos_id=tok.EOS, bos_id=tok.BOS)
    assert rk.logical_tokens < rb.logical_tokens
    assert rk.peak_cache_bytes <= rb.peak_cache_bytes


def test_stbon_truncates_to_one(setup):
    cfg, params, kcfg, prompt = setup
    r = engine.generate_stbon(params, cfg, kcfg, prompt, jax.random.PRNGKey(0),
                              eos_id=tok.EOS, bos_id=tok.BOS, buffer_window=4)
    assert r.compactions == [1]
    assert r.extra["cutoff"] is not None


def test_compaction_disabled_keeps_batch(setup):
    cfg, params, kcfg, prompt = setup
    kcfg2 = KappaConfig(num_branches=5, max_new_tokens=24, max_cutoff=4,
                        horizon=6, window=8, mom_buckets=4, compaction=False)
    r = engine.generate_kappa(params, cfg, kcfg2, prompt, jax.random.PRNGKey(0),
                              eos_id=tok.EOS, bos_id=tok.BOS)
    assert r.compactions == []
    assert r.compute_tokens >= r.logical_tokens


def test_token_log_tracks_all_branches(setup):
    cfg, params, kcfg, prompt = setup
    r = engine.generate_kappa(params, cfg, kcfg, prompt, jax.random.PRNGKey(1),
                              eos_id=tok.EOS, bos_id=tok.BOS)
    assert r.all_tokens.shape[0] == kcfg.num_branches
    assert (r.lengths > 0).all()
    assert r.lengths[r.chosen_branch] >= len(r.tokens)


# ------------------------------------------------------- cache helpers

def test_broadcast_then_gather_roundtrip():
    cfg = get_config("gemma3-4b").reduced(d_model=64)
    from repro.models import init_cache
    c1 = init_cache(cfg, 1, 16)
    cn = cache_lib.broadcast_batch(c1, 4)
    for key in ("stack", "rem"):
        for l1, ln in zip(jax.tree.leaves(c1[key]), jax.tree.leaves(cn[key])):
            assert ln.shape != l1.shape
    c2 = cache_lib.gather_batch(cn, jnp.array([0]))
    for l1, l2 in zip(jax.tree.leaves(c1), jax.tree.leaves(cn)):
        pass
    for l1, l2 in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
        assert l1.shape == l2.shape


def test_bucket_chain_edge_cases():
    assert cache_lib.bucket_chain(1) == [1]          # n=1: no shrink chain
    assert cache_lib.bucket_chain(2) == [2, 1]
    assert cache_lib.bucket_chain(5) == [5, 4, 2, 1]  # non-power-of-two N
    assert cache_lib.bucket_chain(8) == [8, 4, 2, 1]  # power-of-two N
    assert cache_lib.bucket_chain(20) == [20, 16, 8, 4, 2, 1]


def test_next_bucket_edge_cases():
    chain = cache_lib.bucket_chain(5)
    assert cache_lib.next_bucket(chain, 1, 5) == 1    # shrink straight to 1
    assert cache_lib.next_bucket(chain, 3, 5) == 4    # smallest fitting bucket
    assert cache_lib.next_bucket(chain, 5, 5) == 5    # alive > every smaller
    assert cache_lib.next_bucket(chain, 7, 5) == 5    # alive > every bucket
    assert cache_lib.next_bucket(chain, 4, 4) == 4    # no shrink possible
    assert cache_lib.next_bucket(chain, 2, 4) == 2
    chain1 = cache_lib.bucket_chain(1)
    assert cache_lib.next_bucket(chain1, 1, 1) == 1


def test_used_cache_bytes_monotone():
    cfg = get_config("granite-3-8b")
    b1 = cache_lib.used_cache_bytes(cfg, 5, 100, 4096)
    b2 = cache_lib.used_cache_bytes(cfg, 5, 200, 4096)
    b3 = cache_lib.used_cache_bytes(cfg, 10, 200, 4096)
    assert b1 < b2 < b3
    # ring-bounded archs saturate
    cfg2 = get_config("rwkv6-3b")
    s1 = cache_lib.used_cache_bytes(cfg2, 5, 100, 4096)
    s2 = cache_lib.used_cache_bytes(cfg2, 5, 4000, 4096)
    assert s1 == s2, "rwkv6 state is O(1) in sequence length"
