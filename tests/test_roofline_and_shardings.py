"""Unit tests for the roofline HLO parser and the sharding rule engine
(rules evaluated against an abstract 16×16 mesh — no devices needed)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import shardings as sh
from repro.launch.roofline import Roofline, collective_bytes


# ------------------------------------------------------------- parser

HLO = """
ENTRY %main {
  %ag = bf16[2,1024,512]{2,1,0} all-gather(%x), replica_groups={}
  %ar = f32[256,128]{1,0} all-reduce(%y), to_apply=%sum
  %tup = (f32[8,128]{1,0}, f32[8,128]{1,0}) all-reduce(%a, %b), to_apply=%sum
  %a2a = bf16[16,64,32]{2,1,0} all-to-all(%z), dimensions={0}
  %cp = f32[4,4]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %dot = f32[128,128]{1,0} dot(%p, %q)
}
"""


def test_collective_bytes_parses_all_kinds():
    cb = collective_bytes(HLO)
    assert cb["all-gather"] == 2 * 1024 * 512 * 2
    assert cb["all-reduce"] == 256 * 128 * 4 + 2 * (8 * 128 * 4)
    assert cb["all-to-all"] == 16 * 64 * 32 * 2
    assert cb["collective-permute"] == 4 * 4 * 4
    assert cb["reduce-scatter"] == 0


def test_roofline_terms_and_dominant():
    r = Roofline(flops=197e12, hbm_bytes=0, coll_bytes=0, chips=256,
                 model_flops=197e12 * 256, argio_bytes=819e9 * 2)
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 2.0) < 1e-9
    assert r.dominant == "memory"
    assert abs(r.useful_flops_ratio - 1.0) < 1e-9


# ------------------------------------------------------- sharding rules

def _mesh16():
    # AbstractMesh's signature has churned across jax releases:
    # (axis_sizes, axis_names) pairs, kwargs, or a ((name, size), ...) tuple
    for args in [((16, 16), ("data", "model")),
                 ((("data", 16), ("model", 16)),)]:
        try:
            return jax.sharding.AbstractMesh(*args)
        except TypeError:
            continue
    return jax.sharding.AbstractMesh(axis_sizes=(16, 16),
                                     axis_names=("data", "model"))


@pytest.fixture(scope="module")
def mesh():
    return _mesh16()


def test_embed_vocab_sharding_fallback(mesh):
    cfg = get_config("granite-3-8b")
    # 151936 % 16 == 0 → vocab sharded
    assert sh.param_spec("embed", (151936, 2048), mesh, cfg) == P("model", None)
    # 49155 not divisible → falls back to d_model sharding
    assert sh.param_spec("embed", (49155, 4096), mesh, cfg) == P(None, "model")
    # neither divisible → replicated
    assert sh.param_spec("embed", (49155, 333), mesh, cfg) == P()


def test_attention_weight_sharding(mesh):
    cfg = get_config("granite-3-8b")
    assert sh.param_spec("stack/0/attn/wq", (40, 4096, 4096), mesh, cfg) \
        == P(None, None, "model")
    assert sh.param_spec("stack/0/attn/wo", (40, 4096, 4096), mesh, cfg) \
        == P(None, "model", None)


def test_moe_expert_parallel_vs_tensor_fallback(mesh):
    qwen3 = get_config("qwen3-moe-30b-a3b")   # 128 experts % 16 == 0
    assert sh.param_spec("stack/0/ffn/wg", (48, 128, 2048, 768), mesh, qwen3) \
        == P(None, "model", None, None)
    granite = get_config("granite-moe-3b-a800m")  # 40 experts % 16 != 0
    spec = sh.param_spec("stack/0/ffn/wg", (32, 40, 1536, 512), mesh, granite)
    assert spec == P(None, None, None, "model"), "falls back to ff sharding"


def test_norms_replicated(mesh):
    cfg = get_config("granite-3-8b")
    assert sh.param_spec("stack/0/ln1", (40, 4096), mesh, cfg) == P()
    assert sh.param_spec("final_norm", (4096,), mesh, cfg) == P()
